"""Continuous-batching serving engine (Orca/vLLM-style slot scheduler).

A fixed pool of B slots shares one batched KV cache.  New requests prefill
into a free slot (prompt lengths padded to power-of-two buckets to bound
recompiles); every engine step decodes ALL active slots in one batched
step with per-slot lengths; finished slots free immediately and are refilled
from the queue — no head-of-line blocking on long generations.
"""
from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import forward, make_cache


@dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 16
    generated: list = field(default_factory=list)
    done: bool = False


@dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    slot_occupancy: list = field(default_factory=list)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 256, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.B = max_batch
        self.T = max_len
        self.cache = make_cache(cfg, max_batch, max_len, src_len=1,
                                dtype=cfg.cdtype)
        self.lengths = np.zeros(max_batch, np.int32)
        self.slots: list[Optional[Request]] = [None] * max_batch
        self.stats = EngineStats()
        self.greedy = greedy

        @functools.partial(jax.jit, static_argnames=("plen",))
        def prefill_one(params, cache, tokens, slot, plen):
            # tokens: (1, plen_padded); writes slot's KV rows.  The slot's
            # sub-cache is ZEROED first — recurrent states (rwkv/mamba) from
            # a previous occupant must not leak into the new request.
            sub = jax.tree.map(
                lambda c: jnp.zeros_like(
                    jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1)),
                cache)
            logits, _, sub2 = forward(params, tokens, cfg, cache=sub,
                                      cache_index=jnp.zeros((), jnp.int32))
            cache2 = jax.tree.map(
                lambda c, s_: jax.lax.dynamic_update_slice_in_dim(
                    c, s_.astype(c.dtype), slot, axis=1), cache, sub2)
            return logits[:, plen - 1], cache2

        @jax.jit
        def decode_all(params, cache, tokens, lengths):
            logits, _, cache2 = forward(params, tokens, cfg, cache=cache,
                                        lengths=lengths)
            return logits[:, 0], cache2

        self._prefill = prefill_one
        self._decode = decode_all

    # ------------------------------------------------------------ internals
    @staticmethod
    def _bucket(n: int) -> int:
        return max(8, 1 << (n - 1).bit_length())

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _sample(self, logits_row) -> int:
        return int(jnp.argmax(logits_row))

    # ------------------------------------------------------------ api
    def admit(self, req: Request) -> bool:
        slot = self._free_slot()
        if slot is None:
            return False
        plen = len(req.prompt)
        bucket = self._bucket(plen)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt
        logits, self.cache = self._prefill(
            self.params, self.cache, jnp.asarray(toks), slot, plen)
        first = self._sample(logits[0])
        req.generated.append(first)
        self.slots[slot] = req
        self.lengths[slot] = plen
        self.stats.prefills += 1
        self.stats.tokens_out += 1
        return True

    def step(self):
        """One decode step for all active slots."""
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        toks = np.zeros((self.B, 1), np.int32)
        for i in active:
            toks[i, 0] = self.slots[i].generated[-1]
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(self.lengths))
        self.stats.decode_steps += 1
        self.stats.slot_occupancy.append(len(active))
        logits_np = np.asarray(logits)
        for i in active:
            req = self.slots[i]
            self.lengths[i] += 1
            nxt = int(np.argmax(logits_np[i]))
            req.generated.append(nxt)
            self.stats.tokens_out += 1
            if len(req.generated) >= req.max_new_tokens or \
                    self.lengths[i] >= self.T - 1:
                req.done = True
                self.slots[i] = None
                self.lengths[i] = 0

    def run(self, requests: list[Request]) -> list[Request]:
        """Continuous batching: admit whenever a slot frees."""
        pending = list(requests)
        done: list[Request] = []
        while pending or any(s is not None for s in self.slots):
            while pending and self._free_slot() is not None:
                if self.admit(pending[0]):
                    pending.pop(0)
                else:
                    break
            self.step()
            for r in requests:
                if r.done and r not in done:
                    done.append(r)
        return done

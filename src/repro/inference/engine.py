"""Continuous-batching serving SCHEDULER (Orca/vLLM-style slot scheduler).

This module is the policy half of the engine: a fixed pool of B slots, new
requests prefill into a free slot (prompt lengths padded to power-of-two
buckets to bound recompiles), every step decodes ALL active slots in one
batched step with per-slot lengths, finished slots free immediately and
are refilled from the queue — no head-of-line blocking.  Under
``cache="paged"`` it also runs chunked prefill, the evict-or-preempt
policy, and the host-offload tier over ``repro.kvcache`` block tables.

Everything device-side lives behind the ``ExecutionBackend`` protocol
(``repro.inference.backends``): cache construction/placement, the four
step kinds, plan/fusion dispatch, and per-device launch accounting.  The
scheduler never touches meshes, shard_map, or placement — it manipulates
``Request`` objects, numpy block tables, and whatever cache pytree the
backend hands back.  Backends:

  * ``tp=1`` -> ``LocalBackend``: the single-device path; ``plan="jit"``
    runs whole-step jit closures, any other strategy routes through the
    launch-plan runtime (``repro.runtime``) so ``EngineStats`` reports
    real dispatch counts and modeled TKLQT (``plan="autotuned"`` resolves
    the strategy from a measured plan table).
  * ``tp>1`` -> ``ShardedBackend``: tensor-parallel shard_map serving;
    params/KV head-sharded over a device mesh, per-device dispatch
    streams and collective traffic (psum payloads priced over the
    platform's coupling link) surfaced in ``EngineStats``.

Because admission, preemption, and sampling are scheduler-side and the
backends agree numerically, ``ServeEngine(tp=2)`` drains any workload —
including admit -> preempt -> resume under pool pressure — with greedy
tokens byte-identical to ``tp=1``.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.inference.backends import CallAccount, make_backend
from repro.inference.kv_quant import KV_DTYPES
from repro.inference.speculative import (default_draft_config,
                                         draft_params_from_target,
                                         is_truncation_of, pick_spec_k,
                                         validate_draft)
from repro.telemetry.metrics import RequestTiming
from repro.telemetry.registry import MetricsRegistry

PLAN_STRATEGIES = ("jit", "eager", "whole_graph", "chain", "auto", "fused",
                   "autotuned")
CACHE_MODES = ("contiguous", "paged")
OFFLOAD_MODES = ("none", "host")


@dataclass
class Request:
    """One serving request: prompt in, greedy continuation out."""

    rid: int
    prompt: list
    max_new_tokens: int = 16
    arrival_s: float = 0.0         # offset on the engine clock (open loop)
    generated: list = field(default_factory=list)
    done: bool = False
    status: str = "queued"         # queued|active|preempted|done|rejected


@dataclass
class _PrefillTask:
    """One in-flight (chunked) prefill: tokens left to write into the
    paged cache for a slot.  ``replay=True`` rebuilds KV for a preempted
    request (prompt + already-emitted tokens) without emitting anything."""
    req: Request
    slot: int
    toks: list
    pos: int = 0                   # tokens already written
    replay: bool = False
    last_logits: Optional[jax.Array] = None


class EngineStats:
    """Serving counters as a DERIVED VIEW of a ``MetricsRegistry``.

    Every scalar field lives in a registry gauge: attribute reads pull the
    gauge value (int-typed fields come back as Python ints), assignments
    and ``+=`` write it.  The engine's counting code is unchanged — but
    ``registry.snapshot()`` and the Prometheus exporter now see exactly
    the numbers the engine reports, with no second bookkeeping path to
    drift.  Per-step series, per-request timings, and other non-scalar
    state stay plain attributes (series belong in histograms, which the
    engine feeds separately).
    """

    # attribute -> (gauge name, python type, help text)
    _SCALARS = {
        "prefills": ("engine_prefills", int, "prefill steps executed"),
        "decode_steps": ("engine_decode_steps", int,
                         "batched decode steps executed"),
        "tokens_out": ("engine_tokens_out", int, "tokens emitted"),
        "prefill_dispatches": ("engine_prefill_dispatches", int,
                               "host dispatches (launches) in prefills"),
        "decode_dispatches": ("engine_decode_dispatches", int,
                              "host dispatches across all decode steps"),
        "fused_dispatches": ("engine_fused_dispatches", int,
                             "decode dispatches that ran fused kernels"),
        "modeled_tklqt_s": ("engine_modeled_tklqt_seconds", float,
                            "device-model TKLQT summed over steps "
                            "(0 under plan=jit: nothing modeled)"),
        "measured_dispatch_s": ("engine_measured_dispatch_seconds", float,
                                "measured host launch tax, all steps"),
        "decode_dispatch_time_s": ("engine_decode_dispatch_seconds", float,
                                   "measured launch tax, decode only"),
        # ---- tensor parallelism (tp=1: one stream, zero collectives)
        "collectives": ("engine_collectives", int,
                        "collective ops issued (psums)"),
        "collective_bytes": ("engine_collective_bytes", int,
                             "payload bytes entering collectives"),
        "decode_collective_bytes": ("engine_decode_collective_bytes", int,
                                    "decode-step-only collective payload"),
        "modeled_collective_tax_s": ("engine_modeled_collective_tax_seconds",
                                     float,
                                     "collectives priced over the link"),
        # ---- paged KV cache (cache="paged"; zero under contiguous)
        "rejected": ("engine_rejected", int,
                     "admissions refused: plen + budget > max_len"),
        "preemptions": ("engine_preemptions", int,
                        "slots evicted under block-pool pressure"),
        "prefill_chunks": ("engine_prefill_chunks", int,
                           "chunked-prefill segments executed"),
        "offload_bytes": ("engine_offload_bytes", int,
                          "measured KV bytes evicted to the host tier"),
        "restore_bytes": ("engine_restore_bytes", int,
                          "measured KV bytes restored from the host tier"),
        "offload_transfers": ("engine_offload_transfers", int,
                              "block DMAs (evict + restore directions)"),
        "modeled_offload_tax_s": ("engine_modeled_offload_tax_seconds",
                                  float,
                                  "offload DMAs priced over the coupling "
                                  "link (core.device_model PCIe/C2C)"),
        # ---- speculative decoding (speculative=True; zero otherwise)
        "spec_rounds": ("engine_spec_rounds", int,
                        "draft-propose + batched-verify rounds"),
        "proposed": ("engine_spec_proposed", int,
                     "draft tokens offered to verification"),
        "accepted": ("engine_spec_accepted", int,
                     "draft tokens accepted AND emitted"),
        "corrections": ("engine_spec_corrections", int,
                        "target correction tokens emitted"),
        "draft_dispatches": ("engine_draft_dispatches", int,
                             "launches on the draft dispatch stream"),
        "modeled_draft_launch_tax_s": (
            "engine_modeled_draft_launch_tax_seconds", float,
            "draft stream launches, platform-priced"),
        # ---- prefix sharing (share_prefix=True; zero otherwise)
        "prefix_adoptions": ("engine_prefix_adoptions", int,
                             "admissions that adopted shared prefix blocks"),
        "shared_prefix_tokens": ("engine_shared_prefix_tokens", int,
                                 "prompt tokens served from shared blocks "
                                 "instead of re-prefilling"),
    }

    def __init__(self, plan: str = "jit", tp: int = 1, registry=None):
        if registry is None:
            from repro.telemetry.registry import MetricsRegistry
            registry = MetricsRegistry()
        object.__setattr__(self, "registry", registry)
        gauges = {}
        for attr, (name, _, help_text) in self._SCALARS.items():
            g = registry.gauge(name, help_text)
            g.set(0)                      # fresh stats zero their gauges
            gauges[attr] = g
        object.__setattr__(self, "_gauges", gauges)
        self.plan = plan
        self.tp = tp                   # device streams every dispatch fans to
        self.slot_occupancy = []
        self.rule_hits = {}            # rule name -> launches
        self.step_times_s = []         # decode step durations
        self.per_device_dispatches = {}
        self.block_pool_utilization = []  # per decode step
        # single source of truth for per-request latency: rid ->
        # RequestTiming (ttft_s/e2e_s/itl_samples_s below are derived)
        self.timings = {}

    def __getattr__(self, name):
        spec = type(self)._SCALARS.get(name)
        if spec is None:
            raise AttributeError(
                f"{type(self).__name__!r} object has no attribute {name!r}")
        try:
            gauges = object.__getattribute__(self, "_gauges")
        except AttributeError:
            raise AttributeError(name) from None
        v = gauges[name].value()
        return int(v) if spec[1] is int else v

    def __setattr__(self, name, value):
        if name in self._SCALARS:
            self._gauges[name].set(value)
        else:
            object.__setattr__(self, name, value)

    @property
    def dispatches_per_decode_step(self) -> float:
        """Mean host dispatches (kernel launches) per decode step."""
        return (self.decode_dispatches / self.decode_steps
                if self.decode_steps else 0.0)

    @property
    def fused_dispatches_per_decode_step(self) -> float:
        """Mean fused-kernel launches per decode step."""
        return (self.fused_dispatches / self.decode_steps
                if self.decode_steps else 0.0)

    @property
    def ttft_s(self) -> dict:
        """Time-to-first-token per request id (first-token seen only)."""
        return {rid: t.ttft_s for rid, t in self.timings.items()
                if not math.isnan(t.first_token_s)}

    @property
    def e2e_s(self) -> dict:
        """End-to-end latency per completed request id."""
        return {rid: t.e2e_s for rid, t in self.timings.items()
                if not math.isnan(t.done_s)}

    @property
    def itl_samples_s(self) -> list:
        """Every inter-token-latency gap across all requests."""
        return [g for t in self.timings.values() for g in t.itl_s]

    @property
    def mean_ttft_s(self) -> float:
        """Mean time-to-first-token over requests that emitted one."""
        ttft = self.ttft_s
        return sum(ttft.values()) / len(ttft) if ttft else 0.0

    @property
    def mean_itl_s(self) -> float:
        """Mean inter-token latency over all sampled gaps."""
        itl = self.itl_samples_s
        return sum(itl) / len(itl) if itl else 0.0

    @property
    def mean_block_pool_utilization(self) -> float:
        """Mean paged block-pool occupancy across sampled steps."""
        u = self.block_pool_utilization
        return sum(u) / len(u) if u else 0.0

    @property
    def peak_block_pool_utilization(self) -> float:
        """Peak paged block-pool occupancy across sampled steps."""
        return max(self.block_pool_utilization, default=0.0)

    @property
    def launch_tax_per_step_s(self) -> float:
        """Measured host dispatch time per engine step (prefill+decode)."""
        steps = self.prefills + self.decode_steps
        return self.measured_dispatch_s / steps if steps else 0.0

    @property
    def launch_tax_per_decode_step_s(self) -> float:
        """Decode-only launch tax per decode step — comparable against the
        mean decode-step latency (the measured boundedness denominator)."""
        return (self.decode_dispatch_time_s / self.decode_steps
                if self.decode_steps else 0.0)

    @property
    def accept_rate(self) -> float:
        """Fraction of proposed draft tokens accepted (and emitted)."""
        return self.accepted / self.proposed if self.proposed else 0.0

    @property
    def spec_emitted(self) -> int:
        """Tokens emitted through speculative rounds (accept + correct)."""
        return self.accepted + self.corrections

    @property
    def steps_per_emitted_token(self) -> float:
        """Sequential target steps per token emitted in spec rounds —
        < 1.0 is the speculation win (plain decode is exactly 1.0)."""
        return (self.spec_rounds / self.spec_emitted
                if self.spec_emitted else 0.0)

    @property
    def collective_bytes_per_decode_step(self) -> float:
        """Decode-only psum payload per decode step (prefill psums are
        tracked in ``collective_bytes`` but excluded here, so the figure
        is a property of the decode step, not the workload shape)."""
        return (self.decode_collective_bytes / self.decode_steps
                if self.decode_steps else 0.0)


class ServeEngine:
    """Continuous-batching serving scheduler over an execution backend.

    The engine is pure policy — slot admission, chunked prefill,
    preempt/offload/resume, greedy sampling, virtual-clock accounting —
    and delegates every device interaction (cache placement, the step
    kinds, launch accounting) to its ``ExecutionBackend``: local
    (``tp=1``), tensor-parallel sharded (``tp>=2``), optionally wrapped
    speculative.  Drive it closed-loop with ``run(requests)`` or
    open-loop/steppable with ``submit()`` + ``tick()`` (the replica-
    fleet router uses the latter).
    """

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 plan: str = "jit", platform: str = "TPU-v5e",
                 plan_table=None, telemetry=None, tp: int = 1,
                 backend=None,
                 cache: str = "contiguous", block_size: int = 16,
                 num_blocks: Optional[int] = None, offload: str = "none",
                 prefill_chunk: Optional[int] = None,
                 kv_dtype: str = "bf16", share_prefix: bool = False,
                 prefix_len: int = 8,
                 speculative: bool = False, draft_config=None,
                 draft_params=None, spec_k: int = 4,
                 spec_inflection: Optional[int] = None, monitor=True,
                 tracer=None):
        if plan not in PLAN_STRATEGIES:
            raise ValueError(f"unknown plan {plan!r}; "
                             f"expected one of {PLAN_STRATEGIES}")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch} "
                             "(an engine with no slots can never admit)")
        if tp < 1:
            raise ValueError(f"tp must be >= 1, got {tp}")
        if cache not in CACHE_MODES:
            raise ValueError(f"unknown cache {cache!r}; "
                             f"expected one of {CACHE_MODES}")
        if offload not in OFFLOAD_MODES:
            raise ValueError(f"unknown offload {offload!r}; "
                             f"expected one of {OFFLOAD_MODES}")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {prefill_chunk}")
        if cache != "paged" and (offload != "none"
                                 or prefill_chunk is not None):
            raise ValueError(
                "offload= and prefill_chunk= need cache='paged' (the "
                "contiguous cache has no blocks to evict or chunk over)")
        if kv_dtype not in KV_DTYPES:
            raise ValueError(f"unknown kv_dtype {kv_dtype!r}; "
                             f"expected one of {KV_DTYPES}")
        if cache != "paged" and (kv_dtype != "bf16" or share_prefix):
            raise ValueError(
                "kv_dtype= and share_prefix= need cache='paged' (the "
                "contiguous cache has no pages to quantize or share)")
        if prefix_len < 1:
            raise ValueError(f"prefix_len must be >= 1, got {prefix_len}")
        if not speculative and (draft_config is not None
                                or draft_params is not None):
            raise ValueError(
                "draft_config=/draft_params= need speculative=True")
        if speculative:
            if not greedy:
                raise ValueError(
                    "speculative=True requires greedy=True: the accept "
                    "rule matches draft tokens against target ARGMAX — "
                    "sampled decoding has no byte-identical reference "
                    "sequence to preserve")
            if plan != "jit":
                raise ValueError(
                    f"speculative=True executes plan='jit' only (got "
                    f"{plan!r}): the launch-plan runtime replays fixed "
                    "single-token streams; model the draft/verify launch "
                    "trade with telemetry.characterize.spec_sweep or "
                    "runtime.planner.simulate_plan(draft_launches=...)")
        if plan == "autotuned":
            # measured plan table (runtime.autotune): the strategy the
            # autotuner benchmarked best for this slot count
            from repro.runtime.autotune import PlanTable
            if plan_table is None:
                raise ValueError(
                    "plan='autotuned' needs plan_table= (a PlanTable, "
                    "a dict, or a path to a saved plan table)")
            table = (plan_table if isinstance(plan_table, PlanTable)
                     else PlanTable.from_any(plan_table))
            if table.arch and table.arch != cfg.name:
                raise ValueError(
                    f"plan table was autotuned for arch "
                    f"{table.arch!r}, engine config is {cfg.name!r}; "
                    f"re-run repro.launch.autotune for this model")
            if table.d_model and table.d_model != cfg.d_model:
                raise ValueError(
                    f"plan table was autotuned at d_model="
                    f"{table.d_model} (reduced() keeps the arch name), "
                    f"engine config has d_model={cfg.d_model}; re-run "
                    f"repro.launch.autotune against this exact config")
            if table.platform and table.platform != platform:
                raise ValueError(
                    f"plan table was autotuned for platform "
                    f"{table.platform!r}, engine uses {platform!r}; "
                    f"re-run repro.launch.autotune for this platform")
            plan = table.lookup(max_batch)
            self.plan_label = f"autotuned:{plan}"
        else:
            self.plan_label = plan
        self.cfg = cfg
        self.params = params
        self.B = max_batch
        self.T = max_len
        self.cache_mode = cache
        self.prefill_chunk = prefill_chunk
        # the backend owns everything device-side (placement, meshes,
        # compiled steps); pass backend= to serve through a custom one
        self.backend = backend if backend is not None else make_backend(
            cfg, params, max_batch=max_batch, max_len=max_len, tp=tp,
            plan=plan, platform=platform)
        self.speculative = bool(speculative)
        self.spec_k = spec_k
        self.spec_inflection = spec_inflection
        if speculative:
            # wrap whatever target backend was built (local OR sharded —
            # speculation composes with tensor parallelism) with the
            # draft-propose / batched-verify layer
            draft_cfg = (draft_config if draft_config is not None
                         else default_draft_config(cfg))
            validate_draft(cfg, draft_cfg, spec_k)
            if draft_params is None:
                if not is_truncation_of(draft_cfg, cfg):
                    raise ValueError(
                        f"draft config {draft_cfg.name!r} is not a "
                        f"truncation of {cfg.name!r} (different width/"
                        "heads/pattern), so its weights cannot be sliced "
                        "from the target: pass draft_params= explicitly "
                        "(e.g. repro.models.init_params(key, "
                        "draft_config))")
                draft_params = draft_params_from_target(params, draft_cfg)
            from repro.inference.backends.speculative import \
                SpeculativeBackend
            self.backend = SpeculativeBackend(
                self.backend, draft_cfg, draft_params,
                max_batch=max_batch, max_len=max_len, platform=platform)
            self.draft_cfg = draft_cfg
            self.draft_cache = self.backend.init_draft_cache()
            self.draft_lengths = np.zeros(max_batch, np.int32)
        # derived, not stored: an injected backend= decides the degree
        self.tp = self.backend.info.tp
        self.kv_dtype = kv_dtype
        self.share_prefix = bool(share_prefix)
        self.prefix_len = prefix_len
        if cache == "paged":
            from repro.kvcache import (HostOffloadTier, PagedKVCache,
                                       default_num_blocks)
            # default pool sized by BYTES: a quantized pool holds the same
            # byte budget as the bf16 full-capacity pool, in more blocks
            nb = default_num_blocks(max_batch, max_len, block_size,
                                    num_blocks, kv_dtype=kv_dtype,
                                    hd=cfg.hd,
                                    payload_bytes=jnp.dtype(
                                        cfg.cdtype).itemsize)
            self.kv = PagedKVCache(cfg, num_blocks=nb,
                                   block_size=block_size, max_len=max_len,
                                   dtype=cfg.cdtype, kv_dtype=kv_dtype)
            self.cache = self.backend.init_paged_cache(self.kv)
            self.offload_tier = (
                HostOffloadTier(platform, tp=self.backend.info.tp)
                if offload == "host" else None)
        else:
            self.kv = None
            self.offload_tier = None
            self.cache = self.backend.init_contiguous_cache()
        # prefix-sharing donor registry: 8-token prompt-prefix key (the
        # SAME key the router's prefix-affinity policy hashes, so sticky
        # routing lands same-prefix requests where the donor blocks live)
        # -> (donor rid, donor's full verified token sequence)
        self._prefix_donors: dict = {}
        self._prefill_tasks: dict = {}      # slot -> _PrefillTask
        self._preempted: list = []          # evicted Requests awaiting resume
        self._pending: list = []            # submitted, not yet admitted
        self._admit_seq = 0                 # victim ordering (youngest first)
        self._last_step_progressed = True
        self.lengths = np.zeros(max_batch, np.int32)
        self.slots: list[Optional[Request]] = [None] * max_batch
        self.registry = MetricsRegistry()
        self.stats = EngineStats(plan=self.plan_label,
                                 tp=self.backend.info.tp,
                                 registry=self.registry)
        self._dev_base = self.backend.device_dispatches  # reset() baseline
        self.greedy = greedy
        self.plan = plan
        self.platform = platform
        self.telemetry = telemetry          # Optional[SpanRecorder]
        # request-scoped lifecycle tracer (Optional[RequestTracer]); a
        # fleet shares ONE instance across replicas so a trace follows
        # its request through re-queue and re-dispatch — reset() leaves
        # it alone for the same reason
        self.tracer = tracer
        # live boundedness monitor: True -> create one, False/None -> off,
        # or pass a BoundednessMonitor instance to share across engines
        if monitor is True:
            from repro.telemetry.monitor import BoundednessMonitor
            self.monitor = BoundednessMonitor()
        elif monitor:
            self.monitor = monitor
        else:
            self.monitor = None
        # virtual serving clock (seconds): advances by measured wall time
        # while the engine works, jumps forward over idle gaps so open-loop
        # arrival schedules don't cost real wall time to honor
        self.now = 0.0
        self._bind_telemetry()

    # ------------------------------------------------------------ internals
    @property
    def timings(self) -> dict:
        """Per-request RequestTiming objects (lives on stats)."""
        return self.stats.timings

    @property
    def _planned_decode(self):
        """The decode _PlannedFn when a launch-plan mode is active
        (kept as an engine attribute for telemetry/tests compat)."""
        return self.backend.planned_decode

    @staticmethod
    def _bucket(n: int) -> int:
        """Round a length to its power-of-two compile bucket (min 8)."""
        return max(8, 1 << (n - 1).bit_length())

    def _free_slot(self) -> Optional[int]:
        """Index of the first open batch slot, or None when full."""
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _sample(self, logits_row) -> int:
        """Greedy token choice from one logits row."""
        return int(jnp.argmax(logits_row))

    def _absorb(self, acct: CallAccount, *, decode: bool) -> None:
        """Fold one backend call's accounting into EngineStats — the one
        merge path shared by jit, planned, and sharded execution."""
        if decode:
            self.stats.decode_dispatches += acct.dispatches
            self.stats.decode_dispatch_time_s += acct.host_time_s
            self.stats.fused_dispatches += len(acct.rule_names)
            self.stats.decode_collective_bytes += acct.collective_bytes
        else:
            self.stats.prefill_dispatches += acct.dispatches
        self.stats.measured_dispatch_s += acct.host_time_s
        self.stats.modeled_tklqt_s += acct.modeled_tklqt_s
        for nm in acct.rule_names:
            self.stats.rule_hits[nm] = self.stats.rule_hits.get(nm, 0) + 1
        self.stats.collectives += acct.collectives
        self.stats.collective_bytes += acct.collective_bytes
        self.stats.modeled_collective_tax_s += acct.modeled_collective_tax_s
        self.stats.proposed += acct.proposed
        self.stats.accepted += acct.accepted
        self.stats.draft_dispatches += acct.draft_dispatches
        self.stats.modeled_draft_launch_tax_s += \
            acct.modeled_draft_launch_tax_s
        self.stats.per_device_dispatches = {
            d: n - self._dev_base.get(d, 0)
            for d, n in self.backend.device_dispatches.items()}

    def _record_segments(self, acct: CallAccount, t_begin: float) -> None:
        """Per-segment dispatch spans on the engine clock: the measured
        host times of the last planned call, laid out back-to-back from
        the step's start (tid 1 of the merged Chrome trace)."""
        if self.telemetry is None or not self.telemetry.enabled:
            return
        t = t_begin
        for name, h in zip(acct.segment_names, acct.segment_host_times):
            self.telemetry.add(name, "dispatch", t, t + h, tid=1)
            t += h

    # ------------------------------------------------------- observability
    def _bind_telemetry(self) -> None:
        """Point every instrumented component at ``self.registry`` (fresh
        after ``reset()``: gauges restart at zero, histograms empty)."""
        reg = self.registry
        if hasattr(self.backend, "bind_metrics"):
            self.backend.bind_metrics(reg)
        if self.kv is not None:
            self.kv.pool.bind_metrics(reg)
        if self.offload_tier is not None:
            self.offload_tier.bind_metrics(reg)
        if self.telemetry is not None and hasattr(self.telemetry,
                                                  "bind_metrics"):
            self.telemetry.bind_metrics(reg)
        if self.monitor is not None:
            self.monitor.bind_metrics(reg)
        self._h_step = reg.histogram(
            "engine_step_time_seconds", "decode step wall time")
        self._h_ttft = reg.histogram(
            "engine_ttft_seconds",
            "arrival to first emission, engine clock")
        self._h_itl = reg.histogram(
            "engine_itl_seconds", "inter-token latency")

    def _note_step(self, batch: int, dt: float, acct: CallAccount) -> None:
        """One decode step into the step-time histogram and the live
        boundedness monitor (measured step time + measured launch tax,
        plus the step's per-operator attribution when a planned mode
        carries one)."""
        if self._h_step is not None:
            self._h_step.observe(dt)
        if self.monitor is not None:
            self.monitor.observe(batch, dt, acct.host_time_s)
            if acct.attribution is not None:
                self.monitor.observe_operators(acct.attribution.rows)

    def _note_first_token(self, req: Request) -> RequestTiming:
        """Record a request's first emission: its RequestTiming plus the
        TTFT histogram sample."""
        timing = RequestTiming(req.rid, arrival_s=req.arrival_s,
                               first_token_s=self.now)
        timing.token_times_s.append(self.now)
        self.timings[req.rid] = timing
        if self._h_ttft is not None:
            self._h_ttft.observe(max(0.0, self.now - req.arrival_s))
        return timing

    def _note_token(self, timing) -> None:
        """Record a non-first emission: token time plus the ITL sample
        (gap since the request's previous token on the engine clock)."""
        if timing is None:
            return
        if self._h_itl is not None and timing.token_times_s:
            self._h_itl.observe(
                max(0.0, self.now - timing.token_times_s[-1]))
        timing.token_times_s.append(self.now)

    # ------------------------------------------------------------ api
    def admit(self, req: Request) -> bool:
        """Admit one request into a slot and prefill; False = no room.

        Requests whose prompt + decode budget exceed ``max_len`` are
        rejected outright (status ``rejected``) rather than risking
        out-of-bounds KV writes.
        """
        plen = len(req.prompt)
        if plen + req.max_new_tokens > self.T:
            # the full generation cannot fit the KV region: answer with a
            # rejection instead of letting prefill/decode writes clamp or
            # drop out of bounds (silently corrupted attention)
            req.done = True
            req.status = "rejected"
            self.stats.rejected += 1
            self.timings.setdefault(
                req.rid, RequestTiming(req.rid, arrival_s=req.arrival_s))
            if self.tracer is not None:
                self.tracer.reject(req.rid, self.now)
            return True
        if self.cache_mode == "paged":
            return self._admit_paged(req)
        slot = self._free_slot()
        if slot is None:
            return False
        bucket = self._bucket(plen)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt
        t0 = time.perf_counter()
        logits, self.cache = self.backend.prefill(
            self.cache, jnp.asarray(toks), slot, plen)
        acct = self.backend.last
        self._absorb(acct, decode=False)
        first = self._sample(logits[0])
        dt = time.perf_counter() - t0
        t_begin = self.now
        self.now += dt
        req.generated.append(first)
        self.stats.prefills += 1
        self.stats.tokens_out += 1
        timing = self._note_first_token(req)
        if self.tracer is not None:
            self.tracer.admit(req.rid, t_begin)
            self.tracer.prefill(req.rid, t_begin, self.now,
                                tax_s=acct.host_time_s)
            self.tracer.first_token(req.rid, self.now)
        if len(req.generated) >= req.max_new_tokens:
            # single-token budget: done at prefill, never occupies a slot
            req.done = True
            req.status = "done"
            timing.done_s = self.now
            if self.tracer is not None:
                self.tracer.done(req.rid, self.now,
                                 n_tokens=len(req.generated))
        else:
            req.status = "active"
            self.slots[slot] = req
            self.lengths[slot] = plen
            if self.speculative:
                self._draft_prefill_slot(slot, req.prompt)
        if self.telemetry is not None:
            self.telemetry.add(f"prefill[{plen}]", "prefill", t_begin,
                               self.now, rid=req.rid, slot=slot, plen=plen)
            self._record_segments(acct, t_begin)
        return True

    # ------------------------------------------------------------ paged api
    def _admit_paged(self, req: Request) -> bool:
        """Paged-cache admission: allocate blocks, start (chunked)
        prefill, or restore/replay a preempted request's KV."""
        slot = self._free_slot()
        if slot is None:
            return False
        resume = getattr(req, "_resume", None)
        if resume is not None and resume[0] == "host":
            return self._restore_from_host(req, slot, resume[1])
        toks = list(req.prompt)
        replay = False
        if resume is not None:
            # recompute-on-resume: rebuild KV by re-prefilling the prompt
            # plus everything already emitted EXCEPT the last token — that
            # one is the next decode step's input, exactly the state the
            # uninterrupted run would be in (greedy decode then continues
            # byte-identically).  A request preempted mid-prefill has
            # emitted nothing: it re-prefills normally (replay=False) and
            # still gets its first token at completion.
            toks = list(req.prompt) + list(req.generated[:-1])
            replay = len(req.generated) > 0
        req._resume = None
        req.status = "active"
        req._admit_seq = self._admit_seq
        self._admit_seq += 1
        self.slots[slot] = req
        self.lengths[slot] = 0
        # prefix sharing: map the leading full blocks of a donor with the
        # same verified token prefix into this request's table, and start
        # the prefill past them (the skipped tokens' KV already exists) —
        # works for fresh admits AND recompute replays, whose rebuilt KV
        # would be byte-identical to the donor pages anyway
        shared = self._adopt_prefix(req, toks) if self.share_prefix else 0
        self._prefill_tasks[slot] = _PrefillTask(
            req=req, slot=slot, toks=toks, pos=shared, replay=replay)
        if self.tracer is not None:
            self.tracer.admit(req.rid, self.now, resume=resume is not None)
        return True

    # bound on live donor candidates tracked per prefix key
    _DONORS_PER_KEY = 4

    def _register_donor(self, key, rid: int, toks, written: int) -> None:
        """Add/refresh a donor candidate for ``key``.  ``written`` caps how
        many of ``toks`` have fully-written KV blocks (a finished prefill
        covers its whole prompt; an in-flight adopter only its shared
        region)."""
        cands = self._prefix_donors.setdefault(key, [])
        cands[:] = [c for c in cands if c[0] != rid]
        cands.insert(0, (rid, tuple(toks), written))
        del cands[self._DONORS_PER_KEY:]

    def _adopt_prefix(self, req: Request, toks: list) -> int:
        """Adopt a donor's leading blocks when its verified token sequence
        shares a block-aligned prefix with ``toks``.  Only FULL blocks
        strictly inside the prompt are shared (the final prompt token must
        be re-written so its logits exist), so normal prefill/decode never
        writes into a shared block — ``_cow_protect`` guards the rest.
        Returns the number of prompt tokens covered by adopted blocks."""
        if len(toks) < self.prefix_len:
            return 0
        key = tuple(toks[:self.prefix_len])
        cands = self._prefix_donors.get(key)
        if not cands:
            return 0
        bs = self.kv.block_size
        shared, live = 0, []
        for drid, dtoks, written in cands:
            if drid == req.rid:
                continue
            dblocks = self.kv.pool.owned(drid)
            if not dblocks:
                continue               # donor drained: prune this candidate
            live.append((drid, dtoks, written))
            if shared:
                continue               # already adopted from a fresher donor
            common = 0
            for a, b in zip(dtoks, toks):
                if a != b:
                    break
                common += 1
            common = min(common, written)
            n = min(min(common, len(toks) - 1) // bs, len(dblocks))
            if n <= 0:
                continue
            self.kv.pool.adopt(req.rid, dblocks[:n])
            self.stats.prefix_adoptions += 1
            self.stats.shared_prefix_tokens += n * bs
            shared = n * bs
        if live:
            self._prefix_donors[key] = live
        else:
            self._prefix_donors.pop(key, None)
        if shared:
            # the adopter itself now holds fully-written shared blocks, so
            # it can donate them even before its own prefill finishes —
            # this keeps sharing chains alive across short donor lifetimes
            self._register_donor(key, req.rid, toks, shared)
        return shared

    def _cow_protect(self, rid, start: int, end: int) -> bool:
        """Copy-on-write guard: before a write into token range
        ``[start, end)``, diverge any covering block that is still shared
        (refcount > 1) — copy the page, swap the private block into the
        owner's table.  False = no free block for the copy; the caller
        stalls exactly like an ``ensure`` shortfall."""
        if not self.share_prefix:
            return True
        pool = self.kv.pool
        ids = pool.owned(rid)
        if not ids:
            return True
        bs = self.kv.block_size
        first = start // bs
        last = min((max(end, start + 1) - 1) // bs, len(ids) - 1)
        for j in range(first, last + 1):
            if pool.ref_count(ids[j]) > 1:
                try:
                    old, new = pool.cow(rid, j)
                except MemoryError:
                    return False
                self.cache = self.kv.copy_pages(self.cache, old, new)
        return True

    def _restore_from_host(self, req: Request, slot: int,
                           entries: int) -> bool:
        """Re-admit an offloaded request by restoring its host-staged
        KV blocks into fresh pool pages; False = pool still too full."""
        n_blocks = self.offload_tier.stored_blocks(req.rid)
        if not self.kv.pool.can_alloc(n_blocks):
            return False                   # wait for blocks to free
        host_leaves, n_blocks, nbytes, tax = \
            self.offload_tier.restore(req.rid)
        ids = self.kv.pool.alloc(req.rid, n_blocks)
        self.cache = self.kv.scatter_host(self.cache, ids, host_leaves)
        self.stats.restore_bytes += nbytes
        self.stats.offload_transfers += max(n_blocks, 1)
        self.stats.modeled_offload_tax_s += tax
        req._resume = None
        req.status = "active"
        req._admit_seq = self._admit_seq
        self._admit_seq += 1
        self.slots[slot] = req
        self.lengths[slot] = entries
        if self.tracer is not None:
            self.tracer.admit(req.rid, self.now, resume=True,
                              restore_bytes=nbytes, restore_tax_s=tax)
        if self.speculative:
            # the TARGET KV came back byte-exact from host memory, but the
            # draft cache was discarded at preemption: rebuild it from the
            # known token sequence (prompt + emitted minus the pending
            # last token — exactly ``entries`` tokens)
            self._draft_prefill_slot(
                slot, list(req.prompt) + list(req.generated[:-1]))
        return True

    def _pick_victim(self, exclude: int) -> Optional[int]:
        """Youngest decode-phase slot (latest admitted): it has the least
        sunk prefill/decode work to lose — vLLM's preemption order.  When
        every other slot is still prefilling, the youngest in-flight
        prefill is the last-resort victim (its partial KV is discarded,
        not offloaded — re-prefilling it is cheap)."""
        decode = [i for i, s in enumerate(self.slots)
                  if s is not None and i != exclude
                  and i not in self._prefill_tasks]
        if decode:
            return max(decode, key=lambda i: self.slots[i]._admit_seq)
        prefills = [i for i in self._prefill_tasks
                    if i != exclude and self.slots[i] is not None]
        if prefills:
            return max(prefills, key=lambda i: self.slots[i]._admit_seq)
        return None

    def _preempt(self, slot: int) -> None:
        """Evict a slot's request: offload its KV to host (or discard
        for recompute-on-resume) and free its blocks."""
        req = self.slots[slot]
        entries = int(self.lengths[slot])
        ids = self.kv.pool.owned(req.rid)
        mid_prefill = self._prefill_tasks.pop(slot, None) is not None
        nbytes, tax = 0, 0.0
        if self.offload_tier is not None and not mid_prefill:
            host = self.kv.gather_host(self.cache, ids)
            nbytes, tax = self.offload_tier.evict(req.rid, host, len(ids))
            self.stats.offload_bytes += nbytes
            self.stats.offload_transfers += max(len(ids), 1)
            self.stats.modeled_offload_tax_s += tax
            req._resume = ("host", entries)
        else:
            req._resume = ("recompute", None)
        if self.tracer is not None:
            self.tracer.preempt(req.rid, self.now, mode=req._resume[0],
                                offload_bytes=nbytes, offload_tax_s=tax)
        freed = self.kv.pool.free(req.rid)
        self.cache = self.kv.zero_pages(self.cache, freed)
        self.slots[slot] = None
        self.lengths[slot] = 0
        req.status = "preempted"
        self._preempted.append(req)
        self.stats.preemptions += 1

    def _ensure_paged_blocks(self, req: Request, n_tokens: int,
                             exclude: int) -> bool:
        """Grow ``req`` to cover ``n_tokens`` KV entries, preempting
        youngest-first victims while the pool is short (evict-or-preempt).
        False = stalled: no victim available, caller retries next step."""
        pool = self.kv.pool
        while (pool.blocks_for(n_tokens) - len(pool.owned(req.rid))
               > pool.free_blocks):
            victim = self._pick_victim(exclude)
            if victim is None:
                return False
            self._preempt(victim)
        pool.ensure(req.rid, n_tokens)
        return True

    def _release_slot(self, slot: int, req: Request) -> None:
        """Free a finished request's slot, blocks, and host staging."""
        self.slots[slot] = None
        self.lengths[slot] = 0
        freed = self.kv.pool.free(req.rid)
        self.cache = self.kv.zero_pages(self.cache, freed)
        if self.offload_tier is not None:
            self.offload_tier.drop(req.rid)

    def _run_prefill_chunk(self, task: _PrefillTask, chunk_len: int) -> None:
        """Write the next ``chunk_len`` prompt tokens of one in-flight
        prefill into the paged cache (one backend call)."""
        toks = np.asarray([task.toks[task.pos:task.pos + chunk_len]],
                          np.int32)
        bt = jnp.asarray(self.kv.table_row(task.req.rid))
        t0c = jnp.asarray(task.pos, jnp.int32)
        t_start = time.perf_counter()
        logits, self.cache = self.backend.prefill_chunk(
            self.cache, jnp.asarray(toks), bt, t0c)
        acct = self.backend.last
        self._absorb(acct, decode=False)
        task.last_logits = logits
        task.pos += chunk_len
        self.stats.prefill_chunks += 1
        dt = time.perf_counter() - t_start
        t_begin = self.now
        self.now += dt
        if self.tracer is not None:
            self.tracer.prefill(task.req.rid, t_begin, self.now,
                                tax_s=acct.host_time_s, replay=task.replay,
                                chunk=chunk_len)
        if self.telemetry is not None:
            self.telemetry.add(f"prefill_chunk[{chunk_len}]", "prefill",
                               t_begin, self.now, rid=task.req.rid,
                               slot=task.slot, pos=task.pos)
            self._record_segments(acct, t_begin)

    def _finish_prefill(self, task: _PrefillTask) -> None:
        """Complete a chunked prefill: emit the first token (or nothing
        on a replay) and move the slot into decode."""
        req, slot = task.req, task.slot
        del self._prefill_tasks[slot]
        self.lengths[slot] = len(task.toks)
        if self.share_prefix and len(task.toks) >= self.prefix_len:
            # the newest finished prefill becomes the freshest donor
            # candidate for its prefix key; its whole prompt is written
            self._register_donor(tuple(task.toks[:self.prefix_len]),
                                 req.rid, task.toks, len(task.toks))
        if task.replay:
            if self.speculative:
                self._draft_prefill_slot(slot, task.toks)
            return          # resumed recompute: KV rebuilt, nothing emitted
        first = self._sample(task.last_logits[0])
        req.generated.append(first)
        self.stats.prefills += 1
        self.stats.tokens_out += 1
        timing = self._note_first_token(req)
        if self.tracer is not None:
            self.tracer.first_token(req.rid, self.now)
        if len(req.generated) >= req.max_new_tokens:
            req.done = True
            req.status = "done"
            timing.done_s = self.now
            self._release_slot(slot, req)
            if self.tracer is not None:
                self.tracer.done(req.rid, self.now,
                                 n_tokens=len(req.generated))
        elif self.speculative:
            self._draft_prefill_slot(slot, task.toks)

    def _advance_prefills(self) -> bool:
        """One chunk of every in-flight prefill, interleaved with decode:
        a long prompt yields the engine back after each chunk instead of
        monopolizing it until its KV is fully built."""
        progressed = False
        for slot in sorted(self._prefill_tasks):
            task = self._prefill_tasks.get(slot)
            if task is None:        # finished earlier in this sweep
                continue
            remaining = len(task.toks) - task.pos
            chunk_len = (remaining if self.prefill_chunk is None
                         else min(self.prefill_chunk, remaining))
            if not self._ensure_paged_blocks(
                    task.req, task.pos + chunk_len, exclude=slot):
                continue            # stalled on blocks; retry next step
            if not self._cow_protect(task.req.rid, task.pos,
                                     task.pos + chunk_len):
                continue            # stalled on a CoW copy block
            self._run_prefill_chunk(task, chunk_len)
            progressed = True
            if task.pos >= len(task.toks):
                self._finish_prefill(task)
        return progressed

    def _paged_decode_step(self) -> bool:
        """One paged decode round: grow block tables (preempting if the
        pool is exhausted), step ready rows, advance chunked prefills.
        Returns False when nothing could progress."""
        active = [i for i, s in enumerate(self.slots)
                  if s is not None and i not in self._prefill_tasks]
        # grow every row's table to cover the entry this step writes;
        # growth may preempt younger rows out of this very step
        stalled = set()
        for i in active:
            if self.slots[i] is None:
                continue
            if not self._ensure_paged_blocks(
                    self.slots[i], int(self.lengths[i]) + 1, exclude=i):
                # no victim right now (in-flight prefills hold the rest):
                # sit this step out — a finishing prefill frees blocks or
                # becomes preemptable next step.  A true deadlock (nothing
                # anywhere can progress) is raised by run().
                stalled.add(i)
            elif not self._cow_protect(self.slots[i].rid,
                                       int(self.lengths[i]),
                                       int(self.lengths[i]) + 1):
                stalled.add(i)
        active = [i for i in active
                  if self.slots[i] is not None and i not in stalled]
        if not active:
            return False
        toks = np.zeros((self.B, 1), np.int32)
        for i in active:
            toks[i, 0] = self.slots[i].generated[-1]
        owners = [self.slots[i].rid
                  if self.slots[i] is not None
                  and i not in self._prefill_tasks else None
                  for i in range(self.B)]
        bt = jnp.asarray(self.kv.block_tables(owners))
        t0 = time.perf_counter()
        logits, self.cache = self.backend.paged_decode(
            self.cache, jnp.asarray(toks), jnp.asarray(self.lengths), bt)
        acct = self.backend.last
        self._absorb(acct, decode=True)
        self.stats.decode_steps += 1
        self.stats.slot_occupancy.append(len(active))
        self.stats.block_pool_utilization.append(self.kv.pool.utilization)
        logits_np = np.asarray(logits)
        dt = time.perf_counter() - t0
        t_begin = self.now
        self.now += dt
        self.stats.step_times_s.append(dt)
        self._note_step(len(active), dt, acct)
        if self.tracer is not None:
            self.tracer.decode([self.slots[i].rid for i in active],
                               t_begin, self.now, tax_s=acct.host_time_s,
                               batch=len(active),
                               modeled_tklqt_s=acct.modeled_tklqt_s)
        if self.telemetry is not None:
            self.telemetry.add(f"decode[b={len(active)}]", "decode",
                               t_begin, self.now, batch=len(active))
            self._record_segments(acct, t_begin)
        for i in active:
            req = self.slots[i]
            self.lengths[i] += 1
            nxt = int(np.argmax(logits_np[i]))
            req.generated.append(nxt)
            self.stats.tokens_out += 1
            timing = self.timings.get(req.rid)
            self._note_token(timing)
            if len(req.generated) >= req.max_new_tokens or \
                    self.lengths[i] >= self.T - 1:
                req.done = True
                req.status = "done"
                if timing is not None:
                    timing.done_s = self.now
                self._release_slot(i, req)
                if self.tracer is not None:
                    self.tracer.done(req.rid, self.now,
                                     n_tokens=len(req.generated))
        return True

    # ------------------------------------------------------------ speculative
    def _draft_prefill_slot(self, slot: int, toks_list) -> None:
        """Build the draft's KV for a slot from the known token sequence
        (bucketed like target prefill; the body zeroes the slot row)."""
        plen = len(toks_list)
        bucket = self._bucket(plen)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = toks_list
        _, self.draft_cache = self.backend.draft_prefill(
            self.draft_cache, jnp.asarray(toks), slot, plen)
        self._absorb(self.backend.last, decode=False)
        self.draft_lengths[slot] = plen

    def _spec_depth(self) -> int:
        """Launch-tax-aware k for this round: deep while the measured
        boundedness says decode is CPU/dispatch-bound at the current
        batch, shallow near the inflection, 0 (plain decode) past it."""
        batch = sum(1 for i, s in enumerate(self.slots)
                    if s is not None and i not in self._prefill_tasks)
        return pick_spec_k(batch, max_k=self.spec_k,
                           inflection_batch=self.spec_inflection)

    def _spec_round(self, k: int, paged: bool) -> bool:
        """One draft-propose / batched-verify round for all decode-ready
        slots.  The draft proposes k tokens autoregressively (k launches on
        its own dispatch stream), the target verifies all k+1 positions in
        ONE batched forward, and the longest draft prefix matching target
        argmax is emitted plus the target's correction token — so every
        emitted token is a target argmax from the true prefix and the
        output stays byte-identical to plain greedy decode."""
        if paged:
            active = [i for i, s in enumerate(self.slots)
                      if s is not None and i not in self._prefill_tasks]
            # grow every row's table to cover the whole verify window
            # (L .. L+k); growth may preempt younger rows out of this round
            stalled = set()
            for i in active:
                if self.slots[i] is None:
                    continue
                want = min(int(self.lengths[i]) + k + 1, self.T)
                if not self._ensure_paged_blocks(self.slots[i], want,
                                                 exclude=i):
                    stalled.add(i)
                elif not self._cow_protect(self.slots[i].rid,
                                           int(self.lengths[i]), want):
                    stalled.add(i)
            active = [i for i in active
                      if self.slots[i] is not None and i not in stalled]
        else:
            active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return False
        t0 = time.perf_counter()
        # --- draft propose: one width-2 right-aligned catch-up step (the
        # draft never saw its own k-th proposal after a fully-accepted
        # window, so it may be one token behind), then k-1 single steps.
        # Padding columns carry position T: the cache write drops and the
        # logits column is ignored.
        cat_toks = np.zeros((self.B, 2), np.int32)
        cat_pos = np.full((self.B, 2), self.T, np.int32)
        for i in active:
            req = self.slots[i]
            L = int(self.lengths[i])
            cat_toks[i, 1] = req.generated[-1]
            cat_pos[i, 1] = L
            if int(self.draft_lengths[i]) == L - 1:
                cat_toks[i, 0] = req.generated[-2]
                cat_pos[i, 0] = L - 1
        draft = np.zeros((self.B, k), np.int64)
        logits_d, self.draft_cache = self.backend.draft_step(
            self.draft_cache, jnp.asarray(cat_toks), jnp.asarray(cat_pos),
            jnp.asarray(self.draft_lengths))
        self._absorb(self.backend.last, decode=True)
        draft[:, 0] = np.argmax(np.asarray(logits_d), axis=-1)
        for i in active:
            self.draft_lengths[i] = int(self.lengths[i]) + 1
        for j in range(1, k):
            toks_j = np.zeros((self.B, 1), np.int32)
            pos_j = np.full((self.B, 1), self.T, np.int32)
            for i in active:
                toks_j[i, 0] = draft[i, j - 1]
                pos_j[i, 0] = self.draft_lengths[i]
            logits_d, self.draft_cache = self.backend.draft_step(
                self.draft_cache, jnp.asarray(toks_j), jnp.asarray(pos_j),
                jnp.asarray(self.draft_lengths))
            self._absorb(self.backend.last, decode=True)
            draft[:, j] = np.argmax(np.asarray(logits_d), axis=-1)
            for i in active:
                self.draft_lengths[i] += 1
        # --- batched verify: the target scores all k+1 positions at once
        ver = np.zeros((self.B, k + 1), np.int32)
        for i in active:
            ver[i, 0] = self.slots[i].generated[-1]
            ver[i, 1:] = draft[i]
        lengths = jnp.asarray(self.lengths)
        if paged:
            owners = [self.slots[i].rid if self.slots[i] is not None
                      and i not in self._prefill_tasks else None
                      for i in range(self.B)]
            bt = jnp.asarray(self.kv.block_tables(owners))
            logits, self.cache = self.backend.paged_verify(
                self.cache, jnp.asarray(ver), lengths, bt)
        else:
            logits, self.cache = self.backend.verify(
                self.cache, jnp.asarray(ver), lengths)
        acct = self.backend.last
        acct.proposed = k * len(active)
        tgt = np.argmax(np.asarray(logits), axis=-1)    # (B, k+1)
        dt = time.perf_counter() - t0
        t_begin = self.now
        self.now += dt
        self.stats.step_times_s.append(dt)
        self._note_step(len(active), dt, acct)
        self.stats.decode_steps += 1
        self.stats.spec_rounds += 1
        self.stats.slot_occupancy.append(len(active))
        if paged:
            self.stats.block_pool_utilization.append(
                self.kv.pool.utilization)
        if self.tracer is not None:
            # one interval covering the whole draft-propose + verify round
            self.tracer.decode([self.slots[i].rid for i in active],
                               t_begin, self.now, tax_s=acct.host_time_s,
                               batch=len(active),
                               modeled_tklqt_s=acct.modeled_tklqt_s)
        if self.telemetry is not None:
            self.telemetry.add(f"spec_verify[b={len(active)},k={k}]",
                               "decode", t_begin, self.now,
                               batch=len(active))
        total_accepted = 0
        for i in active:
            req = self.slots[i]
            L = int(self.lengths[i])
            n_acc = 0
            while n_acc < k and int(draft[i, n_acc]) == int(tgt[i, n_acc]):
                n_acc += 1
            # emit the accepted prefix + the target's correction token,
            # respecting the same budget/length stops as plain decode
            timing = self.timings.get(req.rid)
            Lcur = L
            for j in range(n_acc + 1):
                req.generated.append(int(tgt[i, j]))
                Lcur += 1
                self.stats.tokens_out += 1
                if j < n_acc:
                    total_accepted += 1
                else:
                    self.stats.corrections += 1
                self._note_token(timing)
                if len(req.generated) >= req.max_new_tokens or \
                        Lcur >= self.T - 1:
                    req.done = True
                    break
            self.lengths[i] = Lcur
            # draft rollback is just a length retreat: entries past the
            # accepted prefix are stale, masked by kv_valid until the next
            # window overwrites them
            self.draft_lengths[i] = L + min(n_acc + 1, k)
            if req.done:
                req.status = "done"
                if timing is not None:
                    timing.done_s = self.now
                if self.tracer is not None:
                    self.tracer.done(req.rid, self.now,
                                     n_tokens=len(req.generated))
                if paged:
                    self._release_slot(i, req)
                else:
                    self.slots[i] = None
                    self.lengths[i] = 0
            elif paged:
                # block-table rollback: free + zero the tail blocks grown
                # for rejected verify positions
                freed = self.kv.pool.trim(req.rid, Lcur)
                if freed:
                    self.cache = self.kv.zero_pages(self.cache, freed)
        acct.accepted = total_accepted
        self._absorb(acct, decode=True)
        return True

    def step(self):
        """One decode step for all active slots."""
        if self.cache_mode == "paged":
            progressed = self._advance_prefills()
            k = self._spec_depth() if self.speculative else 0
            if k:
                progressed = self._spec_round(k, paged=True) or progressed
            else:
                progressed = self._paged_decode_step() or progressed
            self._last_step_progressed = progressed
            return
        if self.speculative:
            k = self._spec_depth()
            if k:
                self._spec_round(k, paged=False)
                return
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        toks = np.zeros((self.B, 1), np.int32)
        for i in active:
            toks[i, 0] = self.slots[i].generated[-1]
        t0 = time.perf_counter()
        logits, self.cache = self.backend.decode(
            self.cache, jnp.asarray(toks), jnp.asarray(self.lengths))
        acct = self.backend.last
        self._absorb(acct, decode=True)
        self.stats.decode_steps += 1
        self.stats.slot_occupancy.append(len(active))
        logits_np = np.asarray(logits)
        dt = time.perf_counter() - t0
        t_begin = self.now
        self.now += dt
        self.stats.step_times_s.append(dt)
        self._note_step(len(active), dt, acct)
        if self.tracer is not None:
            self.tracer.decode([self.slots[i].rid for i in active],
                               t_begin, self.now, tax_s=acct.host_time_s,
                               batch=len(active),
                               modeled_tklqt_s=acct.modeled_tklqt_s)
        if self.telemetry is not None:
            self.telemetry.add(f"decode[b={len(active)}]", "decode",
                               t_begin, self.now, batch=len(active))
            self._record_segments(acct, t_begin)
        for i in active:
            req = self.slots[i]
            self.lengths[i] += 1
            nxt = int(np.argmax(logits_np[i]))
            req.generated.append(nxt)
            self.stats.tokens_out += 1
            timing = self.timings.get(req.rid)
            self._note_token(timing)
            if len(req.generated) >= req.max_new_tokens or \
                    self.lengths[i] >= self.T - 1:
                req.done = True
                req.status = "done"
                self.slots[i] = None
                self.lengths[i] = 0
                if timing is not None:
                    timing.done_s = self.now
                if self.tracer is not None:
                    self.tracer.done(req.rid, self.now,
                                     n_tokens=len(req.generated))

    # ------------------------------------------------------------ run loop
    def submit(self, req: Request) -> None:
        """Enqueue one request for admission (open-loop ingress).

        The engine holds it until the virtual clock reaches
        ``req.arrival_s`` AND a slot frees; ``tick()`` drains the queue.
        This is the entry point an external router uses to feed a replica
        incrementally — ``run()`` is submit-everything-then-drain.
        """
        if self.tracer is not None:
            # idempotent: a router-fed replica already minted this trace
            # at fleet ingress; engine-only runs mint it here
            self.tracer.ingress(req.rid, req.arrival_s)
        self._pending.append(req)
        # stable sort: equal arrival times keep submission order, so a
        # router-fed replica admits exactly like run() over the same list
        self._pending.sort(key=lambda r: r.arrival_s)

    @property
    def busy(self) -> bool:
        """True while any work remains: queued, preempted, or in a slot."""
        return bool(self._pending) or bool(self._preempted) or \
            any(s is not None for s in self.slots)

    @property
    def queue_depth(self) -> int:
        """Requests admitted-or-waiting on this engine (pending +
        preempted + active slots) — the router's load signal."""
        return (len(self._pending) + len(self._preempted)
                + sum(1 for s in self.slots if s is not None))

    @property
    def outstanding_tokens(self) -> int:
        """Un-served work in tokens: full prompt+budget for queued
        requests, remaining decode budget for admitted ones.  Routing
        tie-breaker — two replicas with equal request counts can hold
        very different amounts of work."""
        n = sum(len(r.prompt) + r.max_new_tokens for r in self._pending)
        n += sum(r.max_new_tokens - len(r.generated)
                 for r in self._preempted)
        n += sum(s.max_new_tokens - len(s.generated)
                 for s in self.slots if s is not None)
        return n

    def tick(self) -> bool:
        """One scheduling round: fast-forward over idle gaps, admit every
        eligible request (resumed ones first — they hold generation
        progress and possibly offloaded KV), then one ``step()``.

        Returns False (doing nothing) once no work remains.  ``run()`` is
        a tick loop; a fleet router interleaves ticks of many replicas on
        one global clock.
        """
        if not self.busy:
            return False
        idle = not any(s is not None for s in self.slots) \
            and not self._preempted
        if idle and self._pending and \
                self._pending[0].arrival_s > self.now:
            self.now = self._pending[0].arrival_s
        admitted = False
        # resumed requests first: they hold generation progress (and
        # possibly offloaded KV) — finishing them frees blocks fastest
        while self._preempted and self._free_slot() is not None:
            if not self._admit_paged(self._preempted[0]):
                break               # no blocks to restore into yet
            self._preempted.pop(0)
            admitted = True
        while (self._pending and self._pending[0].arrival_s <= self.now
               and self._free_slot() is not None):
            if self.admit(self._pending[0]):
                self._pending.pop(0)
                admitted = True
            else:
                break
        self.step()
        if self.cache_mode == "paged" and not admitted \
                and not self._last_step_progressed \
                and (self._preempted
                     or any(s is not None for s in self.slots)):
            # nothing ran and nothing was admitted: no future step can
            # free blocks either — the pool cannot hold this workload
            raise RuntimeError(
                "paged engine deadlocked: block pool "
                f"({self.kv.num_blocks} x {self.kv.block_size} tokens) "
                "too small for even one in-flight request; raise "
                "num_blocks")
        return True

    def run(self, requests: list[Request]) -> list[Request]:
        """Continuous batching: admit whenever a slot frees.

        Requests with ``arrival_s > 0`` are held until the engine clock
        reaches them (open-loop traffic).  When every slot is idle and the
        next arrival is in the future, the clock fast-forwards to it — the
        idle gap is honored on the virtual timeline without wall-time cost.
        """
        for r in sorted(requests, key=lambda r: r.arrival_s):
            self.submit(r)
        done: list[Request] = []
        while self.tick():
            for r in requests:
                if r.done and r not in done:
                    done.append(r)
        for r in requests:
            if r.done and r not in done:
                done.append(r)
        return done

    def reset(self):
        """Clear serving state (slots, stats, clock, timings) but keep the
        compiled/planned functions — warmup run, reset, measured run."""
        self.cache = jax.tree.map(jnp.zeros_like, self.cache)
        self.lengths = np.zeros(self.B, np.int32)
        self.slots = [None] * self.B
        # fresh registry so the measured run's gauges/histograms don't
        # carry warmup observations; everything instrumented rebinds below
        self.registry = MetricsRegistry()
        self.stats = EngineStats(plan=self.plan_label,
                                 tp=self.backend.info.tp,
                                 registry=self.registry)
        self._dev_base = self.backend.device_dispatches
        self.now = 0.0
        if self.monitor is not None:
            self.monitor.clear()
        if self.speculative:
            self.draft_cache = jax.tree.map(jnp.zeros_like, self.draft_cache)
            self.draft_lengths = np.zeros(self.B, np.int32)
        self._pending = []
        if self.cache_mode == "paged":
            self.kv.reset()
            self._prefill_tasks = {}
            self._preempted = []
            self._admit_seq = 0
            self._prefix_donors = {}
            if self.offload_tier is not None:
                self.offload_tier.clear()
        if self.telemetry is not None:
            self.telemetry.clear()
        self._bind_telemetry()

"""Data-parallel replica fleet: N full ``ServeEngine``s as one tier.

Each replica is an unmodified ``ServeEngine`` over its own execution
backend, so everything the engine composes — launch plans, paged KV with
offload, tensor parallelism, speculative decoding — composes with data
parallelism for free: a fleet of R replicas at ``tp=T`` is the
``(data=R, model=T)`` grid of ``launch.mesh.make_fleet_mesh``.  On a
device pool that actually holds R*T devices the fleet validates that
mesh at construction; on a smaller pool (CPU CI, local runs) replicas
time-multiplex the local devices and the fleet runs as a
byte-deterministic simulation — the routing, queueing, and accounting
behavior is identical either way because the scheduler layer never
touches placement.

The fleet owns replica lifecycle only (create, drain, retire, metrics
aggregation).  Request routing lives in ``repro.inference.router``; the
fleet's job is to make "which replicas exist right now" a safe,
observable question while the router keeps dispatching.

Elastic resizing reuses ``launch.elastic``: ``plan_fleet`` maps a device
pool (minus lost devices) to the largest ``(data, model)`` grid with the
model axis pinned to the serving ``tp``, and ``remove_replica`` drains
rather than kills — admitted requests finish on the draining replica,
un-admitted ones return to the caller for re-dispatch, so elasticity
never loses or corrupts an admitted request.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.inference.engine import EngineStats, Request, ServeEngine
from repro.telemetry.registry import MetricsRegistry

REPLICA_STATES = ("serving", "draining")


@dataclass
class Replica:
    """One fleet member: an engine plus its routing-visible state."""

    rid: int                        # fleet-wide replica id (never reused)
    engine: ServeEngine
    state: str = "serving"          # serving | draining
    requests: list = field(default_factory=list)   # every Request dispatched
    dispatched: int = 0             # lifetime dispatch count

    @property
    def serving(self) -> bool:
        """True while the router may dispatch new requests here."""
        return self.state == "serving"


class ReplicaFleet:
    """Replica lifecycle + fleet-level metrics for one model deployment.

    All replicas share one config and one params pytree (data parallelism
    replicates weights; here they alias the same host arrays), and each
    builds its own backend/cache through the normal ``ServeEngine``
    constructor — ``engine_kwargs`` forwards serving options (plan, cache
    mode, tp, ...) to every replica identically.
    """

    def __init__(self, cfg, params, *, replicas: int, tp: int = 1,
                 registry: MetricsRegistry | None = None,
                 validate_mesh: bool = False, **engine_kwargs):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if tp < 1:
            raise ValueError(f"tp must be >= 1, got {tp}")
        self.cfg = cfg
        self.params = params
        self.tp = tp
        self.engine_kwargs = dict(engine_kwargs)
        self.engine_kwargs["tp"] = tp
        self.mesh = None
        if validate_mesh:
            # the real (data=R, model=T) grid — fails with an actionable
            # message when the device pool cannot hold the fleet
            from repro.launch.mesh import make_fleet_mesh
            self.mesh = make_fleet_mesh(replicas, tp)
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self._g_replicas = self.registry.gauge(
            "fleet_replicas", "live replicas (serving + draining)")
        self._g_state = self.registry.gauge(
            "fleet_replica_state",
            "1 = serving (routable), 0 = draining", labels=("replica",))
        self._c_added = self.registry.counter(
            "fleet_replicas_added_total", "replicas added over the run")
        self._c_retired = self.registry.counter(
            "fleet_replicas_retired_total",
            "drained replicas detached from the fleet")
        self._next_rid = 0
        self.replicas: dict[int, Replica] = {}
        for _ in range(replicas):
            self.add_replica()

    # ------------------------------------------------------------ lifecycle
    def _make_engine(self) -> ServeEngine:
        """One fresh replica engine (own backend, cache, registry)."""
        return ServeEngine(self.cfg, self.params, **self.engine_kwargs)

    def add_replica(self) -> Replica:
        """Attach a new serving replica (fresh engine, next fleet rid)."""
        rep = Replica(rid=self._next_rid, engine=self._make_engine())
        self._next_rid += 1
        self.replicas[rep.rid] = rep
        self._c_added.inc()
        self._note_states()
        return rep

    def remove_replica(self, rid: int) -> list[Request]:
        """Begin draining replica ``rid``; return its un-admitted requests.

        Admitted work (active slots, preempted-with-state) stays on the
        replica until it drains — re-homing it would discard KV or break
        the offload tier's ownership — so no admitted request is ever
        lost.  Queued-but-unadmitted requests are handed back for the
        router to re-dispatch.  The last serving replica cannot be
        removed (the fleet would deadlock with traffic still queued).
        """
        rep = self.replicas.get(rid)
        if rep is None or rep.state != "serving":
            raise ValueError(f"replica {rid} is not serving "
                             f"(live: {sorted(self.replicas)})")
        if len(self.serving()) <= 1:
            raise ValueError(
                "cannot remove the last serving replica; add_replica() "
                "first or drain traffic")
        rep.state = "draining"
        requeue = list(rep.engine._pending)
        rep.engine._pending.clear()
        for r in requeue:
            rep.requests.remove(r)
        rep.dispatched -= len(requeue)
        self._note_states()
        return requeue

    def reap(self) -> list[int]:
        """Retire every drained replica; return the retired rids."""
        retired = [rid for rid, rep in self.replicas.items()
                   if rep.state == "draining" and not rep.engine.busy]
        for rid in retired:
            del self.replicas[rid]
            self._c_retired.inc()
        if retired:
            self._note_states()
        return retired

    def _note_states(self) -> None:
        """Refresh the replica-count and per-replica state gauges."""
        self._g_replicas.set(len(self.replicas))
        for rep in self.replicas.values():
            self._g_state.set(1.0 if rep.serving else 0.0,
                              replica=rep.rid)

    # ------------------------------------------------------------ views
    def serving(self) -> list[Replica]:
        """Replicas the router may dispatch to, in rid order."""
        return [self.replicas[r] for r in sorted(self.replicas)
                if self.replicas[r].serving]

    def live(self) -> list[Replica]:
        """Every attached replica (serving + draining), in rid order."""
        return [self.replicas[r] for r in sorted(self.replicas)]

    def busy(self) -> list[Replica]:
        """Live replicas that still hold work, in rid order."""
        return [rep for rep in self.live() if rep.engine.busy]

    # ------------------------------------------------------------ metrics
    def aggregate_metrics(self) -> MetricsRegistry:
        """Fleet-labeled registry view of every replica's EngineStats.

        Each ``engine_*`` scalar family becomes a ``fleet_engine_*``
        gauge with a ``replica`` label (one series per live replica), so
        one snapshot answers both "what did replica 2 do" and — summing
        the series — "what did the fleet do".  Router/fleet lifecycle
        families already live in ``self.registry`` and are merged in.
        """
        agg = MetricsRegistry()
        for attr, (name, _, help_text) in EngineStats._SCALARS.items():
            fam = agg.gauge(f"fleet_{name}", help_text,
                            labels=("replica",))
            for rep in self.live():
                fam.set(getattr(rep.engine.stats, attr), replica=rep.rid)
        g = agg.gauge("fleet_replica_queue_depth",
                      "requests pending+preempted+active per replica",
                      labels=("replica",))
        for rep in self.live():
            g.set(rep.engine.queue_depth, replica=rep.rid)
        g = agg.gauge("fleet_replica_clock_seconds",
                      "virtual serving clock per replica",
                      labels=("replica",))
        for rep in self.live():
            g.set(rep.engine.now, replica=rep.rid)
        # lifecycle + router families recorded live in self.registry
        snap = self.registry.snapshot()
        for name, fam in snap.items():
            if fam["type"] == "histogram":
                f = agg.histogram(name, fam["help"],
                                  labels=tuple(fam["label_names"]),
                                  buckets=tuple(fam["buckets"]))
                for s in fam["series"]:
                    v = s["value"]
                    f.merge_series(v["count"], v["sum"], v["buckets"],
                                   **s["labels"])
                continue
            dst = {"counter": agg.counter, "gauge": agg.gauge}.get(
                fam["type"])
            if dst is None:
                continue
            f = dst(name, fam["help"], labels=tuple(fam["label_names"]))
            for s in fam["series"]:
                if fam["type"] == "counter":
                    f.inc(s["value"], **s["labels"])
                else:
                    f.set(s["value"], **s["labels"])
        return agg

    def snapshot(self) -> dict:
        """Fleet snapshot: aggregated families + full per-replica dumps."""
        return {
            "fleet": self.aggregate_metrics().snapshot(),
            "replicas": {str(rep.rid): rep.engine.registry.snapshot()
                         for rep in self.live()},
        }

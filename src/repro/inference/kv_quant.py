"""Int8 KV-cache quantization (decode is KV-streaming-bound: int8 halves
both cache residency and the per-step read traffic, which the
memory-pressure sweep shows is what caps admission under load).

Per-(token, head) symmetric quantization: a K/V row (hd,) becomes an int8
payload plus one f32 scale, so a cached entry costs ``hd + 4`` bytes
instead of ``2 * hd`` (bf16) — a ~1.88x capacity gain at hd=64.
Dequantization happens at load time, inside the paged Pallas decode
kernel (``kernels.decode_attention``) and the pure-XLA paged branch
(``layers.attention._paged_attention_fwd``); the bf16 intermediate never
lives in the cache.  Accuracy is tolerance-bounded vs the bf16 paged
path in tests (round-trip error <= scale/2 per element).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

KV_DTYPES = ("bf16", "int8")


def kv_entry_bytes(hd: int, kv_dtype: str = "bf16") -> int:
    """Cache bytes per (token, head) entry: int8 payload + f32 scale vs
    bf16 payload."""
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, got {kv_dtype!r}")
    return hd + 4 if kv_dtype == "int8" else 2 * hd


def capacity_ratio(hd: int) -> float:
    """How many int8 entries fit in the bytes of one bf16 entry
    (2*hd / (hd+4) — ~1.88x at hd=64)."""
    return kv_entry_bytes(hd, "bf16") / kv_entry_bytes(hd, "int8")


def quantize_kv(x):
    """x: (..., hd) -> (int8 payload, f32 scale (...,))."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def make_quantized_cache(batch: int, max_len: int, n_kv: int, hd: int):
    return {
        "k": jnp.zeros((batch, max_len, n_kv, hd), jnp.int8),
        "v": jnp.zeros((batch, max_len, n_kv, hd), jnp.int8),
        "k_scale": jnp.zeros((batch, max_len, n_kv), jnp.float32),
        "v_scale": jnp.zeros((batch, max_len, n_kv), jnp.float32),
    }


def write_kv(cache: dict, k, v, index):
    """Append k/v (B,S,H,hd) at position `index` (scalar)."""
    qk, sk = quantize_kv(k)
    qv, sv = quantize_kv(v)
    upd = jax.lax.dynamic_update_slice_in_dim
    return {
        "k": upd(cache["k"], qk, index, 1),
        "v": upd(cache["v"], qv, index, 1),
        "k_scale": upd(cache["k_scale"], sk, index, 1),
        "v_scale": upd(cache["v_scale"], sv, index, 1),
    }


def read_kv(cache: dict, dtype=jnp.bfloat16):
    return (dequantize_kv(cache["k"], cache["k_scale"], dtype),
            dequantize_kv(cache["v"], cache["v_scale"], dtype))

"""Int8 KV-cache quantization — the next decode lever identified in
EXPERIMENTS.md §Perf-3 (decode is KV-streaming-bound; int8 halves both
cache residency and read traffic).

Per-(token, head) symmetric quantization: k row (hd,) -> int8 + one f32
scale.  Dequantization fuses into the attention load on TPU; the accuracy
cost is well inside decode tolerances (validated in tests vs bf16 cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_kv(x):
    """x: (..., hd) -> (int8 payload, f32 scale (...,))."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def make_quantized_cache(batch: int, max_len: int, n_kv: int, hd: int):
    return {
        "k": jnp.zeros((batch, max_len, n_kv, hd), jnp.int8),
        "v": jnp.zeros((batch, max_len, n_kv, hd), jnp.int8),
        "k_scale": jnp.zeros((batch, max_len, n_kv), jnp.float32),
        "v_scale": jnp.zeros((batch, max_len, n_kv), jnp.float32),
    }


def write_kv(cache: dict, k, v, index):
    """Append k/v (B,S,H,hd) at position `index` (scalar)."""
    qk, sk = quantize_kv(k)
    qv, sv = quantize_kv(v)
    upd = jax.lax.dynamic_update_slice_in_dim
    return {
        "k": upd(cache["k"], qk, index, 1),
        "v": upd(cache["v"], qv, index, 1),
        "k_scale": upd(cache["k_scale"], sk, index, 1),
        "v_scale": upd(cache["v_scale"], sv, index, 1),
    }


def read_kv(cache: dict, dtype=jnp.bfloat16):
    return (dequantize_kv(cache["k"], cache["k_scale"], dtype),
            dequantize_kv(cache["v"], cache["v_scale"], dtype))

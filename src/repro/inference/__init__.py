"""Serving tier: scheduler (`engine`), execution backends, replica fleet
(`fleet`/`router`), and the KV quantization math (`kv_quant`).

`kv_quant` is imported eagerly (it only needs jax); the heavyweight
serving classes are re-exported lazily so `import repro.inference` stays
cheap and cycle-free for the layers that consume the quant helpers.
"""
from __future__ import annotations

from repro.inference import kv_quant
from repro.inference.kv_quant import (
    KV_DTYPES,
    capacity_ratio,
    dequantize_kv,
    kv_entry_bytes,
    quantize_kv,
)

__all__ = [
    "KV_DTYPES",
    "capacity_ratio",
    "dequantize_kv",
    "kv_entry_bytes",
    "kv_quant",
    "quantize_kv",
    "Request",
    "ServeEngine",
    "ReplicaFleet",
    "RequestRouter",
]

_LAZY = {
    "Request": ("repro.inference.engine", "Request"),
    "ServeEngine": ("repro.inference.engine", "ServeEngine"),
    "ReplicaFleet": ("repro.inference.fleet", "ReplicaFleet"),
    "RequestRouter": ("repro.inference.router", "RequestRouter"),
}


def __getattr__(name: str):
    """Lazy re-export of the serving surface (PEP 562)."""
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod_name), attr)

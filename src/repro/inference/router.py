"""Async request router: one ingress queue over a replica fleet.

The router is the fleet's frontend: workload-generated requests enter a
central admission queue, a pluggable policy picks a serving replica for
each, and per-token output streams back through ``on_token`` as replicas
emit.  Execution is a deterministic discrete-event loop over the fleet's
virtual clocks ("async" in the event-driven sense — cooperative progress
over many replicas, no wall-clock sleeps, no thread nondeterminism):
each round the router releases arrivals that are due, dispatches the
queue, then ticks the busy replica whose clock lags furthest behind, so
replica timelines advance in lock-step exactly as a real async frontend
would interleave them.

Because each replica is an unmodified ``ServeEngine`` and greedy tokens
are batch-composition-independent, every request's output is
byte-identical to serving the same request on a lone engine — the router
changes who serves and when, never what is served.  That is the fleet's
correctness bar and ``tests/test_router.py`` locks it.

Routing policies (``make_policy``):

  round-robin        cycle over serving replicas in rid order
  least-queue-depth  fewest queued+active requests; outstanding-token
                     tie-break (two equal-depth replicas can hold very
                     different amounts of work)
  prefix-affinity    requests sharing a prompt prefix stick to the
                     replica that saw the prefix first (KV/prefix-cache
                     locality); unseen prefixes fall back to
                     least-queue-depth
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from repro.inference.engine import Request
from repro.inference.fleet import Replica, ReplicaFleet

POLICIES = ("round-robin", "least-queue-depth", "prefix-affinity")


def _least_loaded(replicas: list[Replica]) -> Replica:
    """Lowest (queue depth, outstanding tokens, rid) serving replica."""
    return min(replicas, key=lambda rep: (rep.engine.queue_depth,
                                          rep.engine.outstanding_tokens,
                                          rep.rid))


class RoundRobinPolicy:
    """Cycle over serving replicas in rid order, load-blind."""

    name = "round-robin"

    def __init__(self):
        self._turn = 0

    def choose(self, req: Request, replicas: list[Replica]) -> Replica:
        """Next replica in the cycle (rid order, wrapping)."""
        rep = replicas[self._turn % len(replicas)]
        self._turn += 1
        return rep


class LeastQueueDepthPolicy:
    """Route to the replica with the fewest outstanding requests.

    Queue depth counts pending + preempted + active requests on the
    replica's engine; ties break on outstanding tokens (remaining prompt
    + decode budget), then rid — so a burst of equal-depth replicas
    still balances by actual work, not arrival parity.
    """

    name = "least-queue-depth"

    def choose(self, req: Request, replicas: list[Replica]) -> Replica:
        """The least-loaded serving replica right now."""
        return _least_loaded(replicas)


class PrefixAffinityPolicy:
    """Sticky routing by prompt prefix (cache-locality routing).

    Requests whose first ``prefix_len`` prompt tokens match are sent to
    the replica that first served that prefix — the replica whose KV
    pages / prefix cache already hold the shared context.  Unseen
    prefixes, and prefixes whose home replica has drained away, fall
    back to least-queue-depth and re-home the prefix there.
    """

    name = "prefix-affinity"

    def __init__(self, prefix_len: int = 8):
        if prefix_len < 1:
            raise ValueError(f"prefix_len must be >= 1, got {prefix_len}")
        self.prefix_len = prefix_len
        self._home: dict[tuple, int] = {}

    def choose(self, req: Request, replicas: list[Replica]) -> Replica:
        """The prefix's home replica, (re)assigned least-loaded-first."""
        key = tuple(req.prompt[:self.prefix_len])
        by_rid = {rep.rid: rep for rep in replicas}
        home = self._home.get(key)
        if home in by_rid:
            return by_rid[home]
        rep = _least_loaded(replicas)
        self._home[key] = rep.rid
        return rep


def make_policy(name: str, **kwargs):
    """Policy instance for a ``POLICIES`` name (kwargs reach __init__)."""
    table = {"round-robin": RoundRobinPolicy,
             "least-queue-depth": LeastQueueDepthPolicy,
             "prefix-affinity": PrefixAffinityPolicy}
    try:
        return table[name](**kwargs)
    except KeyError:
        raise ValueError(f"unknown routing policy {name!r}; "
                         f"expected one of {POLICIES}") from None


@dataclass
class TokenEvent:
    """One streamed token: which request emitted what, where and when."""

    rid: int                        # request id
    replica: int                    # fleet replica rid that emitted it
    index: int                      # position in the request's output
    token: int                      # token id
    t: float                        # emitting replica's virtual clock


@dataclass
class RouterReport:
    """Outcome of one ``RequestRouter.route()`` drain."""

    policy: str
    clock_s: float                  # router clock at drain (makespan)
    completed: list = field(default_factory=list)    # done Requests
    assignment: dict = field(default_factory=dict)   # rid -> replica rid
    token_events: int = 0
    dispatches: int = 0
    requeued: int = 0               # re-dispatched off draining replicas

    @property
    def tokens_by_rid(self) -> dict:
        """Generated token list per request id."""
        return {r.rid: list(r.generated) for r in self.completed}


class RequestRouter:
    """Central admission queue + routing policy over a ``ReplicaFleet``.

    ``route(requests)`` runs the discrete-event loop to drain: release
    due arrivals into the queue, dispatch by policy, tick the
    furthest-behind busy replica, stream newly emitted tokens, retire
    drained replicas.  ``remove_replica``/``add_replica`` may be called
    mid-route (directly or via ``actions``) — dispatch simply stops
    targeting draining replicas and their un-admitted requests re-enter
    the queue at their original arrival order.
    """

    def __init__(self, fleet: ReplicaFleet, policy="least-queue-depth",
                 on_token=None, tracer=None):
        self.fleet = fleet
        self.policy = (make_policy(policy) if isinstance(policy, str)
                       else policy)
        self.on_token = on_token        # callable(TokenEvent) or None
        # request-scoped lifecycle tracer; pass the SAME instance to the
        # fleet (engine_kwargs tracer=) so traces span router + replicas
        self.tracer = tracer
        self.clock = 0.0                # router virtual time (monotonic)
        reg = fleet.registry
        self._g_queue = reg.gauge(
            "router_queue_depth",
            "requests in the central admission queue")
        self._g_clock = reg.gauge(
            "router_clock_seconds", "router virtual clock")
        self._c_dispatch = reg.counter(
            "router_dispatches_total",
            "routing decisions by target replica",
            labels=("replica", "policy"))
        self._c_requeued = reg.counter(
            "router_requeued_total",
            "requests re-dispatched off a draining replica")
        self._c_tokens = reg.counter(
            "router_token_events_total", "tokens streamed through on_token")
        self._c_completed = reg.counter(
            "router_completed_total", "requests finished fleet-wide")
        self._h_queue_wait = reg.histogram(
            "router_queue_wait_seconds",
            "per-request ingress-queue wait: arrival to policy dispatch",
            labels=("replica",))
        self._queue: deque = deque()
        self._emitted: dict[int, int] = {}   # rid -> tokens streamed
        self._watch: dict[int, Replica] = {}  # rid -> emitting replica
        self._report: RouterReport | None = None

    # ------------------------------------------------------------ elasticity
    def add_replica(self) -> int:
        """Attach a fresh serving replica mid-route; returns its rid."""
        return self.fleet.add_replica().rid

    def remove_replica(self, rid: int) -> int:
        """Drain replica ``rid``; its queued requests re-enter the
        router queue (original arrival order).  Returns how many were
        requeued."""
        requeue = self.fleet.remove_replica(rid)
        for req in requeue:
            self._watch.pop(req.rid, None)
        if requeue:
            merged = sorted(list(self._queue) + requeue,
                            key=lambda r: r.arrival_s)
            self._queue = deque(merged)
            self._c_requeued.inc(len(requeue))
            if self._report is not None:
                self._report.requeued += len(requeue)
        return len(requeue)

    # ------------------------------------------------------------ internals
    def _dispatch(self, req: Request) -> Replica:
        """Policy-route one request and submit it to the chosen engine."""
        serving = self.fleet.serving()
        if not serving:
            raise RuntimeError(
                "router has queued traffic but no serving replica; "
                "add_replica() before draining the fleet")
        rep = self.policy.choose(req, serving)
        if self.tracer is not None:
            self.tracer.dispatch(req.rid, self.clock, replica=rep.rid)
        rep.engine.submit(req)
        rep.requests.append(req)
        rep.dispatched += 1
        self._watch[req.rid] = rep
        self._c_dispatch.inc(replica=rep.rid, policy=self.policy.name)
        self._h_queue_wait.observe(max(0.0, self.clock - req.arrival_s),
                                   replica=rep.rid)
        if self._report is not None:
            self._report.assignment[req.rid] = rep.rid
            self._report.dispatches += 1
        return rep

    def _stream(self, rep: Replica) -> None:
        """Emit TokenEvents for tokens ``rep`` produced since last seen."""
        for req in rep.requests:
            seen = self._emitted.get(req.rid, 0)
            n = len(req.generated)
            if n > seen:
                for j in range(seen, n):
                    ev = TokenEvent(rid=req.rid, replica=rep.rid,
                                    index=j, token=int(req.generated[j]),
                                    t=rep.engine.now)
                    if self.on_token is not None:
                        self.on_token(ev)
                self._c_tokens.inc(n - seen)
                self._emitted[req.rid] = n
            if req.done and self._watch.pop(req.rid, None) is not None:
                self._c_completed.inc()
                if self._report is not None:
                    self._report.completed.append(req)

    def _frontier(self) -> float:
        """Lagging edge of fleet progress: min busy-replica clock."""
        busy = self.fleet.busy()
        return min((rep.engine.now for rep in busy),
                   default=self.clock) if busy else self.clock

    # ------------------------------------------------------------ main loop
    def route(self, requests: list[Request], *, actions=None) -> RouterReport:
        """Drain ``requests`` through the fleet; returns a RouterReport.

        ``actions`` is an optional list of ``(dispatch_count, fn)``
        pairs: after the Nth dispatch, ``fn(self)`` runs once — the
        deterministic hook the elastic tests and the CLI's
        ``--remove-at/--add-at`` use to resize the fleet under load.
        """
        arrivals = sorted(requests, key=lambda r: r.arrival_s)
        pending_actions = sorted(actions or [], key=lambda a: a[0])
        self._report = report = RouterReport(policy=self.policy.name,
                                             clock_s=0.0)
        i = 0
        while True:
            self.fleet.reap()
            self.clock = max(self.clock, self._frontier())
            while i < len(arrivals) and \
                    arrivals[i].arrival_s <= self.clock:
                if self.tracer is not None:
                    self.tracer.ingress(arrivals[i].rid,
                                        arrivals[i].arrival_s)
                self._queue.append(arrivals[i])
                i += 1
            busy = self.fleet.busy()
            if not busy and not self._queue:
                if i >= len(arrivals):
                    break               # drained
                # idle fast-forward: jump the router clock to the next
                # arrival instead of spinning (mirrors the engine clock)
                self.clock = arrivals[i].arrival_s
                continue
            while self._queue:
                self._dispatch(self._queue.popleft())
                while pending_actions and \
                        report.dispatches >= pending_actions[0][0]:
                    pending_actions.pop(0)[1](self)
            # tick the busy replica whose virtual clock lags furthest:
            # replica timelines advance in lock-step, so arrivals are
            # released against a consistent global time
            busy = self.fleet.busy()
            if busy:
                rep = min(busy, key=lambda r: (r.engine.now, r.rid))
                rep.engine.tick()
                self._stream(rep)
            self._g_queue.set(len(self._queue))
            self._g_clock.set(self.clock)
        self.fleet.reap()
        self._g_queue.set(0)
        self._g_clock.set(self.clock)
        report.clock_s = self.clock
        report.token_events = int(sum(
            s["value"] for s in
            self.fleet.registry.snapshot()
            ["router_token_events_total"]["series"]))
        self._report = None
        return report

"""Speculative-decoding policy: greedy accept/reject + launch-tax-aware depth.

The device-free half of speculation.  ``greedy_accept`` is the scheduler's
accept rule — longest draft prefix matching target argmax, then the target's
own correction token — which keeps emitted tokens byte-identical to plain
greedy decoding no matter how good or bad the draft is: every emitted token
is an argmax the *target* computed from the true prefix.

``pick_spec_k`` is the paper-facing part: speculation trades MORE kernel
launches (the draft's extra dispatch stream) for FEWER sequential target
steps, so it pays off exactly where decode is CPU/dispatch-bound — low
batch, and on coupled (CC) parts up to ~4x larger batches than LC parts.
The policy takes the measured/modeled CPU->GPU-bound inflection batch
(``telemetry.characterize`` / ``core.boundedness``) and goes deep below it,
shallow approaching it, off above it.

Draft construction: the default draft is the TARGET truncated to its first
``n`` superblocks ("layer-skip" self-speculation) — it shares the embedding,
final norm, and unembed, so the vocab matches by construction and the
proposals track the target distribution without any extra training.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np

from repro.configs.base import ModelConfig


# --------------------------------------------------------------- accept rule
def greedy_accept(draft_tokens: Sequence[int],
                  target_argmax: Sequence[int]) -> tuple[int, list]:
    """Longest-prefix accept against target argmax.

    ``draft_tokens``: the k proposed tokens.  ``target_argmax``: k+1 argmax
    rows from the batched verify — position j is the target's next token
    after the true prefix plus draft_tokens[:j].  Returns ``(n_accepted,
    emitted)`` where ``emitted`` is the accepted prefix plus the target's
    correction token (the argmax right after the last accepted draft token).
    Always emits >= 1 token, and every emitted token equals what sequential
    greedy decoding would produce.
    """
    if len(target_argmax) != len(draft_tokens) + 1:
        raise ValueError(
            f"verify must cover k+1 positions: got {len(draft_tokens)} "
            f"draft tokens but {len(target_argmax)} target rows")
    n = 0
    for d, t in zip(draft_tokens, target_argmax):
        if int(d) != int(t):
            break
        n += 1
    emitted = [int(t) for t in target_argmax[:n]] + [int(target_argmax[n])]
    return n, emitted


def accept_lengths(draft_tokens: np.ndarray,
                   target_argmax: np.ndarray) -> np.ndarray:
    """Vectorized ``greedy_accept`` prefix lengths: (B,k) x (B,k+1) -> (B,)."""
    match = draft_tokens == target_argmax[:, :-1]
    return np.where(match.all(axis=1), match.shape[1],
                    np.argmin(match, axis=1)).astype(np.int64)


# --------------------------------------------------------------- depth policy
def pick_spec_k(batch: int, *, max_k: int,
                inflection_batch: Optional[int] = None) -> int:
    """Launch-tax-aware speculation depth for one scheduler round.

    ``inflection_batch`` is the batch where decode flips from CPU/dispatch-
    bound to GPU/compute-bound (``BoundednessResult.inflection_batch``;
    None = CPU-bound over the whole measured range).  Deep where launches
    dominate (speculation amortizes the per-step launch tax over multiple
    emitted tokens), shallow approaching the inflection (the batched verify
    costs ~(k+1)x decode compute), off where the engine is compute-bound.
    """
    if max_k < 1 or batch < 1:
        return 0
    if inflection_batch is None or 2 * batch <= inflection_batch:
        return max_k                      # deep: launch tax dominates
    if batch < inflection_batch:
        return max(1, max_k // 2)         # shallow: nearing compute-bound
    return 0                              # off: GPU-bound, verify can't pay


# ---------------------------------------------------------- draft construction
def default_draft_config(cfg: ModelConfig) -> ModelConfig:
    """Truncated-target draft: half the superblocks, everything else shared."""
    n_sb = max(1, cfg.n_superblocks // 2)
    return cfg.replace(name=f"{cfg.name}-draft{n_sb}sb",
                       n_layers=n_sb * len(cfg.block_pattern))


def is_truncation_of(draft_cfg: ModelConfig, cfg: ModelConfig) -> bool:
    """True when draft params can be SLICED from the target's stacked blocks
    (same per-layer geometry, fewer superblocks)."""
    return (draft_cfg.block_pattern == cfg.block_pattern
            and draft_cfg.d_model == cfg.d_model
            and draft_cfg.n_heads == cfg.n_heads
            and draft_cfg.n_kv_heads == cfg.n_kv_heads
            and draft_cfg.hd == cfg.hd
            and draft_cfg.d_ff == cfg.d_ff
            and draft_cfg.vocab_size == cfg.vocab_size
            and draft_cfg.n_superblocks <= cfg.n_superblocks)


def draft_params_from_target(params, draft_cfg: ModelConfig):
    """Slice the first ``draft_cfg.n_superblocks`` off the target's stacked
    block params; embedding/final-norm/unembed are shared by reference."""
    out = dict(params)
    out["blocks"] = jax.tree.map(lambda a: a[:draft_cfg.n_superblocks],
                                 params["blocks"])
    return out


def validate_draft(cfg: ModelConfig, draft_cfg: ModelConfig,
                   spec_k: int) -> None:
    """Actionable CLI/engine validation for the speculative options."""
    if spec_k < 1:
        raise ValueError(
            f"spec_k must be >= 1, got {spec_k} (k draft tokens are "
            "proposed per round; use speculative=False to disable)")
    if draft_cfg.vocab_size != cfg.vocab_size:
        raise ValueError(
            f"draft config {draft_cfg.name!r} has vocab_size="
            f"{draft_cfg.vocab_size} but target {cfg.name!r} has "
            f"{cfg.vocab_size}: speculation verifies draft token ids "
            "against target argmax, so draft and target must share the "
            "tokenizer/vocab (pick a truncated/narrower variant of the "
            "same family)")
    if draft_cfg.n_layers >= cfg.n_layers and is_truncation_of(
            draft_cfg, cfg):
        raise ValueError(
            f"draft config {draft_cfg.name!r} ({draft_cfg.n_layers} "
            f"layers) is not smaller than the target ({cfg.n_layers} "
            "layers): a draft at least as deep as the target proposes at "
            "target cost and cannot win the launch trade")

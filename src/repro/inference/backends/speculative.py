"""Speculative execution backend: draft-propose / batched-verify.

Wraps a target backend (``LocalBackend`` or ``ShardedBackend`` — speculation
composes with tensor parallelism) and adds a small draft model that proposes
k tokens autoregressively; the target then verifies all k+1 positions in ONE
batched forward (``verify``/``paged_verify``).  Accept/reject lives in the
scheduler (``repro.inference.speculative.greedy_accept``); this class owns
only the device half: the draft's cache/closures and the accounting of its
extra dispatch stream.

Accounting is the paper tie-in: every draft forward is a host launch that
buys nothing by itself — it only pays off by shrinking the number of
sequential target steps.  Draft launches are counted on their own stream
(``CallAccount.draft_dispatches``) and priced per platform via
``core.device_model.dispatch_fanout_s`` into
``modeled_draft_launch_tax_s``, so the LC-vs-CC launch-tax gap (GH200's
~2-3x costlier per-launch host path, but far wider CPU-bound batch range)
shows up directly in the engine stats and the ``spec_sweep``.

The draft always runs single-device with a contiguous (B, T) cache — its
whole point is to be small — while the target keeps whatever cache mode and
sharding the engine configured.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.device_model import PLATFORMS, dispatch_fanout_s
from repro.inference.backends.base import BackendInfo, CallAccount
from repro.models import forward, make_cache


class SpeculativeBackend:
    """Draft-propose / batched-verify wrapper around a target backend."""

    def __init__(self, target, draft_cfg: ModelConfig, draft_params, *,
                 max_batch: int, max_len: int, platform: str = "TPU-v5e"):
        self.target = target
        self.cfg_draft = draft_cfg
        self.draft_params = draft_params
        self.B = max_batch
        self.T = max_len
        self.platform = platform
        self.spec = PLATFORMS[platform]
        self.info = BackendInfo(
            kind=f"speculative+{target.info.kind}", tp=target.info.tp,
            devices=target.info.devices)
        self.last = CallAccount()
        self._draft_device_dispatches = 0
        self._m_draft_calls = None
        self._m_draft_host = None

        cfg = draft_cfg

        def draft_prefill_body(params, cache, tokens, slot, plen):
            # same zero-then-write slot prefill as bodies.prefill — the
            # draft cache must not leak a previous occupant either
            sub = jax.tree.map(
                lambda c: jnp.zeros_like(
                    jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1)),
                cache)
            logits, _, sub2 = forward(params, tokens, cfg, cache=sub,
                                      cache_index=jnp.zeros((), jnp.int32))
            cache2 = jax.tree.map(
                lambda c, s_: jax.lax.dynamic_update_slice_in_dim(
                    c, s_.astype(c.dtype), slot, axis=1), cache, sub2)
            return logits[:, plen - 1], cache2

        def draft_step_body(params, cache, tokens, positions, lengths):
            # right-aligned multi-token draft step with EXPLICIT per-row
            # positions: the catch-up after a fully-accepted window feeds
            # 2 tokens (the draft never saw its own k-th proposal), normal
            # rounds feed 1; padding columns carry position T (the cache
            # write drops) and their logits are ignored.  Only the last
            # column's logits matter — the next proposal.
            logits, _, cache2 = forward(params, tokens, cfg, cache=cache,
                                        positions=positions, lengths=lengths)
            return logits[:, -1], cache2

        self._draft_prefill = jax.jit(draft_prefill_body,
                                      static_argnames=("plen",))
        self._draft_step = jax.jit(draft_step_body)

    # ------------------------------------------------------------ draft side
    def init_draft_cache(self):
        """Fresh contiguous KV cache for the truncated draft model."""
        return make_cache(self.cfg_draft, self.B, self.T, src_len=1,
                          dtype=self.cfg_draft.cdtype)

    def bind_metrics(self, registry) -> None:
        """Target backend publishes its own families; the draft's extra
        dispatch stream gets its own counters."""
        if hasattr(self.target, "bind_metrics"):
            self.target.bind_metrics(registry)
        self._m_draft_calls = registry.counter(
            "speculative_draft_dispatches_total",
            "launches on the draft model's dispatch stream")
        self._m_draft_host = registry.counter(
            "speculative_draft_host_seconds_total",
            "measured host time of draft forwards")

    def _charge_draft(self, n_calls: int, host_time: float) -> CallAccount:
        """Account draft forwards as their own dispatch stream."""
        # the draft is its own dispatch stream on the target's lead device:
        # launches counted apart from the target stream, priced at one
        # stream's host cost (dispatch_fanout_s at tp=1)
        self.last = CallAccount(
            draft_dispatches=n_calls, host_time_s=host_time,
            modeled_draft_launch_tax_s=n_calls * dispatch_fanout_s(
                self.spec, 1))
        self._draft_device_dispatches += n_calls
        if self._m_draft_calls is not None:
            self._m_draft_calls.inc(n_calls)
            self._m_draft_host.inc(host_time)
        return self.last

    def draft_prefill(self, draft_cache, tokens, slot: int, plen: int):
        """Prefill the draft cache with a slot's prompt."""
        t0 = time.perf_counter()
        logits, draft_cache = self._draft_prefill(
            self.draft_params, draft_cache, tokens, slot, plen)
        self._charge_draft(1, time.perf_counter() - t0)
        return logits, draft_cache

    def draft_step(self, draft_cache, tokens, positions, lengths):
        """One autoregressive draft proposal step."""
        t0 = time.perf_counter()
        logits, draft_cache = self._draft_step(
            self.draft_params, draft_cache, tokens, positions, lengths)
        self._charge_draft(1, time.perf_counter() - t0)
        return logits, draft_cache

    # ---------------------------------------------------- delegated protocol
    def init_contiguous_cache(self):
        """Delegate target-cache construction to the wrapped backend."""
        return self.target.init_contiguous_cache()

    def init_paged_cache(self, kv):
        """Delegate paged-cache construction to the wrapped backend."""
        return self.target.init_paged_cache(kv)

    def _delegate(self, out):
        """Forward a target-backend result, mirroring its account."""
        self.last = self.target.last
        return out

    def prefill(self, cache, tokens, slot: int, plen: int):
        """Target prefill (delegated)."""
        return self._delegate(self.target.prefill(cache, tokens, slot, plen))

    def decode(self, cache, tokens, lengths):
        """Target decode step (delegated)."""
        return self._delegate(self.target.decode(cache, tokens, lengths))

    def prefill_chunk(self, cache, tokens, bt_row, t0):
        """Target paged prompt-chunk write (delegated)."""
        return self._delegate(
            self.target.prefill_chunk(cache, tokens, bt_row, t0))

    def paged_decode(self, cache, tokens, lengths, block_tables):
        """Target paged decode step (delegated)."""
        return self._delegate(
            self.target.paged_decode(cache, tokens, lengths, block_tables))

    def verify(self, cache, tokens, lengths):
        """Target verify of k+1 speculative positions (delegated)."""
        return self._delegate(self.target.verify(cache, tokens, lengths))

    def paged_verify(self, cache, tokens, lengths, block_tables):
        """Target paged verify (delegated)."""
        return self._delegate(
            self.target.paged_verify(cache, tokens, lengths, block_tables))

    # ------------------------------------------------------- accounting
    @property
    def device_dispatches(self) -> dict:
        """Target per-device dispatches with draft launches merged onto
        the lead device's stream."""
        # draft launches land on the target's lead device stream
        merged = dict(self.target.device_dispatches)
        if self._draft_device_dispatches:
            lead = self.info.devices[0] if self.info.devices else 0
            merged[lead] = (merged.get(lead, 0)
                            + self._draft_device_dispatches)
        return merged

    @property
    def planned_decode(self):
        """The wrapped backend's launch-plan decode handle."""
        return self.target.planned_decode

"""The four serving step bodies, shared by every execution backend.

One source of numerics: ``LocalBackend`` jits/plans these directly;
``ShardedBackend`` builds them with its per-device config and a psum
``reduce`` hook and wraps them in shard_map.  The tp=1 vs tp=2
byte-identical-tokens guarantee rests on both backends running THIS
code — keep anything that changes logits or cache writes here.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward


class StepBodies(NamedTuple):
    """Pure step functions: (params, cache, ...) -> (logits_row, cache)."""
    prefill: callable          # contiguous prefill of one slot
    decode: callable           # batched contiguous decode step
    paged_prefill: callable    # one paged prefill chunk
    paged_decode: callable     # batched paged decode step
    verify: callable           # batched multi-token verify (ALL logits rows)
    paged_verify: callable     # same over the paged cache


def make_step_bodies(cfg: ModelConfig, reduce=None) -> StepBodies:
    """Build the step bodies for one (possibly per-device) config.

    ``reduce``: tensor-parallel output hook forwarded to the model
    (psum inside shard_map; None on a single device).  ``unroll=True``
    runs the layer stack as a python loop — the planned modes trace with
    it so the per-layer kernel stream stays visible to proximity mining.
    """

    def prefill_body(params, cache, tokens, slot, plen, unroll=False):
        # tokens: (1, plen_padded); writes slot's KV rows.  The slot's
        # sub-cache is ZEROED first — recurrent states (rwkv/mamba) from
        # a previous occupant must not leak into the new request.
        sub = jax.tree.map(
            lambda c: jnp.zeros_like(
                jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1)),
            cache)
        logits, _, sub2 = forward(params, tokens, cfg, cache=sub,
                                  cache_index=jnp.zeros((), jnp.int32),
                                  unroll=unroll, reduce=reduce)
        cache2 = jax.tree.map(
            lambda c, s_: jax.lax.dynamic_update_slice_in_dim(
                c, s_.astype(c.dtype), slot, axis=1), cache, sub2)
        return logits[:, plen - 1], cache2

    def decode_body(params, cache, tokens, lengths, unroll=False):
        logits, _, cache2 = forward(params, tokens, cfg, cache=cache,
                                    lengths=lengths, unroll=unroll,
                                    reduce=reduce)
        return logits[:, 0], cache2

    def paged_prefill_body(params, cache, tokens, bt_row, t0, unroll=False):
        # tokens: (1, C) one chunk; bt_row: (NB,) the slot's block
        # table; t0: chunk start offset (traced — one compile per
        # chunk LENGTH, not per position)
        logits, _, cache2 = forward(params, tokens, cfg, cache=cache,
                                    cache_index=t0,
                                    block_tables=bt_row[None],
                                    unroll=unroll, reduce=reduce)
        return logits[:, -1], cache2

    def paged_decode_body(params, cache, tokens, lengths, block_tables,
                          unroll=False):
        logits, _, cache2 = forward(params, tokens, cfg, cache=cache,
                                    lengths=lengths,
                                    block_tables=block_tables,
                                    unroll=unroll, reduce=reduce)
        return logits[:, 0], cache2

    def verify_body(params, cache, tokens, lengths, unroll=False):
        # speculative verify: tokens (B, k+1) = last emitted token + k
        # draft tokens.  Row b writes KV at lengths[b] .. lengths[b]+k and
        # ALL k+1 logits rows come back so the scheduler can accept the
        # longest draft prefix matching target argmax — column j is
        # exactly what a sequential decode step would produce after
        # emitting tokens[:j+1], which is what makes speculative output
        # byte-identical to greedy
        logits, _, cache2 = forward(params, tokens, cfg, cache=cache,
                                    lengths=lengths, unroll=unroll,
                                    reduce=reduce)
        return logits, cache2

    def paged_verify_body(params, cache, tokens, lengths, block_tables,
                          unroll=False):
        logits, _, cache2 = forward(params, tokens, cfg, cache=cache,
                                    lengths=lengths,
                                    block_tables=block_tables,
                                    unroll=unroll, reduce=reduce)
        return logits, cache2

    return StepBodies(prefill_body, decode_body, paged_prefill_body,
                      paged_decode_body, verify_body, paged_verify_body)

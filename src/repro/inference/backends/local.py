"""Single-device execution backend — the engine's original device path.

Holds the four step bodies (contiguous prefill/decode, paged chunk/decode)
as plain functions, dispatched either through ``jax.jit`` closures
(``plan="jit"``) or through the launch-plan runtime (every other strategy:
the body is traced once, a ``LaunchPlan`` is chosen, and each call executes
the plan's compiled segments so real dispatch counts and modeled TKLQT are
observable).  This is byte-for-byte the execution logic that used to live
inline in ``ServeEngine``; only the accounting moved into ``CallAccount``.
"""
from __future__ import annotations

import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.inference.backends.base import (AccountingMixin, BackendInfo,
                                           CallAccount)
from repro.inference.backends.bodies import make_step_bodies
from repro.models import make_cache


class _PlannedFn:
    """One engine callable routed through the launch-plan runtime.

    Traced and planned lazily on first call (shapes are only known then);
    afterwards every call executes the chosen plan's compiled segments,
    which are shared process-wide via the runtime's segment cache.
    """

    def __init__(self, fn, strategy: str, platform: str,
                 lengths=(2, 4, 8, 16, 32)):
        self.fn = fn
        self.strategy = strategy
        self.platform = platform
        self.lengths = lengths
        self.executor = None
        self.plan = None                # chosen LaunchPlan (after _build)
        self.modeled_tklqt_s = 0.0      # modeled TKLQT of ONE invocation
        self.modeled_events = []        # simulated device timeline, one call
        self.last_host_times = []       # measured per-segment dispatch, last call
        self.segment_ops = ()           # per-segment {op -> kernel count}
        self.attribution = None         # AttributionReport, one invocation

    def _build(self, *args):
        """Trace the body, choose the LaunchPlan for this strategy, and
        compile the per-segment executor (once, on first call)."""
        from repro.core.tracing import trace_fn
        from repro.runtime import LaunchPlan, PlanExecutor, Planner
        trace = trace_fn(self.fn, *args)
        planner = Planner(trace, self.platform)
        n = len(trace.kernels)
        if self.strategy == "eager":
            plan = LaunchPlan.eager(n)
        elif self.strategy == "whole_graph":
            plan = LaunchPlan.whole_graph(n)
        elif self.strategy == "chain":
            plan = planner.compare(
                [planner.chain(L) for L in self.lengths])[0].plan
        elif self.strategy == "auto":
            plan = planner.auto(lengths=self.lengths).plan
        elif self.strategy == "fused":
            plan = planner.fused_rules(lengths=self.lengths)
        else:
            raise ValueError(f"unknown plan strategy {self.strategy!r}")
        self.plan = plan
        self.executor = PlanExecutor(trace, plan)
        self.modeled_tklqt_s = planner.evaluate(plan).tklqt
        from repro.runtime.planner import simulate_plan
        self.modeled_events = simulate_plan(trace.kernels, plan, planner.spec)
        from repro.runtime.plan import segment_label
        self.segment_names = [segment_label(trace.kernels, s)
                              for s in plan.segments]
        # operator->kernel attribution of ONE call: per-segment op maps
        # plus the modeled timeline split across issuing operators —
        # computed once here, constant for every later invocation
        from repro.telemetry.attribution import attribute_events
        self.segment_ops = tuple(self.executor.segment_operators())
        self.attribution = attribute_events(trace.kernels, plan,
                                            self.modeled_events)

    def __call__(self, *args):
        if self.executor is None:
            self._build(*args)
        out, self.last_host_times = self.executor.call_timed(*args)
        return out

    @property
    def n_launches(self) -> int:
        """Host dispatches per invocation (0 before first build)."""
        return self.executor.n_launches if self.executor else 0

    @property
    def rule_names(self) -> list:
        """Fusion-rule names overlaid on the chosen plan."""
        return self.plan.rule_names() if self.plan is not None else []


class LocalBackend(AccountingMixin):
    """Default single-device backend (jit or launch-plan dispatch)."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int,
                 max_len: int, plan: str = "jit",
                 platform: str = "TPU-v5e"):
        self.cfg = cfg
        self.params = params
        self.B = max_batch
        self.T = max_len
        self.plan = plan
        self.platform = platform
        self.info = BackendInfo(kind="local", tp=1, devices=(0,))
        self._init_accounting()
        self._planned_prefill: dict = {}    # (bucket, plen) -> _PlannedFn
        self._planned_decode: Optional[_PlannedFn] = None

        bodies = make_step_bodies(cfg)      # shared numerics (see bodies.py)
        self._prefill = jax.jit(bodies.prefill, static_argnames=("plen",))
        self._decode = jax.jit(bodies.decode)
        self._prefill_paged = jax.jit(bodies.paged_prefill)
        self._decode_paged = jax.jit(bodies.paged_decode)
        self._verify = jax.jit(bodies.verify)
        self._verify_paged = jax.jit(bodies.paged_verify)
        # planned modes trace with unroll=True: the unrolled layer stack
        # gives the periodic kernel stream proximity mining feeds on
        self._prefill_body = bodies.prefill
        self._decode_body = bodies.decode
        self._paged_prefill_body = bodies.paged_prefill
        self._paged_decode_body = bodies.paged_decode

    # ------------------------------------------------------------ caches
    def init_contiguous_cache(self):
        """Fresh per-slot contiguous KV cache on the local device."""
        return make_cache(self.cfg, self.B, self.T, src_len=1,
                          dtype=self.cfg.cdtype)

    def init_paged_cache(self, kv):
        """Fresh pooled KV pages for the paged-cache layout."""
        return kv.make_pages()

    # ------------------------------------------------------------ helpers
    def _planned_account(self, pf: _PlannedFn) -> CallAccount:
        """Charge one launch-plan call: measured per-segment dispatch
        times plus the plan's modeled TKLQT and attribution."""
        return self._charge(CallAccount(
            dispatches=pf.n_launches,
            host_time_s=sum(pf.last_host_times),
            modeled_tklqt_s=pf.modeled_tklqt_s,
            rule_names=tuple(pf.rule_names),
            segment_names=tuple(pf.segment_names),
            segment_host_times=tuple(pf.last_host_times),
            segment_ops=pf.segment_ops,
            attribution=pf.attribution))

    def _jit_account(self, t0: float) -> CallAccount:
        """Charge one jit call: a single dispatch, measured host time."""
        return self._charge(CallAccount(
            dispatches=1, host_time_s=time.perf_counter() - t0))

    # ------------------------------------------------------------ steps
    def prefill(self, cache, tokens, slot: int, plen: int):
        """Write one prompt into a contiguous-cache slot; returns
        (last-position logits, updated cache)."""
        if self.plan == "jit":
            t0 = time.perf_counter()
            logits, cache = self._prefill(self.params, cache, tokens,
                                          slot, plen)
            self._jit_account(t0)
            return logits, cache
        bucket = tokens.shape[1]
        pf = self._planned_prefill.get((bucket, plen))
        if pf is None:
            fn = functools.partial(self._prefill_body, plen=plen,
                                   unroll=True)
            pf = _PlannedFn(fn, self.plan, self.platform)
            self._planned_prefill[(bucket, plen)] = pf
        logits, cache = pf(self.params, cache, tokens,
                           jnp.asarray(slot, jnp.int32))
        self._planned_account(pf)
        return logits, cache

    def decode(self, cache, tokens, lengths):
        """One batched decode step over the contiguous cache."""
        if self.plan == "jit":
            t0 = time.perf_counter()
            logits, cache = self._decode(self.params, cache, tokens, lengths)
            self._jit_account(t0)
            return logits, cache
        if self._planned_decode is None:
            self._planned_decode = _PlannedFn(
                functools.partial(self._decode_body, unroll=True),
                self.plan, self.platform)
        logits, cache = self._planned_decode(self.params, cache, tokens,
                                             lengths)
        self._planned_account(self._planned_decode)
        return logits, cache

    def prefill_chunk(self, cache, tokens, bt_row, t0_index):
        """Write one prompt chunk into paged KV through a block table."""
        if self.plan == "jit":
            t0 = time.perf_counter()
            logits, cache = self._prefill_paged(self.params, cache, tokens,
                                                bt_row, t0_index)
            self._jit_account(t0)
            return logits, cache
        chunk_len = tokens.shape[1]
        pf = self._planned_prefill.get(("paged", chunk_len))
        if pf is None:
            fn = functools.partial(self._paged_prefill_body, unroll=True)
            pf = _PlannedFn(fn, self.plan, self.platform)
            self._planned_prefill[("paged", chunk_len)] = pf
        logits, cache = pf(self.params, cache, tokens, bt_row, t0_index)
        self._planned_account(pf)
        return logits, cache

    def paged_decode(self, cache, tokens, lengths, block_tables):
        """One batched decode step gathering KV through block tables."""
        if self.plan == "jit":
            t0 = time.perf_counter()
            logits, cache = self._decode_paged(self.params, cache, tokens,
                                               lengths, block_tables)
            self._jit_account(t0)
            return logits, cache
        if self._planned_decode is None:
            self._planned_decode = _PlannedFn(
                functools.partial(self._paged_decode_body, unroll=True),
                self.plan, self.platform)
        logits, cache = self._planned_decode(self.params, cache, tokens,
                                             lengths, block_tables)
        self._planned_account(self._planned_decode)
        return logits, cache

    def verify(self, cache, tokens, lengths):
        """Speculative verify: score k+1 positions in one forward."""
        # speculative verify is jit-dispatched in every plan mode: the
        # launch-plan runtime replays fixed single-token streams, and the
        # draft/verify launch trade is priced by Planner(draft_launches=)
        # / telemetry.characterize.spec_sweep instead
        t0 = time.perf_counter()
        logits, cache = self._verify(self.params, cache, tokens, lengths)
        self._jit_account(t0)
        return logits, cache

    def paged_verify(self, cache, tokens, lengths, block_tables):
        """Paged-cache variant of ``verify``."""
        t0 = time.perf_counter()
        logits, cache = self._verify_paged(self.params, cache, tokens,
                                           lengths, block_tables)
        self._jit_account(t0)
        return logits, cache

    # ------------------------------------------------------- accounting
    @property
    def planned_decode(self) -> Optional[_PlannedFn]:
        """The decode ``_PlannedFn`` in launch-plan modes (else None)."""
        return self._planned_decode

"""Tensor-parallel sharded execution backend (Megatron-style, shard_map).

Params are sharded over a ``(data=1, model=tp)`` host mesh with the
existing sharding-rule engine (``distributed.sharding.param_specs`` /
``cache_specs`` / ``paged_cache_specs`` + ``launch.mesh.make_host_mesh``):
wq/wk/wv column-sharded by head, wo row-sharded, MLP d_ff split, KV caches
(contiguous and paged) head-sharded.  The prefill/decode bodies run under
``shard_map`` (via the version shims in ``distributed.compat``) with a
PER-DEVICE config — ``n_heads/tp`` local heads — and the model's
``reduce`` hook psums the partial attention/MLP outputs over the model
axis.  Embeddings and the LM head stay replicated, so every device holds
identical activations between blocks and the greedy tokens are the same
ones the single-device ``LocalBackend`` emits.

Accounting is the point: each step is ONE executable but ``tp`` device
dispatch streams (``CallAccount.dispatches = tp`` — the per-device launch
multiplication of Chung et al.), and every psum the body issues is
captured AT TRACE TIME (name + payload bytes) then priced over the
platform's coupling link via ``core.device_model.allreduce_cost_s`` — the
LC/PCIe vs CC/NVLink-C2C axis applied to tensor-parallel serving.

Runs on CPU CI: ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
simulates the device pool (``make_host_mesh`` validates and says exactly
that when devices are short).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.device_model import PLATFORMS, allreduce_cost_s
from repro.distributed.compat import shard_map
from repro.distributed.sharding import (cache_specs, paged_cache_specs,
                                        param_specs, shardings_for)
from repro.inference.backends.base import (AccountingMixin, BackendInfo,
                                           CallAccount)
from repro.inference.backends.bodies import make_step_bodies
from repro.launch.mesh import make_host_mesh
from repro.models import make_cache

_SUPPORTED_KINDS = ("attn", "attn_local")


def _validate_tp(cfg: ModelConfig, tp: int) -> None:
    """Reject configs the head-sharded shard_map body cannot serve."""
    if tp < 2:
        raise ValueError(f"ShardedBackend needs tp >= 2, got {tp} "
                         "(use LocalBackend for single-device serving)")
    bad = [k for k in cfg.block_pattern if k not in _SUPPORTED_KINDS]
    if bad or cfg.moe_slots or cfg.n_encoder_layers:
        raise ValueError(
            f"ShardedBackend supports pure-attention decoder stacks; "
            f"{cfg.name} has block kinds {bad or cfg.block_pattern}, "
            f"moe_slots={cfg.moe_slots}, "
            f"n_encoder_layers={cfg.n_encoder_layers}")
    for dim, val in (("n_heads", cfg.n_heads),
                     ("n_kv_heads", cfg.n_kv_heads),
                     ("d_ff", cfg.d_ff)):
        if val % tp:
            raise ValueError(
                f"tp={tp} must divide {dim}={val} for {cfg.name}: the "
                f"shard_map body runs {dim}//tp per device (pick a tp "
                f"from the divisors of {val}, or serve this arch with "
                f"tp=1)")


class ShardedBackend(AccountingMixin):
    """Head-sharded tensor-parallel backend over a host/device mesh."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int,
                 max_len: int, tp: int, platform: str = "TPU-v5e",
                 plan: str = "jit"):
        if plan != "jit":
            raise ValueError(
                f"ShardedBackend executes plan='jit' only (got {plan!r}): "
                "the launch-plan runtime replays single-device kernel "
                "streams and cannot re-dispatch shard_map bodies; "
                "per-device launch pricing for tp>1 comes from "
                "Planner(tp=...) / telemetry.characterize.tp_sweep")
        _validate_tp(cfg, tp)
        self.cfg = cfg
        self.tp = tp
        self.B = max_batch
        self.T = max_len
        self.plan = plan
        self.platform = platform
        self.spec = PLATFORMS[platform]
        # raises the actionable device-count error when the pool is short
        self.mesh = make_host_mesh(data=1, model=tp)
        self.info = BackendInfo(
            kind="sharded", tp=tp,
            devices=tuple(d.id for d in self.mesh.devices.flat))
        self._init_accounting()
        # per-device view: the body reshapes local projections with LOCAL
        # head counts (head_dim pinned — d_model//n_heads_local is wrong)
        self.cfg_local = cfg.replace(n_heads=cfg.n_heads // tp,
                                     n_kv_heads=cfg.n_kv_heads // tp,
                                     head_dim=cfg.hd)
        specs = param_specs(params, cfg, self.mesh, tp="model")
        # embeddings + unembed stay replicated: every device computes the
        # full (tiny at decode) logits row, so out_specs need no gather
        specs = dict(specs)
        specs["embed"] = P(None, None)
        if "lm_head" in specs:
            specs["lm_head"] = P(None, None)
        self.param_spec_tree = specs
        self.params = jax.device_put(
            params, shardings_for(params, specs, self.mesh))
        self._cache_spec_tree = None        # set by init_*_cache
        self._fns: dict = {}                # key -> jitted shard_map fn
        self._profiles: dict = {}           # key -> ((name, bytes), ...)
        self._trace_log: list = []          # filled by reduce() at trace time

        def reduce(name, x):
            # trace-time capture: one entry per psum ISSUED IN THE TRACED
            # BODY (the superblock scan body traces once — scale by
            # n_superblocks at accounting time); x.shape is the local
            # (per-device) payload entering the collective
            self._trace_log.append(
                (name, int(x.size) * x.dtype.itemsize))
            return jax.lax.psum(x, "model")

        self._reduce = reduce
        # IDENTICAL numerics to LocalBackend (bodies.py), instantiated
        # with the per-device config + psum hook — the byte-identical
        # tokens guarantee is structural, not hand-synchronized
        bodies = make_step_bodies(self.cfg_local, reduce=reduce)
        self._prefill_body = bodies.prefill
        self._decode_body = bodies.decode
        self._paged_prefill_body = bodies.paged_prefill
        self._paged_decode_body = bodies.paged_decode
        self._verify_body = bodies.verify
        self._paged_verify_body = bodies.paged_verify

    # ------------------------------------------------------------ caches
    def init_contiguous_cache(self):
        """Head-sharded contiguous KV cache placed on the tp mesh."""
        cache = make_cache(self.cfg, self.B, self.T, src_len=1,
                           dtype=self.cfg.cdtype)
        specs = cache_specs(cache, self.cfg, self.mesh, dp=("data",),
                            tp="model")
        self._cache_spec_tree = specs
        return jax.device_put(cache,
                              shardings_for(cache, specs, self.mesh))

    def init_paged_cache(self, kv):
        """Head-sharded pooled KV pages placed on the tp mesh."""
        pages = kv.make_pages()
        specs = paged_cache_specs(pages, self.cfg, self.mesh, tp="model")
        self._cache_spec_tree = specs
        return jax.device_put(pages,
                              shardings_for(pages, specs, self.mesh))

    # ------------------------------------------------------------ dispatch
    def _wrapped(self, key, body, arg_specs, logits_spec=P(None, None)):
        """jit(shard_map(body)) for one step kind, built lazily once the
        cache spec tree exists (cache structure fixes in_specs)."""
        fn = self._fns.get(key)
        if fn is None:
            if self._cache_spec_tree is None:
                raise RuntimeError(
                    "backend cache not initialized; call "
                    "init_contiguous_cache()/init_paged_cache() first")
            in_specs = (self.param_spec_tree, self._cache_spec_tree,
                        *arg_specs)
            out_specs = (logits_spec, self._cache_spec_tree)
            fn = jax.jit(shard_map(
                body, mesh=self.mesh, in_specs=in_specs,
                out_specs=out_specs, check_vma=False))
            self._fns[key] = fn
        return fn

    def _call(self, key, fn, args):
        """Invoke one sharded step and charge tp dispatch streams plus
        the psum traffic captured at trace time (priced per platform)."""
        mark = len(self._trace_log)
        t0 = time.perf_counter()
        logits, cache = fn(self.params, *args)
        host = time.perf_counter() - t0
        new = self._trace_log[mark:]
        del self._trace_log[mark:]
        if new:
            self._profiles[key] = tuple(new)
        prof = self._profiles.get(key, ())
        # the superblock scan body traces once but runs n_superblocks
        # times: every captured psum fires once per superblock
        n_sb = self.cfg.n_superblocks
        payload = sum(b for _, b in prof) * n_sb
        tax = n_sb * sum(allreduce_cost_s(self.spec, b, self.tp)
                         for _, b in prof)
        self._charge(CallAccount(
            dispatches=self.tp, host_time_s=host,
            collectives=len(prof) * n_sb, collective_bytes=payload,
            modeled_collective_tax_s=tax))
        return logits, cache

    # ------------------------------------------------------------ steps
    def prefill(self, cache, tokens, slot: int, plen: int):
        """Sharded prompt prefill into a contiguous-cache slot."""
        key = ("prefill", tokens.shape[1], plen)
        fn = self._fns.get(key)
        if fn is None:
            def body(params, cache, tokens, slot):
                return self._prefill_body(params, cache, tokens, slot, plen)
            fn = self._wrapped(key, body, (P(None, None), P()))
        return self._call(key, fn, (cache, tokens,
                                    jnp.asarray(slot, jnp.int32)))

    def decode(self, cache, tokens, lengths):
        """One sharded batched decode step (contiguous cache)."""
        key = ("decode",)
        fn = self._fns.get(key) or self._wrapped(
            key, self._decode_body, (P(None, None), P(None)))
        return self._call(key, fn, (cache, tokens, lengths))

    def prefill_chunk(self, cache, tokens, bt_row, t0_index):
        """Sharded paged prompt-chunk write through a block table."""
        key = ("prefill_chunk", tokens.shape[1])
        fn = self._fns.get(key) or self._wrapped(
            key, self._paged_prefill_body, (P(None, None), P(None), P()))
        return self._call(key, fn, (cache, tokens, bt_row, t0_index))

    def paged_decode(self, cache, tokens, lengths, block_tables):
        """One sharded batched decode step over paged KV."""
        key = ("paged_decode",)
        fn = self._fns.get(key) or self._wrapped(
            key, self._paged_decode_body,
            (P(None, None), P(None), P(None, None)))
        return self._call(key, fn, (cache, tokens, lengths, block_tables))

    def verify(self, cache, tokens, lengths):
        """Sharded speculative verify (k+1 positions, one forward)."""
        # speculative verify composes with tp: same shard_map body family,
        # replicated (B, k+1, V) logits out (tiny at decode widths)
        key = ("verify",)
        fn = self._fns.get(key) or self._wrapped(
            key, self._verify_body, (P(None, None), P(None)),
            logits_spec=P(None, None, None))
        return self._call(key, fn, (cache, tokens, lengths))

    def paged_verify(self, cache, tokens, lengths, block_tables):
        """Paged-cache variant of sharded ``verify``."""
        key = ("paged_verify",)
        fn = self._fns.get(key) or self._wrapped(
            key, self._paged_verify_body,
            (P(None, None), P(None), P(None, None)),
            logits_spec=P(None, None, None))
        return self._call(key, fn, (cache, tokens, lengths, block_tables))

    # ------------------------------------------------------- accounting
    @property
    def planned_decode(self):
        """Launch-plan decode handle — always None (jit-only backend)."""
        return None

"""Execution-backend protocol: what the scheduler needs from a device path.

The scheduler layer (``repro.inference.engine.ServeEngine``) owns request
lifecycle — slots, admission, chunked prefill, preemption/offload policy,
block tables — and is deliberately device-free: no meshes, no shard_map,
no placement.  Everything that touches devices lives behind this protocol:

  * cache construction (where the KV pytree lives, and how it is sharded)
  * the four step kinds (contiguous prefill/decode, paged chunk/decode)
  * plan/fusion dispatch (the launch-plan runtime) and its accounting

Each call returns ``(logits, cache)`` exactly like the jitted closures the
monolithic engine used, plus fills ``backend.last`` with a ``CallAccount``
the scheduler folds into ``EngineStats`` — one merge path for jit, planned,
and sharded execution instead of three inline copies.

Backends: ``LocalBackend`` (single device, the extracted engine code) and
``ShardedBackend`` (tensor-parallel shard_map over a device mesh).  Future
scale axes — DP replicas, pipeline serving, speculative decoding — are new
backends, not engine rewrites.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Protocol, runtime_checkable


@dataclass
class CallAccount:
    """Dispatch/collective accounting for ONE backend call.

    ``dispatches`` counts host launch events summed over per-device
    dispatch streams (a tp=4 jit step is 1 executable but 4 streams), so
    ``EngineStats.decode_dispatches`` keeps the paper's per-device launch
    semantics as tensor parallelism grows.
    """
    dispatches: int = 0             # host launches, summed over device streams
    host_time_s: float = 0.0        # measured host dispatch time of this call
    modeled_tklqt_s: float = 0.0    # modeled TKLQT (planned modes; 0 for jit)
    rule_names: tuple = ()          # fusion rules that fired (planned modes)
    segment_names: tuple = ()       # per-segment labels (telemetry spans)
    segment_host_times: tuple = ()  # measured per-segment host dispatch
    collectives: int = 0            # collective ops issued (psum count)
    collective_bytes: int = 0       # payload bytes entering collectives
    modeled_collective_tax_s: float = 0.0  # priced over the platform link
    # --- speculative decoding (SpeculativeBackend; zero everywhere else)
    proposed: int = 0               # draft tokens offered to this verify
    accepted: int = 0               # draft tokens that matched target argmax
    draft_dispatches: int = 0       # launches on the draft's dispatch stream
    modeled_draft_launch_tax_s: float = 0.0  # draft stream priced per platform
    # --- operator->kernel attribution (planned modes; None/() for jit)
    segment_ops: tuple = ()         # per-segment {op -> kernel count} maps
    attribution: object = None      # telemetry AttributionReport for ONE call


@dataclass
class BackendInfo:
    """Static facts the scheduler surfaces in stats/reports."""
    kind: str                       # "local" | "sharded" | ...
    tp: int = 1                     # tensor-parallel degree (device streams)
    devices: tuple = ()             # device ids backing this backend


@runtime_checkable
class ExecutionBackend(Protocol):
    """Device-side half of the serving engine.

    All methods are functional over the cache pytree: take it, return the
    updated one.  ``last`` holds the accounting of the most recent call.
    """

    info: BackendInfo
    last: CallAccount

    # ------------------------------------------------------------ caches
    def init_contiguous_cache(self):
        """Fresh per-slot KV cache pytree, placed for this backend."""
        ...

    def init_paged_cache(self, kv):
        """Fresh pages pytree for a ``PagedKVCache`` geometry, placed."""
        ...

    # ------------------------------------------------------------ steps
    def prefill(self, cache, tokens, slot: int, plen: int):
        """Contiguous prefill of one slot; tokens (1, bucket) padded."""
        ...

    def decode(self, cache, tokens, lengths):
        """One batched contiguous decode step; tokens (B, 1)."""
        ...

    def prefill_chunk(self, cache, tokens, bt_row, t0):
        """One paged prefill chunk; tokens (1, C), bt_row (NB,)."""
        ...

    def paged_decode(self, cache, tokens, lengths, block_tables):
        """One batched paged decode step."""
        ...

    def verify(self, cache, tokens, lengths):
        """Batched multi-token verify; tokens (B, k+1), ALL logits back."""
        ...

    def paged_verify(self, cache, tokens, lengths, block_tables):
        """Same over the paged cache."""
        ...

    # ------------------------------------------------------- accounting
    @property
    def device_dispatches(self) -> dict:
        """Cumulative launches per device stream (device index -> count)."""
        ...

    @property
    def planned_decode(self) -> Optional[object]:
        """The decode ``_PlannedFn`` when a launch-plan mode is active
        (telemetry exports its modeled device events); None otherwise."""
        ...


class AccountingMixin:
    """Shared per-device dispatch bookkeeping for concrete backends.

    Concrete ``__init__`` must set ``self.info`` and call
    ``self._init_accounting()``.
    """

    def _init_accounting(self) -> None:
        """Zero the per-call account and per-device dispatch map."""
        self.last = CallAccount()
        self._device_dispatches: dict = {}
        self._m_calls = None
        self._m_dispatches = None
        self._m_host = None
        self._m_coll_bytes = None

    def bind_metrics(self, registry) -> None:
        """Publish per-call accounting into a ``MetricsRegistry``; idempotent
        (families are get-or-create) and cheap per call (counter adds)."""
        kind = self.info.kind
        self._m_calls = registry.counter(
            "backend_calls_total", "backend step calls",
            labels=("backend",))
        self._m_dispatches = registry.counter(
            "backend_dispatches_total",
            "host launches summed over device streams", labels=("backend",))
        self._m_host = registry.counter(
            "backend_host_seconds_total",
            "measured host dispatch time", labels=("backend",))
        self._m_coll_bytes = registry.counter(
            "backend_collective_bytes_total",
            "payload bytes entering collectives", labels=("backend",))
        self._m_kind = kind

    def _charge(self, acct: CallAccount) -> CallAccount:
        """Record ``acct`` as the last call and fold per-device counts."""
        self.last = acct
        per_dev = acct.dispatches // max(self.info.tp, 1)
        for d in range(self.info.tp):
            key = self.info.devices[d] if d < len(self.info.devices) else d
            self._device_dispatches[key] = (
                self._device_dispatches.get(key, 0) + per_dev)
        if self._m_calls is not None:
            self._m_calls.inc(backend=self._m_kind)
            self._m_dispatches.inc(acct.dispatches, backend=self._m_kind)
            self._m_host.inc(acct.host_time_s, backend=self._m_kind)
            if acct.collective_bytes:
                self._m_coll_bytes.inc(acct.collective_bytes,
                                       backend=self._m_kind)
        return acct

    @property
    def device_dispatches(self) -> dict:
        """Cumulative host dispatches per device stream."""
        return dict(self._device_dispatches)

"""Pluggable execution backends for the serving engine.

The scheduler (``repro.inference.engine.ServeEngine``) is device-free;
everything that places tensors, builds meshes, or dispatches compiled
steps implements the ``ExecutionBackend`` protocol here:

  * ``LocalBackend``   — single device; jit or launch-plan dispatch
  * ``ShardedBackend`` — tensor-parallel shard_map over a device mesh

``make_backend`` picks by tensor-parallel degree.  New scale axes (DP
replicas, pipeline serving, speculative decoding) are new backends.
"""
from repro.inference.backends.base import (  # noqa: F401
    BackendInfo, CallAccount, ExecutionBackend,
)
from repro.inference.backends.local import LocalBackend  # noqa: F401


def make_backend(cfg, params, *, max_batch: int, max_len: int,
                 tp: int = 1, plan: str = "jit",
                 platform: str = "TPU-v5e"):
    """Backend for a tensor-parallel degree: tp=1 local, tp>1 sharded.

    The sharded import is deferred so single-device serving never touches
    mesh/shard_map machinery (and its device-count validation).
    """
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if tp == 1:
        return LocalBackend(cfg, params, max_batch=max_batch,
                            max_len=max_len, plan=plan, platform=platform)
    from repro.inference.backends.sharded import ShardedBackend
    return ShardedBackend(cfg, params, max_batch=max_batch,
                          max_len=max_len, tp=tp, plan=plan,
                          platform=platform)

"""Optimizers: AdamW and Adafactor (factored second moment — the memory-
frugal choice for trillion-param configs like kimi-k2), plus global-norm
clipping and cosine schedule.  Pure pytree transforms, no external deps.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"           # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    # keep the original dtype: a full fp32 copy of trillion-param grads
    # would double the gradient footprint
    return jax.tree.map(lambda x: (x * scale.astype(x.dtype)), grads), g


# ------------------------------------------------------------------ adamw
def adamw_init(params):
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {"step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def adamw_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"step": step, "m": m, "v": v}, \
        {"lr": lr, "grad_norm": gnorm}


# ------------------------------------------------------------------ adafactor
def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params):
    def per_leaf(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"step": jnp.zeros((), jnp.int32),
            "v": jax.tree.map(per_leaf, params,
                              is_leaf=lambda x: isinstance(x, jax.Array))}


def adafactor_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    decay = 1.0 - step.astype(jnp.float32) ** -0.8

    def upd(p, g, s):
        g2 = g * g + 1e-30
        if _factored(p.shape):
            vr = decay * s["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
            vc = decay * s["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
            rfac = (vr / jnp.mean(vr, axis=-1, keepdims=True))[..., None]
            u = g * jax.lax.rsqrt(rfac * vc[..., None, :] + 1e-30)
            ns = {"vr": vr, "vc": vc}
        else:
            v = decay * s["v"] + (1 - decay) * g2
            u = g * jax.lax.rsqrt(v + 1e-30)
            ns = {"v": v}
        # update clipping (RMS <= 1)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return newp, ns

    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_s = treedef.flatten_up_to(state["v"])
    outs = [upd(p, g, s) for p, g, s in zip(leaves_p, leaves_g, leaves_s)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_params, {"step": step, "v": new_v}, \
        {"lr": lr, "grad_norm": gnorm}


# ------------------------------------------------------------------ facade
def opt_init(cfg: OptConfig, params):
    return adafactor_init(params) if cfg.kind == "adafactor" else adamw_init(params)


def opt_update(cfg: OptConfig, grads, state, params):
    if cfg.kind == "adafactor":
        return adafactor_update(cfg, grads, state, params)
    return adamw_update(cfg, grads, state, params)

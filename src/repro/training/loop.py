"""Trainer: checkpointed, fault-tolerant training loop with a straggler
watchdog and exact resume.

Failure story (1000+ node posture):
  * every `ckpt_every` steps an async checkpoint is written (params + opt
    state + step); a SHA-256 manifest catches torn writes;
  * on (re)start, `latest_step` auto-resumes — the deterministic data
    pipeline replays from exactly that step;
  * a per-step wall-time watchdog flags straggling steps (z-score over a
    sliding window) — on multi-host deployments this hook feeds the
    controller that re-slices the mesh (launch/elastic.py);
  * simulated-failure hook `fail_at_step` for tests: raises mid-run after
    the checkpoint, proving the restart path end to end.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import init_params, loss_fn
from repro.training.optim import OptConfig, opt_init, opt_update


@dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    seed: int = 0
    watchdog_window: int = 20
    watchdog_zscore: float = 4.0
    fail_at_step: Optional[int] = None     # test hook: simulated crash


class StragglerWatchdog:
    """Flags steps whose wall time is a z-score outlier vs a sliding window."""

    def __init__(self, window: int = 20, z: float = 4.0):
        self.times = collections.deque(maxlen=window)
        self.z = z
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= 5:
            mu = np.mean(self.times)
            sd = np.std(self.times) + 1e-9
            if (dt - mu) / sd > self.z:
                self.flagged.append((step, dt))
                is_straggler = True
        self.times.append(dt)
        return is_straggler


class Trainer:
    def __init__(self, cfg: ModelConfig, data_cfg: DataConfig,
                 train_cfg: TrainConfig,
                 opt_cfg: Optional[OptConfig] = None,
                 step_fn: Optional[Callable] = None):
        self.cfg = cfg
        self.data_cfg = data_cfg
        self.tc = train_cfg
        self.opt_cfg = opt_cfg or OptConfig(total_steps=train_cfg.steps)
        self.ckpt = CheckpointManager(train_cfg.ckpt_dir)
        self.watchdog = StragglerWatchdog(train_cfg.watchdog_window,
                                          train_cfg.watchdog_zscore)
        self.history: list[dict] = []
        if step_fn is None:
            oc = self.opt_cfg

            @jax.jit
            def step_fn(params, opt_state, batch):
                grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
                (loss, (ce, aux)), grads = grad_fn(params, batch, cfg,
                                                   remat=False)
                p2, o2, m = opt_update(oc, grads, opt_state, params)
                return p2, o2, {"loss": loss, **m}
        self.step_fn = step_fn

    # ------------------------------------------------------------ state
    def init_state(self):
        params = init_params(jax.random.PRNGKey(self.tc.seed), self.cfg)
        opt_state = opt_init(self.opt_cfg, params)
        return params, opt_state, 0

    def restore_or_init(self):
        latest = self.ckpt.latest_step()
        params, opt_state, start = self.init_state()
        if latest is not None:
            tree = {"params": params, "opt": opt_state}
            restored = self.ckpt.restore(latest, tree)
            params, opt_state = restored["params"], restored["opt"]
            start = latest
        return params, opt_state, start

    # ------------------------------------------------------------ run
    def run(self) -> dict:
        params, opt_state, start = self.restore_or_init()
        pipe = Pipeline(self.data_cfg, self.cfg, start_step=start)
        t_wall = time.time()
        step = start
        try:
            for step in range(start, self.tc.steps):
                batch = next(pipe)
                t0 = time.time()
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                straggler = self.watchdog.observe(step, dt)
                rec = {"step": step,
                       "loss": float(metrics["loss"]),
                       "dt": dt, "straggler": straggler}
                self.history.append(rec)
                if (step + 1) % self.tc.ckpt_every == 0 or \
                        step + 1 == self.tc.steps:
                    self.ckpt.save(step + 1,
                                   {"params": params, "opt": opt_state})
                if self.tc.fail_at_step is not None and \
                        step + 1 == self.tc.fail_at_step:
                    self.ckpt.wait()
                    raise RuntimeError(f"simulated failure at {step + 1}")
        finally:
            pipe.close()
            self.ckpt.wait()
        return {"params": params, "opt_state": opt_state,
                "final_step": step + 1,
                "history": self.history,
                "wall_s": time.time() - t_wall,
                "stragglers": self.watchdog.flagged}

"""Workload subsystem: scenario registry, seeded generators, JSONL replay."""
from repro.workload.generator import (  # noqa: F401
    Workload, WorkloadRequest, sample_requests,
)
from repro.workload.scenarios import (  # noqa: F401
    LengthDist, Scenario, get_scenario, list_scenarios, register_scenario,
)
from repro.workload.trace_io import load_workload, save_workload  # noqa: F401

"""Scenario registry: named serving-traffic shapes.

The boundedness story of the paper (and of "Characterizing CPU-Induced
Slowdowns in Multi-GPU LLM Inference") depends on traffic shape: arrival
rate, prompt/output length mix, and burstiness move the CPU/GPU-bound
crossover.  A ``Scenario`` captures one such shape declaratively —
an arrival process plus prompt/output length distributions — and the
registry gives them stable names so a characterization run is fully
described by ``(scenario, seed, n_requests)``.

Arrival processes:

  poisson      open loop, exponential inter-arrivals at ``rate_rps``
  closed       closed loop: all requests available at t=0, concurrency
               is bounded by the engine's slot pool
  bursty       on/off-modulated Poisson: ``burst_s`` of ``rate_rps``
               traffic, then ``idle_s`` of silence, repeating
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

ARRIVALS = ("poisson", "closed", "bursty")


@dataclass(frozen=True)
class LengthDist:
    """Integer length distribution: fixed | uniform | lognormal (clipped)."""
    kind: str                       # fixed | uniform | lognormal
    lo: int                         # fixed value, or clip floor
    hi: Optional[int] = None        # clip ceiling (uniform/lognormal)
    sigma: float = 0.5              # lognormal shape (median = lo..hi midpoint)

    def sample(self, rng) -> int:
        if self.kind == "fixed":
            return int(self.lo)
        if self.kind == "uniform":
            return int(rng.integers(self.lo, self.hi + 1))
        if self.kind == "lognormal":
            median = (self.lo + self.hi) / 2.0
            v = rng.lognormal(0.0, self.sigma) * median
            return int(min(max(round(v), self.lo), self.hi))
        raise ValueError(f"unknown length distribution kind {self.kind!r}")


@dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    arrival: str                    # poisson | closed | bursty
    prompt: LengthDist
    output: LengthDist
    rate_rps: float = 0.0           # poisson/bursty mean arrival rate
    burst_s: float = 0.0            # bursty: length of an on-phase
    idle_s: float = 0.0             # bursty: silence between bursts
    # default latency SLOs for goodput accounting (None = unconstrained);
    # CLI --slo-ttft-ms/--slo-itl-ms override per run
    slo_ttft_s: Optional[float] = None
    slo_itl_s: Optional[float] = None

    def __post_init__(self):
        if self.arrival not in ARRIVALS:
            raise ValueError(f"unknown arrival process {self.arrival!r}; "
                             f"expected one of {ARRIVALS}")
        if self.arrival in ("poisson", "bursty") and not self.rate_rps > 0:
            raise ValueError(f"{self.arrival!r} arrivals need rate_rps > 0, "
                             f"got {self.rate_rps}")
        if self.arrival == "bursty":
            if not self.burst_s > 0:
                raise ValueError("bursty arrivals need burst_s > 0, "
                                 f"got {self.burst_s}")
            if self.idle_s < 0:
                raise ValueError(f"idle_s must be >= 0, got {self.idle_s}")


_SCENARIOS: dict[str, Scenario] = {}


def register_scenario(s: Scenario) -> Scenario:
    _SCENARIOS[s.name] = s
    return s


def get_scenario(name: str) -> Scenario:
    try:
        return _SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"known: {sorted(_SCENARIOS)}") from None


def list_scenarios() -> list[str]:
    return sorted(_SCENARIOS)


# ------------------------------------------------------------ catalog
# Length scales are in tokens and deliberately modest so reduced-model CPU
# runs stay fast; the generator's prompt_cap/output_cap clip them further.
register_scenario(Scenario(
    name="chatbot",
    description="interactive chat: open-loop Poisson arrivals, "
                "medium prompts, medium decode-heavy outputs",
    arrival="poisson", rate_rps=4.0,
    prompt=LengthDist("lognormal", lo=8, hi=64, sigma=0.4),
    output=LengthDist("lognormal", lo=8, hi=48, sigma=0.4),
    slo_ttft_s=0.2, slo_itl_s=0.05,
))
register_scenario(Scenario(
    name="code-completion",
    description="IDE completions: closed loop (editor waits), larger "
                "context prompts, short outputs",
    arrival="closed",
    prompt=LengthDist("lognormal", lo=24, hi=128, sigma=0.3),
    output=LengthDist("uniform", lo=4, hi=16),
    slo_ttft_s=0.5, slo_itl_s=0.05,
))
register_scenario(Scenario(
    name="summarization",
    description="long-prefill summarization: closed loop, long prompts, "
                "short outputs — prefill-dominated",
    arrival="closed",
    prompt=LengthDist("uniform", lo=96, hi=256),
    output=LengthDist("uniform", lo=4, hi=12),
    slo_ttft_s=2.0, slo_itl_s=0.1,
))
register_scenario(Scenario(
    name="agentic",
    description="bursty agent loops: on/off Poisson bursts of tool-call "
                "turns, short prompts and outputs",
    arrival="bursty", rate_rps=8.0, burst_s=1.0, idle_s=3.0,
    prompt=LengthDist("uniform", lo=8, hi=32),
    output=LengthDist("uniform", lo=4, hi=12),
    slo_ttft_s=0.3, slo_itl_s=0.05,
))

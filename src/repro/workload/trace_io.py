"""JSONL workload trace record/replay.

File format: line 1 is a meta header (schema/scenario/seed/...), every
following line is one request.  All lines are canonical JSON
(sorted keys, no whitespace), so ``save(load(path)) == bytes(path)`` —
the round-trip is byte-identical and a trace file is a stable artifact
that fully reproduces a characterization run's traffic.
"""
from __future__ import annotations

import json

from repro.workload.generator import Workload, WorkloadRequest


def _canon(obj: dict) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def save_workload(workload: Workload, path: str) -> str:
    lines = [_canon(workload.meta())]
    lines += [_canon(r.to_json()) for r in workload.requests]
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    return path


def load_workload(path: str) -> Workload:
    with open(path) as f:
        lines = [ln for ln in f.read().splitlines() if ln.strip()]
    if not lines:
        raise ValueError(f"empty workload trace: {path}")
    meta = json.loads(lines[0])
    if meta.get("schema") != 1:
        raise ValueError(f"unsupported workload trace schema in {path}: "
                         f"{meta.get('schema')!r}")
    reqs = [WorkloadRequest.from_json(json.loads(ln)) for ln in lines[1:]]
    if len(reqs) != meta.get("n_requests", len(reqs)):
        raise ValueError(
            f"trace {path} header claims {meta['n_requests']} requests, "
            f"found {len(reqs)}")
    return Workload(scenario=meta["scenario"], seed=meta["seed"],
                    vocab_size=meta["vocab_size"], requests=reqs)

"""Seeded workload generation: Scenario -> concrete request list.

Everything downstream (engine runs, telemetry, boundedness sweeps) is a
pure function of the generated requests, so determinism here — one
``numpy`` Generator seeded from ``(seed)``, sampled in a fixed order —
makes whole characterization runs reproducible and replayable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.workload.scenarios import Scenario, get_scenario


@dataclass
class WorkloadRequest:
    """One generated request: arrival offset + prompt + decode budget."""
    rid: int
    arrival_s: float
    prompt: list                    # token ids
    max_new_tokens: int

    def to_json(self) -> dict:
        return {"rid": self.rid, "arrival_s": self.arrival_s,
                "prompt": list(self.prompt),
                "max_new_tokens": self.max_new_tokens}

    @classmethod
    def from_json(cls, d: dict) -> "WorkloadRequest":
        return cls(rid=int(d["rid"]), arrival_s=float(d["arrival_s"]),
                   prompt=[int(t) for t in d["prompt"]],
                   max_new_tokens=int(d["max_new_tokens"]))


@dataclass
class Workload:
    scenario: str
    seed: int
    vocab_size: int
    requests: list = field(default_factory=list)  # list[WorkloadRequest]

    @property
    def n(self) -> int:
        return len(self.requests)

    def meta(self) -> dict:
        return {"schema": 1, "scenario": self.scenario, "seed": self.seed,
                "vocab_size": self.vocab_size, "n_requests": self.n}


def _arrivals(scenario: Scenario, n: int, rng, time_scale: float) -> list:
    if scenario.arrival == "closed":
        return [0.0] * n
    # time_scale > 1 compresses the timeline: arrivals come time_scale x
    # faster and bursty on/off windows shrink by the same factor
    rate = scenario.rate_rps * time_scale
    gaps = rng.exponential(1.0 / rate, size=n)
    ts = np.cumsum(gaps)
    if scenario.arrival == "bursty":
        # on/off modulation: traffic generated at `rate` fills burst_s-long
        # windows; each completed window pushes later arrivals past idle_s
        burst = scenario.burst_s / time_scale
        idle = scenario.idle_s / time_scale
        ts = ts + np.floor(ts / burst) * idle
    return [float(round(t, 6)) for t in ts]


def sample_requests(scenario, n_requests: int, *, seed: int = 0,
                    vocab_size: int = 503,
                    prompt_cap: Optional[int] = None,
                    output_cap: Optional[int] = None,
                    time_scale: float = 1.0,
                    shared_prefix: int = 0) -> Workload:
    """Generate a deterministic request list for ``scenario``.

    prompt_cap/output_cap clip the scenario's length distributions (so a
    long-prefill scenario stays tractable on a reduced model);
    time_scale > 1 compresses the arrival timeline by that factor;
    shared_prefix > 0 prepends the SAME sampled system prompt of that
    many tokens to every request (prefix-affinity routing and engine
    prefix sharing then see one common key).  prompt_cap applies to the
    per-request tail, so the shared head is never clipped away.
    """
    if isinstance(scenario, str):
        scenario = get_scenario(scenario)
    if not time_scale > 0:
        raise ValueError(f"time_scale must be > 0, got {time_scale}")
    if shared_prefix < 0:
        raise ValueError(f"shared_prefix must be >= 0, got {shared_prefix}")
    rng = np.random.default_rng(seed)
    # sampled FIRST (only when requested) so shared_prefix=0 workloads
    # stay byte-identical to pre-option streams
    head = ([int(t) for t in rng.integers(0, vocab_size, size=shared_prefix)]
            if shared_prefix else [])
    arrivals = _arrivals(scenario, n_requests, rng, time_scale)
    reqs = []
    for i in range(n_requests):
        plen = scenario.prompt.sample(rng)
        olen = scenario.output.sample(rng)
        if prompt_cap:
            plen = min(plen, prompt_cap)
        if output_cap:
            olen = min(olen, output_cap)
        prompt = head + [int(t)
                         for t in rng.integers(0, vocab_size, size=plen)]
        reqs.append(WorkloadRequest(rid=i, arrival_s=arrivals[i],
                                    prompt=prompt, max_new_tokens=max(olen, 1)))
    name = scenario.name
    return Workload(scenario=name, seed=seed, vocab_size=vocab_size,
                    requests=reqs)

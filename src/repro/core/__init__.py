"""The paper's primary contribution: SKIP-JAX profiler, TKLQT metrics,
PU-boundedness classification, proximity-score fusion mining + chain-jit."""
from repro.core.skip import SKIP                       # noqa: F401
from repro.core.device_model import PLATFORMS          # noqa: F401
from repro.core.proximity import mine_chains, sweep_lengths  # noqa: F401
from repro.core.fusion import apply_fusion             # noqa: F401
from repro.core.boundedness import classify_sweep, find_inflection  # noqa: F401
from repro.core.tracing import Executor, trace_fn      # noqa: F401
# the launch-plan runtime lives in repro.runtime (LaunchPlan, Planner,
# PlanExecutor); it is not re-exported here to keep the import graph
# acyclic — core facades import it lazily inside their methods

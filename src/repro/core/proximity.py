"""Proximity-score kernel-fusion mining (paper §III-C, Eqs. 6-8).

PS(C) = f(C) / f(k_i): the likelihood that executing kernel k_i is followed
by exactly the chain C of length L.  PS == 1 chains are deterministic
patterns — ideal fusion candidates.  The idealized speedup from pure
launch-count reduction:

    K_fused  = K_eager - C_fused * (L - 1)        (Eq. 7)
    speedup  = K_eager / K_fused                  (Eq. 8)
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from typing import Sequence


@dataclass(frozen=True)
class ChainStats:
    chain: tuple                   # kernel-name tuple, len L
    frequency: int                 # f(C)
    first_frequency: int           # f(k_i)

    @property
    def ps(self) -> float:         # Eq. 6
        return self.frequency / self.first_frequency


@dataclass
class MiningResult:
    length: int
    candidates: list               # all chains with PS >= threshold
    deterministic: list            # PS == 1 chains
    n_unique: int
    n_instances: int               # total occurrences of candidates
    k_eager: int
    c_fused: int                   # non-overlapping deterministic fusions
    k_fused: int                   # Eq. 7
    speedup: float                 # Eq. 8


def mine_chains(seq: Sequence[str], length: int,
                threshold: float = 1.0) -> MiningResult:
    """Mine chains of a given length from one kernel-name sequence.

    Degenerate cases are explicit: a sequence shorter than ``length`` (or
    empty, or ``length < 2``) has no mineable chains — every kernel stays
    an eager launch and the speedup is exactly 1.0, never a division by a
    zero/garbage ``k_fused``.
    """
    n = len(seq)
    if n == 0 or length < 2 or length > n:
        return MiningResult(length, [], [], 0, 0, n, 0, n, 1.0)
    first = Counter(seq)
    chains = Counter()
    for i in range(n - length + 1):
        chains[tuple(seq[i:i + length])] += 1

    cands = []
    for c, f in chains.items():
        st = ChainStats(c, f, first[c[0]])
        if st.ps >= threshold:
            cands.append(st)
    det = [c for c in cands if c.ps >= 1.0]

    # greedy non-overlapping cover with deterministic chains
    det_set = {c.chain for c in det}
    c_fused = 0
    i = 0
    while i <= n - length:
        if tuple(seq[i:i + length]) in det_set:
            c_fused += 1
            i += length
        else:
            i += 1
    k_eager = n
    k_fused = k_eager - c_fused * (length - 1)                 # Eq. 7
    speedup = k_eager / k_fused if k_fused > 0 else float("inf")  # Eq. 8
    return MiningResult(length, cands, det, len(cands),
                        sum(c.frequency for c in cands), k_eager,
                        c_fused, k_fused, speedup)


def fusion_segments(seq: Sequence[str], length: int,
                    mining: "MiningResult | None" = None) -> list[list[int]]:
    """Segment the kernel sequence for the chain-jit engine: greedy
    non-overlapping deterministic chains become multi-eqn segments, the rest
    stay singleton (eager).  Pass a precomputed ``mining`` result (for the
    same seq/length at threshold 1.0) to skip re-mining."""
    res = mining or mine_chains(seq, length, threshold=1.0)
    det = {c.chain for c in res.deterministic}
    segs, i, n = [], 0, len(seq)
    while i < n:
        if i <= n - length and tuple(seq[i:i + length]) in det:
            segs.append(list(range(i, i + length)))
            i += length
        else:
            segs.append([i])
            i += 1
    return segs


def sweep_lengths(seq: Sequence[str], lengths=(2, 4, 8, 16, 32, 64, 128, 256),
                  threshold: float = 1.0) -> list[MiningResult]:
    return [mine_chains(seq, L, threshold) for L in lengths
            if L <= max(len(seq), 1)]

"""SKIP-JAX tracing: jaxpr flattening, eager eqn-by-eqn execution with
measured host dispatch, and segment ("chain-jit") compilation.

The operator->kernel mapping of the paper translates as:

  ATen operator stream      -> flattened jaxpr equation sequence
  cudaLaunchKernel          -> dispatch of one per-eqn XLA executable
  CUDA-graph / torch.compile-> whole-jaxpr jit (one dispatch)
  fused chains (this work)  -> per-segment jit (one dispatch per chain)

The dependency graph is exact (jaxpr vars), unlike the paper's
timestamp-reconstructed graphs.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import jax
import jax.extend.core as jexc

from repro.core.costs import eqn_costs

# primitives whose sub-jaxprs we inline ("operators" containing child ops)
_INLINE_PRIMS = {"pjit", "jit", "closed_call", "custom_jvp_call",
                 "custom_vjp_call", "remat", "checkpoint", "custom_vjp_call_jaxpr"}


def _sub_jaxpr(eqn):
    p = eqn.params
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in p:
            j = p[key]
            return j
    return None


@dataclass
class Kernel:
    """One leaf equation = one eager-mode kernel launch."""
    index: int
    name: str                       # primitive name
    eqn: object
    flops: float
    bytes: float
    out_shapes: tuple
    host_dispatch_s: float = 0.0    # measured on this host
    operator: str = ""              # enclosing top-level operator name


@dataclass
class Trace:
    jaxpr: object                   # flattened ClosedJaxpr-like (eqns list)
    consts: list
    in_vars: list
    out_vars: list
    kernels: list                   # list[Kernel], one per eqn
    example_args: tuple

    @property
    def kernel_names(self) -> list[str]:
        return [k.name for k in self.kernels]

    def total_flops(self) -> float:
        return sum(k.flops for k in self.kernels)


def _flatten(jaxpr, env_map, eqns_out, depth=0):
    """Inline nested call-like primitives; collect leaf eqns."""
    for eqn in jaxpr.eqns:
        sub = _sub_jaxpr(eqn) if eqn.primitive.name in _INLINE_PRIMS else None
        if sub is not None:
            inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
            # map inner invars to outer values(vars), inline constvars
            sub_map = {}
            consts = list(getattr(sub, "consts", ()) or ())
            for cv, cval in zip(inner.constvars, consts):
                sub_map[cv] = ("const", cval)
            for iv, ov in zip(inner.invars, eqn.invars):
                sub_map[iv] = ("var", env_map.get(ov, ov) if not isinstance(
                    ov, jexc.Literal) else ov)
            # recurse with substitution: rewrite inner eqns' vars
            _flatten_inner(inner, sub_map, env_map, eqns_out)
            for ov_inner, ov_outer in zip(inner.outvars, eqn.outvars):
                tgt = sub_map.get(ov_inner, ov_inner)
                env_map[ov_outer] = tgt if not isinstance(
                    ov_inner, jexc.Literal) else ("lit", ov_inner)
        else:
            new_invars = []
            for v in eqn.invars:
                if isinstance(v, jexc.Literal):
                    new_invars.append(v)
                else:
                    r = env_map.get(v, v)
                    new_invars.append(r)
            eqns_out.append((eqn, new_invars))


def _flatten_inner(inner, sub_map, env_map, eqns_out):
    """Flatten an inlined sub-jaxpr, rewriting through sub_map."""
    for eqn in inner.eqns:
        sub = _sub_jaxpr(eqn) if eqn.primitive.name in _INLINE_PRIMS else None
        if sub is not None:
            inner2 = sub.jaxpr if hasattr(sub, "jaxpr") else sub
            sub_map2 = {}
            consts = list(getattr(sub, "consts", ()) or ())
            for cv, cval in zip(inner2.constvars, consts):
                sub_map2[cv] = ("const", cval)
            for iv, ov in zip(inner2.invars, eqn.invars):
                sub_map2[iv] = _resolve(ov, sub_map)
            _flatten_inner(inner2, sub_map2, env_map, eqns_out)
            for ov_inner, ov_outer in zip(inner2.outvars, eqn.outvars):
                sub_map[ov_outer] = _resolve(ov_inner, sub_map2)
        else:
            new_invars = [_resolve(v, sub_map) for v in eqn.invars]
            eqns_out.append((eqn, new_invars))
            for ov in eqn.outvars:
                sub_map[ov] = ov  # identity


def _resolve(v, sub_map):
    if isinstance(v, jexc.Literal):
        return v
    r = sub_map.get(v, v)
    return r


def _read(env, v):
    if isinstance(v, jexc.Literal):
        return v.val
    if isinstance(v, tuple):
        kind, val = v
        if kind == "const":
            return val
        return _read(env, val)
    return env[v]


def _is_drop(v) -> bool:
    return type(v).__name__ == "DropVar"


def trace_fn(fn: Callable, *example_args) -> Trace:
    """Flatten fn into a leaf-primitive kernel trace with cost estimates."""
    closed = jax.make_jaxpr(fn)(*example_args)
    env_map: dict = {}
    flat: list = []
    _flatten(closed.jaxpr, env_map, flat)
    kernels = []
    for i, (eqn, _) in enumerate(flat):
        fl, bt = eqn_costs(eqn)
        shapes = tuple(getattr(v.aval, "shape", ()) for v in eqn.outvars)
        kernels.append(Kernel(i, eqn.primitive.name, eqn, fl, bt, shapes))
    return Trace(jaxpr=closed.jaxpr, consts=list(closed.consts),
                 in_vars=list(closed.jaxpr.invars),
                 out_vars=list(closed.jaxpr.outvars),
                 kernels=kernels, example_args=example_args,
                 )._with_flat(flat, env_map, closed)


# attach flattened eqns without polluting the dataclass signature
def _with_flat(self, flat, env_map, closed):
    self._flat = flat
    self._env_map = env_map
    self._closed = closed
    return self


Trace._with_flat = _with_flat


class Executor:
    """Executes a trace in segments; each segment is one jitted executable
    (= one 'kernel launch').  Eager mode: one segment per eqn."""

    def __init__(self, trace: Trace, segments: Optional[list] = None):
        self.trace = trace
        flat = trace._flat
        n = len(flat)
        self.segments = segments or [[i] for i in range(n)]
        self._compiled = None

    def _build(self):
        trace = self.trace
        flat = trace._flat
        closed = trace._closed
        # global env keyed by Var; seed with consts + inputs
        const_vars = list(closed.jaxpr.constvars)

        seg_fns = []
        for seg in self.segments:
            eqns = [flat[i] for i in seg]

            # free inputs of the segment: vars read before defined inside
            defined = set()
            free = []
            for eqn, invars in eqns:
                for v in invars:
                    base = v
                    while isinstance(base, tuple):
                        if base[0] == "const":
                            base = None
                            break
                        base = base[1]
                    if base is None or isinstance(base, jexc.Literal):
                        continue
                    if base not in defined and base not in free:
                        free.append(base)
                for ov in eqn.outvars:
                    if not _is_drop(ov):
                        defined.add(ov)
            outs = [ov for eqn, _ in eqns for ov in eqn.outvars
                    if not _is_drop(ov)]

            def seg_fn(vals, _eqns=eqns, _free=free):
                env = dict(zip(_free, vals))

                def read(v):
                    if isinstance(v, jexc.Literal):
                        return v.val
                    if isinstance(v, tuple):
                        if v[0] == "const":
                            return v[1]
                        return read(v[1])
                    return env[v]

                results = []
                for eqn, invars in _eqns:
                    invals = [read(v) for v in invars]
                    out = eqn.primitive.bind(*invals, **eqn.params)
                    if not eqn.primitive.multiple_results:
                        out = [out]
                    for ov, o in zip(eqn.outvars, out):
                        if not _is_drop(ov):
                            env[ov] = o
                            results.append(o)
                return results

            seg_fns.append((jax.jit(seg_fn), free, outs))
        self._compiled = seg_fns
        return seg_fns

    def run(self, *args, measure: bool = False):
        """Execute all segments; returns (outputs, host_times per segment)."""
        trace = self.trace
        closed = trace._closed
        segs = self._compiled or self._build()
        env = {}
        for cv, cval in zip(closed.jaxpr.constvars, closed.consts):
            env[cv] = cval
        flat_args = jax.tree.leaves(args)
        for iv, val in zip(closed.jaxpr.invars, flat_args):
            env[iv] = val

        host_times = []
        for jfn, free, outs in segs:
            vals = [env[v] if not isinstance(v, tuple) else v[1]
                    for v in free]
            t0 = time.perf_counter()
            res = jfn(vals)
            t1 = time.perf_counter()
            if measure:
                jax.block_until_ready(res)
            host_times.append(t1 - t0)
            for v, o in zip(outs, res):
                env[v] = o

        def read_out(v):
            if isinstance(v, jexc.Literal):
                return v.val
            r = trace._env_map.get(v, v)
            return _read(env, r)

        outputs = [read_out(v) for v in closed.jaxpr.outvars]
        return outputs, host_times

    def measure_host(self, *args, repeats: int = 3):
        """Warm up (compile) then measure median per-segment dispatch time."""
        self.run(*args)  # warmup/compile
        all_times = []
        for _ in range(repeats):
            _, ts = self.run(*args, measure=False)
            all_times.append(ts)
        import statistics
        med = [statistics.median(x) for x in zip(*all_times)]
        if len(self.segments) == len(self.trace.kernels):
            for k, t in zip(self.trace.kernels, med):
                k.host_dispatch_s = t
        return med

    @property
    def n_launches(self) -> int:
        return len(self.segments)

"""SKIP-JAX tracing: jaxpr flattening, eager eqn-by-eqn execution with
measured host dispatch, and segment ("chain-jit") compilation.

The operator->kernel mapping of the paper translates as:

  ATen operator stream      -> flattened jaxpr equation sequence
  cudaLaunchKernel          -> dispatch of one per-eqn XLA executable
  CUDA-graph / torch.compile-> whole-jaxpr jit (one dispatch)
  fused chains (this work)  -> per-segment jit (one dispatch per chain)

The dependency graph is exact (jaxpr vars), unlike the paper's
timestamp-reconstructed graphs.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.extend.core as jexc

from repro.core.costs import eqn_costs

_TRACE_TOKENS = itertools.count()

# primitives whose sub-jaxprs we inline ("operators" containing child ops)
_INLINE_PRIMS = {"pjit", "jit", "closed_call", "custom_jvp_call",
                 "custom_vjp_call", "remat", "checkpoint", "custom_vjp_call_jaxpr"}


def _sub_jaxpr(eqn):
    p = eqn.params
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in p:
            j = p[key]
            return j
    return None


@dataclass
class Kernel:
    """One leaf equation = one eager-mode kernel launch."""
    index: int
    name: str                       # primitive name
    eqn: object
    flops: float
    bytes: float
    out_shapes: tuple
    host_dispatch_s: float = 0.0    # measured on this host
    operator: str = ""              # enclosing top-level operator name


@dataclass
class Trace:
    jaxpr: object                   # flattened ClosedJaxpr-like (eqns list)
    consts: list
    in_vars: list
    out_vars: list
    kernels: list                   # list[Kernel], one per eqn
    example_args: tuple
    flat_eqns: list = field(default_factory=list)   # [(eqn, rewritten invars)]
    env_map: dict = field(default_factory=dict)     # outer var -> rewritten
    closed: object = None           # the original ClosedJaxpr
    out_tree: object = None         # output pytree structure of the traced fn
    token: int = -1                 # unique id (compiled-segment cache key)

    @property
    def kernel_names(self) -> list[str]:
        return [k.name for k in self.kernels]

    def total_flops(self) -> float:
        return sum(k.flops for k in self.kernels)


def _scope_of(eqn) -> str:
    """``jax.named_scope`` stack recorded on one eqn at trace time."""
    si = getattr(eqn, "source_info", None)
    stack = getattr(si, "name_stack", None)
    if stack is None:
        return ""
    try:
        return str(stack)
    except Exception:  # noqa: BLE001 - provenance is best-effort
        return ""


def _join_scope(prefix: str, inner: str) -> str:
    if prefix and inner:
        return f"{prefix}/{inner}"
    return prefix or inner


def _flatten(jaxpr, env_map, eqns_out, depth=0, prefixes=None, prefix=""):
    """Inline nested call-like primitives; collect leaf eqns.

    ``prefixes`` (when given) collects one scope-prefix string per leaf
    eqn: sub-jaxprs are traced in a fresh name-stack context, so their
    eqns carry scopes *relative* to the call site — the enclosing call
    eqn's own stack must be re-prepended to recover absolute provenance
    (e.g. the gather inside ``jnp.take``'s pjit regains ``embed``).
    """
    for eqn in jaxpr.eqns:
        sub = _sub_jaxpr(eqn) if eqn.primitive.name in _INLINE_PRIMS else None
        if sub is not None:
            inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
            # map inner invars to outer values(vars), inline constvars
            sub_map = {}
            consts = list(getattr(sub, "consts", ()) or ())
            for cv, cval in zip(inner.constvars, consts):
                sub_map[cv] = ("const", cval)
            for iv, ov in zip(inner.invars, eqn.invars):
                sub_map[iv] = ("var", env_map.get(ov, ov) if not isinstance(
                    ov, jexc.Literal) else ov)
            # recurse with substitution: rewrite inner eqns' vars
            _flatten_inner(inner, sub_map, env_map, eqns_out,
                           prefixes=prefixes,
                           prefix=_join_scope(prefix, _scope_of(eqn)))
            for ov_inner, ov_outer in zip(inner.outvars, eqn.outvars):
                tgt = sub_map.get(ov_inner, ov_inner)
                env_map[ov_outer] = tgt if not isinstance(
                    ov_inner, jexc.Literal) else ("lit", ov_inner)
        else:
            new_invars = []
            for v in eqn.invars:
                if isinstance(v, jexc.Literal):
                    new_invars.append(v)
                else:
                    r = env_map.get(v, v)
                    new_invars.append(r)
            eqns_out.append((eqn, new_invars))
            if prefixes is not None:
                prefixes.append(prefix)


def _flatten_inner(inner, sub_map, env_map, eqns_out, prefixes=None,
                   prefix=""):
    """Flatten an inlined sub-jaxpr, rewriting through sub_map."""
    for eqn in inner.eqns:
        sub = _sub_jaxpr(eqn) if eqn.primitive.name in _INLINE_PRIMS else None
        if sub is not None:
            inner2 = sub.jaxpr if hasattr(sub, "jaxpr") else sub
            sub_map2 = {}
            consts = list(getattr(sub, "consts", ()) or ())
            for cv, cval in zip(inner2.constvars, consts):
                sub_map2[cv] = ("const", cval)
            for iv, ov in zip(inner2.invars, eqn.invars):
                sub_map2[iv] = _resolve(ov, sub_map)
            _flatten_inner(inner2, sub_map2, env_map, eqns_out,
                           prefixes=prefixes,
                           prefix=_join_scope(prefix, _scope_of(eqn)))
            for ov_inner, ov_outer in zip(inner2.outvars, eqn.outvars):
                sub_map[ov_outer] = _resolve(ov_inner, sub_map2)
        else:
            new_invars = [_resolve(v, sub_map) for v in eqn.invars]
            eqns_out.append((eqn, new_invars))
            if prefixes is not None:
                prefixes.append(prefix)
            for ov in eqn.outvars:
                sub_map[ov] = ov  # identity


def _resolve(v, sub_map):
    if isinstance(v, jexc.Literal):
        return v
    r = sub_map.get(v, v)
    return r


def _read(env, v):
    if isinstance(v, jexc.Literal):
        return v.val
    if isinstance(v, tuple):
        kind, val = v
        if kind == "const":
            return val
        return _read(env, val)
    return env[v]


def _is_drop(v) -> bool:
    return type(v).__name__ == "DropVar"


def _eqn_operator(eqn, prefix: str = "") -> str:
    """Provenance tag for one equation: the inlining-time scope prefix
    joined with the ``jax.named_scope`` stack recorded at trace time
    (e.g. ``"layer0/slot0/attn"``); ``""`` for eqns issued outside any
    scope."""
    return _join_scope(prefix, _scope_of(eqn))


def trace_fn(fn: Callable, *example_args) -> Trace:
    """Flatten fn into a leaf-primitive kernel trace with cost estimates."""
    closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*example_args)
    env_map: dict = {}
    flat: list = []
    prefixes: list = []
    _flatten(closed.jaxpr, env_map, flat, prefixes=prefixes)
    kernels = []
    for i, (eqn, _) in enumerate(flat):
        fl, bt = eqn_costs(eqn)
        shapes = tuple(getattr(v.aval, "shape", ()) for v in eqn.outvars)
        kernels.append(Kernel(i, eqn.primitive.name, eqn, fl, bt, shapes,
                              operator=_eqn_operator(eqn, prefixes[i])))
    return Trace(jaxpr=closed.jaxpr, consts=list(closed.consts),
                 in_vars=list(closed.jaxpr.invars),
                 out_vars=list(closed.jaxpr.outvars),
                 kernels=kernels, example_args=example_args,
                 flat_eqns=flat, env_map=env_map, closed=closed,
                 out_tree=jax.tree.structure(out_shape),
                 token=next(_TRACE_TOKENS))


class Executor:
    """Back-compat facade over ``repro.runtime.PlanExecutor``.

    ``Executor(trace)`` is the eager plan (one jitted executable per eqn =
    one 'kernel launch'); ``Executor(trace, segments=...)`` wraps an
    explicit segment list.  New code should use the runtime types directly:
    ``PlanExecutor(trace, LaunchPlan...)``.
    """

    def __init__(self, trace: Trace, segments: Optional[list] = None):
        from repro.runtime.executor import PlanExecutor
        from repro.runtime.plan import LaunchPlan
        plan = (LaunchPlan.from_segments(segments) if segments is not None
                else LaunchPlan.eager(len(trace.kernels)))
        self.trace = trace
        self._ex = PlanExecutor(trace, plan)

    @property
    def plan(self):
        return self._ex.plan

    @property
    def segments(self) -> list:
        return [list(s) for s in self._ex.plan.segments]

    def run(self, *args, measure: bool = False):
        return self._ex.run(*args, measure=measure)

    def measure_host(self, *args, repeats: int = 3):
        return self._ex.measure_host(*args, repeats=repeats)

    @property
    def n_launches(self) -> int:
        return self._ex.n_launches

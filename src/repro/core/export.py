"""Chrome-trace (chrome://tracing / Perfetto) export of simulated SKIP
timelines — host lane (launch calls) + device lane (kernel execution),
so the CPU-bound launch trains and GPU-bound queue pileups of the paper's
Fig. 4 are visually inspectable.
"""
from __future__ import annotations

import json
from typing import Sequence

from repro.core.device_model import KernelEvent


def to_chrome_trace(events: Sequence[KernelEvent], platform: str) -> dict:
    out = []
    for i, e in enumerate(events):
        out.append({
            "name": e.name, "ph": "X", "pid": 0, "tid": 0,
            "ts": e.launch_begin * 1e6,
            "dur": max(e.t_launch * 1e6, 0.01),
            "cat": "host_launch",
        })
        out.append({
            "name": e.name, "ph": "X", "pid": 0, "tid": 1,
            "ts": e.kernel_start * 1e6,
            "dur": max(e.duration * 1e6, 0.01),
            "cat": "kernel",
            "args": {"t_l_us": e.t_l * 1e6, "queue_us": e.t_queue * 1e6},
        })
    return {
        "traceEvents": out,
        "displayTimeUnit": "ms",
        "metadata": {"platform": platform},
        "otherData": {
            "thread_names": {"0": "CPU (launch calls)",
                             "1": f"{platform} stream 0"},
        },
    }


def save_chrome_trace(events, platform: str, path: str) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(events, platform), f)
    return path

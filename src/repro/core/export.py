"""Chrome-trace (chrome://tracing / Perfetto) export of simulated SKIP
timelines — host lane (launch calls) + device lane (kernel execution),
so the CPU-bound launch trains and GPU-bound queue pileups of the paper's
Fig. 4 are visually inspectable.
"""
from __future__ import annotations

import json
from typing import Sequence

from repro.core.device_model import KernelEvent


def _flow_pair(name: str, flow_id: int, host_ts_us: float,
               device_ts_us: float, host_tid: int, device_tid: int,
               pid: int = 0) -> list:
    """Chrome-trace flow arrow: a start (``s``) on the host dispatch slice
    and a finish (``f``, binding-point ``e`` = enclosing slice) on the
    device kernel slice, joined by a shared numeric ``id``."""
    return [
        {"name": name, "ph": "s", "pid": pid, "tid": host_tid,
         "ts": host_ts_us, "id": flow_id, "cat": "dispatch_flow"},
        {"name": name, "ph": "f", "pid": pid, "tid": device_tid,
         "ts": device_ts_us, "id": flow_id, "cat": "dispatch_flow",
         "bp": "e"},
    ]


def to_chrome_trace(events: Sequence[KernelEvent], platform: str) -> dict:
    out = []
    for i, e in enumerate(events):
        args = {"t_l_us": e.t_l * 1e6, "queue_us": e.t_queue * 1e6}
        if getattr(e, "operator", ""):
            args["operator"] = e.operator
        out.append({
            "name": e.name, "ph": "X", "pid": 0, "tid": 0,
            "ts": e.launch_begin * 1e6,
            "dur": max(e.t_launch * 1e6, 0.01),
            "cat": "host_launch",
        })
        out.append({
            "name": e.name, "ph": "X", "pid": 0, "tid": 1,
            "ts": e.kernel_start * 1e6,
            "dur": max(e.duration * 1e6, 0.01),
            "cat": "kernel",
            "args": args,
        })
        # arrow from this launch call to the kernel it enqueued: the
        # start event must land INSIDE the host slice, so nudge past
        # launch_begin by a fraction of the (clamped) slice duration
        out.extend(_flow_pair(e.name, i,
                              e.launch_begin * 1e6
                              + 0.5 * max(e.t_launch * 1e6, 0.01),
                              e.kernel_start * 1e6, 0, 1))
    return {
        "traceEvents": out,
        "displayTimeUnit": "ms",
        "metadata": {"platform": platform},
        "otherData": {
            "thread_names": {"0": "CPU (launch calls)",
                             "1": f"{platform} stream 0"},
        },
    }


def save_chrome_trace(events, platform: str, path: str) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(events, platform), f)
    return path


# --------------------------------------------------------------- measured
def spans_to_chrome_events(spans, pid: int = 0) -> list:
    """Telemetry spans (repro.telemetry.spans.Span) -> chrome trace events."""
    out = []
    for s in spans:
        ev = {
            "name": s.name, "ph": "X", "pid": pid, "tid": s.tid,
            "ts": s.t0 * 1e6, "dur": max(s.dur * 1e6, 0.01),
            "cat": s.cat,
        }
        if s.args:
            ev["args"] = dict(s.args)
        out.append(ev)
    return out


def merged_chrome_trace(spans, platform: str,
                        device_events: Sequence[KernelEvent] = (),
                        device_anchors: Sequence[float] = (),
                        device_tid: int = 2,
                        metadata: dict | None = None) -> dict:
    """Merged timeline: MEASURED host spans + MODELED device kernels.

    ``device_events`` is one modeled invocation (e.g. the planner's
    simulated decode step); it is replicated at each ``device_anchors``
    offset (seconds) — typically the measured start of every decode step —
    so the modeled device lane lines up under the real host lane.
    """
    out = spans_to_chrome_events(spans)
    n_ev = len(device_events)
    for ai, anchor in enumerate(device_anchors):
        for i, e in enumerate(device_events):
            args = {"t_l_us": e.t_l * 1e6}
            if getattr(e, "operator", ""):
                args["operator"] = e.operator
            out.append({
                "name": e.name, "ph": "X", "pid": 0, "tid": device_tid,
                "ts": (anchor + e.kernel_start) * 1e6,
                "dur": max(e.duration * 1e6, 0.01),
                "cat": "modeled_kernel",
                "args": args,
            })
            # arrow from the modeled host-issue instant (within the
            # measured segment-dispatch lane) to the modeled kernel;
            # ids are unique per (anchor, event) pair
            out.extend(_flow_pair(e.name, ai * n_ev + i,
                                  (anchor + e.launch_begin) * 1e6,
                                  (anchor + e.kernel_start) * 1e6,
                                  1, device_tid))
    meta = {"platform": platform}
    if metadata:
        meta.update(metadata)
    return {
        "traceEvents": out,
        "displayTimeUnit": "ms",
        "metadata": meta,
        "otherData": {
            "thread_names": {
                "0": "CPU host (engine steps)",
                "1": "CPU host (segment dispatches)",
                str(device_tid): f"{platform} stream 0 (modeled)",
            },
        },
    }


def save_merged_trace(spans, platform: str, path: str, *,
                      device_events: Sequence[KernelEvent] = (),
                      device_anchors: Sequence[float] = (),
                      metadata: dict | None = None) -> str:
    with open(path, "w") as f:
        json.dump(merged_chrome_trace(spans, platform,
                                      device_events=device_events,
                                      device_anchors=device_anchors,
                                      metadata=metadata), f)
    return path


# --------------------------------------------------------------- requests
# request critical-path tracks live in their own trace process so per-rid
# tids never collide with the host/device lanes of pid 0
REQUEST_PID = 1
_EXEC_SEGMENTS = ("prefill_exec", "decode_exec", "launch_tax")


def _flow_pair_xpid(name: str, flow_id: int,
                    src_pid: int, src_tid: int, src_ts_us: float,
                    dst_pid: int, dst_tid: int, dst_ts_us: float) -> list:
    """Cross-process flow arrow (request track -> engine host lane);
    same s/f contract as ``_flow_pair`` but each end names its own pid,
    and ``cat`` namespaces the id space away from dispatch flows."""
    return [
        {"name": name, "ph": "s", "pid": src_pid, "tid": src_tid,
         "ts": src_ts_us, "id": flow_id, "cat": "request_flow"},
        {"name": name, "ph": "f", "pid": dst_pid, "tid": dst_tid,
         "ts": dst_ts_us, "id": flow_id, "cat": "request_flow",
         "bp": "e"},
    ]


def request_trace(analysis, platform: str = "",
                  host_spans=(), metadata: dict | None = None) -> dict:
    """Chrome/Perfetto trace of per-request critical paths.

    One track per request (pid ``REQUEST_PID``, tid = rid) whose slices
    are the breakdown's ordered segment pieces — the waterfall a triage
    reader scrubs.  Engine execution lanes live at pid 0, one tid per
    replica, rebuilt from the exec pieces themselves (deduped: a batched
    decode step shared by four requests is one host slice), and every
    exec piece carries a flow arrow from its request track into the host
    slice that ran it.  ``host_spans`` optionally merges a measured
    ``SpanRecorder`` dump (tids 0/1/2) into pid 0 as well, lining the
    request tracks up over the kernel lanes of ``merged_chrome_trace``.

    ``analysis`` is a ``repro.telemetry.critical_path``
    ``CriticalPathAnalysis`` (duck-typed: anything with ``breakdowns``).
    """
    out = [{"name": "process_name", "ph": "M", "pid": REQUEST_PID,
            "args": {"name": "requests (critical path)"}},
           {"name": "process_name", "ph": "M", "pid": 0,
            "args": {"name": "engine host lanes"}}]
    host_seen = set()
    flow_id = 0
    for b in analysis.breakdowns:
        host_tid = b.replica if b.replica is not None else 0
        out.append({"name": "thread_name", "ph": "M", "pid": REQUEST_PID,
                    "tid": b.rid, "args": {"name": f"request {b.rid}"}})
        for seg, t0, t1 in b.pieces:
            is_exec = seg in _EXEC_SEGMENTS
            dur = max((t1 - t0) * 1e6, 0.01)
            ev = {"name": seg, "ph": "X", "pid": REQUEST_PID,
                  "tid": b.rid, "ts": t0 * 1e6, "dur": dur,
                  "cat": "request_exec" if is_exec else "request_wait",
                  "args": {"rid": b.rid, "segment": seg}}
            if b.replica is not None:
                ev["args"]["replica"] = b.replica
            out.append(ev)
            if not is_exec or seg == "launch_tax":
                continue
            hkey = (host_tid, round(t0 * 1e6, 3), round(t1 * 1e6, 3))
            if hkey not in host_seen:
                host_seen.add(hkey)
                out.append({"name": seg, "ph": "X", "pid": 0,
                            "tid": host_tid, "ts": t0 * 1e6, "dur": dur,
                            "cat": "host_step",
                            "args": {"replica": host_tid}})
            # arrow from inside the request slice into the host slice
            out.extend(_flow_pair_xpid(
                f"{seg}[rid={b.rid}]", flow_id,
                REQUEST_PID, b.rid, t0 * 1e6 + 0.5 * dur,
                0, host_tid, t0 * 1e6 + 0.5 * dur))
            flow_id += 1
    out.extend(spans_to_chrome_events(host_spans, pid=0))
    meta = {"platform": platform} if platform else {}
    if metadata:
        meta.update(metadata)
    return {
        "traceEvents": out,
        "displayTimeUnit": "ms",
        "metadata": meta,
        "otherData": {
            "thread_names": {
                str(b.rid): f"request {b.rid}"
                for b in analysis.breakdowns},
        },
    }


def save_request_trace(analysis, path: str, *, platform: str = "",
                       host_spans=(), metadata: dict | None = None) -> str:
    """Write ``request_trace`` to ``path`` as strict JSON."""
    with open(path, "w") as f:
        json.dump(request_trace(analysis, platform,
                                host_spans=host_spans, metadata=metadata),
                  f, allow_nan=False)
    return path

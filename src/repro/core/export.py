"""Chrome-trace (chrome://tracing / Perfetto) export of simulated SKIP
timelines — host lane (launch calls) + device lane (kernel execution),
so the CPU-bound launch trains and GPU-bound queue pileups of the paper's
Fig. 4 are visually inspectable.
"""
from __future__ import annotations

import json
from typing import Sequence

from repro.core.device_model import KernelEvent


def to_chrome_trace(events: Sequence[KernelEvent], platform: str) -> dict:
    out = []
    for i, e in enumerate(events):
        out.append({
            "name": e.name, "ph": "X", "pid": 0, "tid": 0,
            "ts": e.launch_begin * 1e6,
            "dur": max(e.t_launch * 1e6, 0.01),
            "cat": "host_launch",
        })
        out.append({
            "name": e.name, "ph": "X", "pid": 0, "tid": 1,
            "ts": e.kernel_start * 1e6,
            "dur": max(e.duration * 1e6, 0.01),
            "cat": "kernel",
            "args": {"t_l_us": e.t_l * 1e6, "queue_us": e.t_queue * 1e6},
        })
    return {
        "traceEvents": out,
        "displayTimeUnit": "ms",
        "metadata": {"platform": platform},
        "otherData": {
            "thread_names": {"0": "CPU (launch calls)",
                             "1": f"{platform} stream 0"},
        },
    }


def save_chrome_trace(events, platform: str, path: str) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(events, platform), f)
    return path


# --------------------------------------------------------------- measured
def spans_to_chrome_events(spans, pid: int = 0) -> list:
    """Telemetry spans (repro.telemetry.spans.Span) -> chrome trace events."""
    out = []
    for s in spans:
        ev = {
            "name": s.name, "ph": "X", "pid": pid, "tid": s.tid,
            "ts": s.t0 * 1e6, "dur": max(s.dur * 1e6, 0.01),
            "cat": s.cat,
        }
        if s.args:
            ev["args"] = dict(s.args)
        out.append(ev)
    return out


def merged_chrome_trace(spans, platform: str,
                        device_events: Sequence[KernelEvent] = (),
                        device_anchors: Sequence[float] = (),
                        device_tid: int = 2,
                        metadata: dict | None = None) -> dict:
    """Merged timeline: MEASURED host spans + MODELED device kernels.

    ``device_events`` is one modeled invocation (e.g. the planner's
    simulated decode step); it is replicated at each ``device_anchors``
    offset (seconds) — typically the measured start of every decode step —
    so the modeled device lane lines up under the real host lane.
    """
    out = spans_to_chrome_events(spans)
    for anchor in device_anchors:
        for e in device_events:
            out.append({
                "name": e.name, "ph": "X", "pid": 0, "tid": device_tid,
                "ts": (anchor + e.kernel_start) * 1e6,
                "dur": max(e.duration * 1e6, 0.01),
                "cat": "modeled_kernel",
                "args": {"t_l_us": e.t_l * 1e6},
            })
    meta = {"platform": platform}
    if metadata:
        meta.update(metadata)
    return {
        "traceEvents": out,
        "displayTimeUnit": "ms",
        "metadata": meta,
        "otherData": {
            "thread_names": {
                "0": "CPU host (engine steps)",
                "1": "CPU host (segment dispatches)",
                str(device_tid): f"{platform} stream 0 (modeled)",
            },
        },
    }


def save_merged_trace(spans, platform: str, path: str, *,
                      device_events: Sequence[KernelEvent] = (),
                      device_anchors: Sequence[float] = (),
                      metadata: dict | None = None) -> str:
    with open(path, "w") as f:
        json.dump(merged_chrome_trace(spans, platform,
                                      device_events=device_events,
                                      device_anchors=device_anchors,
                                      metadata=metadata), f)
    return path

"""Chrome-trace (chrome://tracing / Perfetto) export of simulated SKIP
timelines — host lane (launch calls) + device lane (kernel execution),
so the CPU-bound launch trains and GPU-bound queue pileups of the paper's
Fig. 4 are visually inspectable.
"""
from __future__ import annotations

import json
from typing import Sequence

from repro.core.device_model import KernelEvent


def _flow_pair(name: str, flow_id: int, host_ts_us: float,
               device_ts_us: float, host_tid: int, device_tid: int,
               pid: int = 0) -> list:
    """Chrome-trace flow arrow: a start (``s``) on the host dispatch slice
    and a finish (``f``, binding-point ``e`` = enclosing slice) on the
    device kernel slice, joined by a shared numeric ``id``."""
    return [
        {"name": name, "ph": "s", "pid": pid, "tid": host_tid,
         "ts": host_ts_us, "id": flow_id, "cat": "dispatch_flow"},
        {"name": name, "ph": "f", "pid": pid, "tid": device_tid,
         "ts": device_ts_us, "id": flow_id, "cat": "dispatch_flow",
         "bp": "e"},
    ]


def to_chrome_trace(events: Sequence[KernelEvent], platform: str) -> dict:
    out = []
    for i, e in enumerate(events):
        args = {"t_l_us": e.t_l * 1e6, "queue_us": e.t_queue * 1e6}
        if getattr(e, "operator", ""):
            args["operator"] = e.operator
        out.append({
            "name": e.name, "ph": "X", "pid": 0, "tid": 0,
            "ts": e.launch_begin * 1e6,
            "dur": max(e.t_launch * 1e6, 0.01),
            "cat": "host_launch",
        })
        out.append({
            "name": e.name, "ph": "X", "pid": 0, "tid": 1,
            "ts": e.kernel_start * 1e6,
            "dur": max(e.duration * 1e6, 0.01),
            "cat": "kernel",
            "args": args,
        })
        # arrow from this launch call to the kernel it enqueued: the
        # start event must land INSIDE the host slice, so nudge past
        # launch_begin by a fraction of the (clamped) slice duration
        out.extend(_flow_pair(e.name, i,
                              e.launch_begin * 1e6
                              + 0.5 * max(e.t_launch * 1e6, 0.01),
                              e.kernel_start * 1e6, 0, 1))
    return {
        "traceEvents": out,
        "displayTimeUnit": "ms",
        "metadata": {"platform": platform},
        "otherData": {
            "thread_names": {"0": "CPU (launch calls)",
                             "1": f"{platform} stream 0"},
        },
    }


def save_chrome_trace(events, platform: str, path: str) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(events, platform), f)
    return path


# --------------------------------------------------------------- measured
def spans_to_chrome_events(spans, pid: int = 0) -> list:
    """Telemetry spans (repro.telemetry.spans.Span) -> chrome trace events."""
    out = []
    for s in spans:
        ev = {
            "name": s.name, "ph": "X", "pid": pid, "tid": s.tid,
            "ts": s.t0 * 1e6, "dur": max(s.dur * 1e6, 0.01),
            "cat": s.cat,
        }
        if s.args:
            ev["args"] = dict(s.args)
        out.append(ev)
    return out


def merged_chrome_trace(spans, platform: str,
                        device_events: Sequence[KernelEvent] = (),
                        device_anchors: Sequence[float] = (),
                        device_tid: int = 2,
                        metadata: dict | None = None) -> dict:
    """Merged timeline: MEASURED host spans + MODELED device kernels.

    ``device_events`` is one modeled invocation (e.g. the planner's
    simulated decode step); it is replicated at each ``device_anchors``
    offset (seconds) — typically the measured start of every decode step —
    so the modeled device lane lines up under the real host lane.
    """
    out = spans_to_chrome_events(spans)
    n_ev = len(device_events)
    for ai, anchor in enumerate(device_anchors):
        for i, e in enumerate(device_events):
            args = {"t_l_us": e.t_l * 1e6}
            if getattr(e, "operator", ""):
                args["operator"] = e.operator
            out.append({
                "name": e.name, "ph": "X", "pid": 0, "tid": device_tid,
                "ts": (anchor + e.kernel_start) * 1e6,
                "dur": max(e.duration * 1e6, 0.01),
                "cat": "modeled_kernel",
                "args": args,
            })
            # arrow from the modeled host-issue instant (within the
            # measured segment-dispatch lane) to the modeled kernel;
            # ids are unique per (anchor, event) pair
            out.extend(_flow_pair(e.name, ai * n_ev + i,
                                  (anchor + e.launch_begin) * 1e6,
                                  (anchor + e.kernel_start) * 1e6,
                                  1, device_tid))
    meta = {"platform": platform}
    if metadata:
        meta.update(metadata)
    return {
        "traceEvents": out,
        "displayTimeUnit": "ms",
        "metadata": meta,
        "otherData": {
            "thread_names": {
                "0": "CPU host (engine steps)",
                "1": "CPU host (segment dispatches)",
                str(device_tid): f"{platform} stream 0 (modeled)",
            },
        },
    }


def save_merged_trace(spans, platform: str, path: str, *,
                      device_events: Sequence[KernelEvent] = (),
                      device_anchors: Sequence[float] = (),
                      metadata: dict | None = None) -> str:
    with open(path, "w") as f:
        json.dump(merged_chrome_trace(spans, platform,
                                      device_events=device_events,
                                      device_anchors=device_anchors,
                                      metadata=metadata), f)
    return path

"""PU-boundedness classification from TKLQT-vs-batch curves (paper §V-B).

CPU-bound region: TKLQT flat in batch (pure launch overhead, GPU
under-utilized).  GPU-bound: kernel queuing dominates, TKLQT grows.  The
inflection batch size (star markers in Fig. 6) is where TKLQT exceeds the
flat launch-tax level by a threshold factor.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

INFLECTION_FACTOR = 1.5


@dataclass
class BoundednessResult:
    batches: list
    tklqt: list                   # per batch
    queue_share: list
    inflection_batch: int | None  # first GPU-bound batch (None = always CPU-bound)

    def classify(self, batch: int) -> str:
        if self.inflection_batch is None or batch < self.inflection_batch:
            return "CPU-bound"
        return "GPU-bound"

    @property
    def cpu_bound_region(self):
        if self.inflection_batch is None:
            return (self.batches[0], self.batches[-1])
        return (self.batches[0], self.inflection_batch)


_BASE_EPS = 1e-12      # below this the flat (launch) level is not established


def find_inflection(batches: Sequence[int], tklqt: Sequence[float],
                    factor: float = INFLECTION_FACTOR):
    """First batch where TKLQT rises above factor x the flat (launch) level.

    Degenerate inputs return None (no inflection) rather than a spurious
    one: a zero/near-zero base level would let ANY positive value trip
    ``t > factor * base``, and mismatched sequence lengths mean the input
    is not a curve at all.
    """
    if not batches or len(batches) != len(tklqt):
        return None
    base = tklqt[0]
    if not (base > _BASE_EPS):        # zero, near-zero, negative, or NaN
        return None
    for b, t in zip(batches, tklqt):
        if t > factor * base:
            return b
    return None


def classify_sweep(batches, reports) -> BoundednessResult:
    t = [r.tklqt for r in reports]
    q = [r.queue_share for r in reports]
    return BoundednessResult(list(batches), t, q, find_inflection(batches, t))

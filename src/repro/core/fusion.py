"""Chain-jit fusion — thin facade over the launch-plan runtime.

Takes proximity-score recommendations, builds a chain ``LaunchPlan``, and
runs both it and the eager plan through ``repro.runtime.PlanExecutor``.
Reports measured dispatch counts and host time against eager, plus the
paper's idealized Eq. 8 speedup for comparison.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.proximity import mine_chains
from repro.core.tracing import Trace


def json_safe(value):
    """JSON-exportable number: finite floats pass through, ``inf``/``nan``
    become their string names.  Python's ``json`` would otherwise emit
    bare ``Infinity``/``NaN`` tokens, which are NOT valid JSON and break
    strict parsers reading exported reports."""
    if isinstance(value, float) and not math.isfinite(value):
        return str(value)
    return value


def json_sanitize(obj):
    """Recursive ``json_safe``: walk dicts/lists/tuples and sanitize every
    leaf, so whole report payloads (bench artifacts, serve CLI JSON) can be
    dumped with ``allow_nan=False`` — the shared strict-JSON export path."""
    if isinstance(obj, dict):
        return {k: json_sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_sanitize(v) for v in obj]
    return json_safe(obj)


@dataclass
class FusionOutcome:
    length: int
    k_eager: int
    k_fused: int                   # Eq. 7 (and actual launch count)
    ideal_speedup: float           # Eq. 8
    eager_host_s: float            # measured host dispatch total
    fused_host_s: float
    measured_speedup: float        # eager host / fused host
    max_abs_err: float             # fused vs eager outputs

    def row(self) -> dict:
        """JSON-safe export dict: ``measured_speedup`` can be ``inf``
        (0-cost fused time) or ``nan`` (0/0) by design — see
        ``_speedup`` — so export paths must go through here."""
        return {
            "length": self.length,
            "k_eager": self.k_eager,
            "k_fused": self.k_fused,
            "ideal_speedup": json_safe(self.ideal_speedup),
            "eager_host_us": round(self.eager_host_s * 1e6, 3),
            "fused_host_us": round(self.fused_host_s * 1e6, 3),
            "measured_speedup": json_safe(self.measured_speedup),
            "max_abs_err": json_safe(self.max_abs_err),
        }


def _speedup(eager_host: float, fused_host: float) -> float:
    """eager/fused with degenerate guards: 0-cost fused time on a nonzero
    eager baseline is an infinite speedup, and 0/0 is undefined — neither
    should silently report 0.0 (i.e. a slowdown)."""
    if fused_host > 0.0:
        return eager_host / fused_host
    return float("inf") if eager_host > 0.0 else float("nan")


def apply_fusion(trace: Trace, *args, length: int = 8,
                 repeats: int = 3) -> FusionOutcome:
    from repro.runtime.executor import PlanExecutor
    from repro.runtime.plan import LaunchPlan

    names = trace.kernel_names
    mining = mine_chains(names, length, threshold=1.0)

    eager = PlanExecutor(trace, LaunchPlan.eager(len(names)))
    fused = PlanExecutor(trace, LaunchPlan.chain(names, length,
                                                 mining=mining))

    t_e = eager.measure_host(*args, repeats=repeats)
    t_f = fused.measure_host(*args, repeats=repeats)

    out_e, _ = eager.run(*args)
    out_f, _ = fused.run(*args)
    import numpy as np
    err = 0.0
    for a, b in zip(out_e, out_f):
        err = max(err, float(np.max(np.abs(np.asarray(a, dtype=np.float64)
                                           - np.asarray(b, dtype=np.float64)))))

    eager_host = sum(t_e)
    fused_host = sum(t_f)
    return FusionOutcome(
        length=length, k_eager=mining.k_eager, k_fused=fused.n_launches,
        ideal_speedup=mining.speedup,
        eager_host_s=eager_host, fused_host_s=fused_host,
        measured_speedup=_speedup(eager_host, fused_host),
        max_abs_err=err)

"""Chain-jit fusion engine — the paper recommends, we implement.

Takes proximity-score recommendations and compiles each deterministic chain
into ONE XLA executable, then executes the workload with the reduced launch
count.  Reports measured dispatch counts and host time against eager, plus
the paper's idealized Eq. 8 speedup for comparison.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.proximity import fusion_segments, mine_chains
from repro.core.tracing import Executor, Trace


@dataclass
class FusionOutcome:
    length: int
    k_eager: int
    k_fused: int                   # Eq. 7 (and actual launch count)
    ideal_speedup: float           # Eq. 8
    eager_host_s: float            # measured host dispatch total
    fused_host_s: float
    measured_speedup: float        # eager host / fused host
    max_abs_err: float             # fused vs eager outputs


def apply_fusion(trace: Trace, *args, length: int = 8,
                 repeats: int = 3) -> FusionOutcome:
    names = trace.kernel_names
    mining = mine_chains(names, length, threshold=1.0)
    segs = fusion_segments(names, length)

    eager = Executor(trace)
    fused = Executor(trace, segments=segs)

    t_e = eager.measure_host(*args, repeats=repeats)
    t_f = fused.measure_host(*args, repeats=repeats)

    out_e, _ = eager.run(*args)
    out_f, _ = fused.run(*args)
    import numpy as np
    err = 0.0
    for a, b in zip(out_e, out_f):
        err = max(err, float(np.max(np.abs(np.asarray(a, dtype=np.float64)
                                           - np.asarray(b, dtype=np.float64)))))

    eager_host = sum(t_e)
    fused_host = sum(t_f)
    return FusionOutcome(
        length=length, k_eager=mining.k_eager, k_fused=len(segs),
        ideal_speedup=mining.speedup,
        eager_host_s=eager_host, fused_host_s=fused_host,
        measured_speedup=eager_host / fused_host if fused_host else 0.0,
        max_abs_err=err)

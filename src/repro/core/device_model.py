"""Platform models + the in-order offload/queue simulator (paper Fig. 4).

Host-side dispatch costs are MEASURED on this machine (core/tracing.py);
device-side kernel durations are MODELED per-kernel as
``max(flops/peak, bytes/bw) + fixed_overhead`` with platform constants from
the paper (Table V launch overheads & nullKernel durations) and public
accelerator specs.  This is the honest CPU-only-container adaptation: the
same trace-driven-simulation methodology as Daydream/TraceSim (both cited by
the paper as the neighbouring tool class).

Simulator semantics (Eq. 1): a kernel's launch call begins on the host at
``ts_b(l)``; the kernel starts executing at
``max(host launch done, device free)``; ``t_l = kernel_start - ts_b(l)``;
TKLQT = sum of t_l (Eq. 2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence


@dataclass(frozen=True)
class PlatformSpec:
    name: str
    coupling: str                  # LC | CC | TC | host
    launch_overhead_ns: float      # nullKernel launch overhead (Table V)
    null_duration_ns: float        # nullKernel execution time (Table V)
    peak_flops: float              # fp16/bf16 dense
    hbm_bw: float                  # bytes/s
    # per-op CPU framework tax BEYOND the null launch (python/op-prep work);
    # scales inversely with CPU single-thread performance — this is the
    # paper's key low-batch finding: Grace's weaker single-thread perf makes
    # GH200 *slower* below the crossover despite the faster GPU.
    op_tax_ns: float = 6000.0
    mxu_efficiency: float = 0.4    # attainable fraction of peak for GEMMs
    bw_efficiency: float = 0.7
    # host<->device coupling fabric (the LC-vs-CC axis): sustained one-way
    # bandwidth of the link KV blocks cross when offloaded to host memory
    # (PCIe for LC parts, NVLink-C2C for CC parts) plus a per-transfer
    # latency floor.  This prices the paged-KV offload tier.
    link_bw: float = 32e9          # bytes/s, one direction
    link_lat_s: float = 10e-6      # per-transfer setup latency
    link_efficiency: float = 0.8   # attainable fraction of peak link bw

    @property
    def host_cost_ns(self) -> float:
        return self.launch_overhead_ns + self.op_tax_ns


# Table V launch/duration numbers; public specs for compute/bandwidth;
# op_tax = 6 us reference (Xeon 8468V) / relative single-thread perf
# (EPYC 7313 ~0.9x, Grace Neoverse-V2 ~0.4x per the paper's observations).
PLATFORMS = {
    # LC: AMD EPYC 7313 + A100-SXM4-80GB (312 TF fp16 dense, 2.04 TB/s);
    # host link PCIe Gen4 x16 (~32 GB/s/dir)
    "AMD+A100": PlatformSpec("AMD+A100", "LC", 2260.5, 1440.0,
                             312e12, 2.039e12, op_tax_ns=6650.0,
                             link_bw=32e9),
    # LC: 2P Xeon 8468V + H100 PCIe (756 TF fp16 dense, 2.0 TB/s);
    # host link PCIe Gen5 x16 (~64 GB/s/dir)
    "Intel+H100": PlatformSpec("Intel+H100", "LC", 2374.6, 1235.2,
                               756e12, 2.0e12, op_tax_ns=6000.0,
                               link_bw=64e9),
    # CC: GH200 (Grace + H100-SXM-class 96GB HBM3, ~990 TF fp16, 3.35 TB/s);
    # host link NVLink-C2C (~450 GB/s/dir) with a much lower setup latency
    "GH200": PlatformSpec("GH200", "CC", 2771.6, 1171.2,
                          989e12, 3.35e12, op_tax_ns=15000.0,
                          link_bw=450e9, link_lat_s=2e-6),
    # the TPU target of this repo (per chip); PCIe-attached host
    "TPU-v5e": PlatformSpec("TPU-v5e", "CC", 2500.0, 1200.0,
                            197e12, 819e9, op_tax_ns=6000.0,
                            link_bw=32e9),
}


@dataclass
class KernelEvent:
    """One simulated kernel launch+execution (timeline entry)."""
    name: str
    launch_begin: float            # ts_b(l)
    launch_end: float              # host done issuing the call
    kernel_start: float            # ts_b(k)
    kernel_end: float              # ts_e(k)
    operator: str = ""             # issuing model operator (provenance tag)

    @property
    def t_l(self) -> float:        # Eq. 1
        return self.kernel_start - self.launch_begin

    @property
    def t_launch(self) -> float:   # pure host launch component
        return self.launch_end - self.launch_begin

    @property
    def t_queue(self) -> float:    # queuing component of t_l
        return self.kernel_start - self.launch_end

    @property
    def duration(self) -> float:
        return self.kernel_end - self.kernel_start


@dataclass
class DispatchDecomposition:
    """Per-kernel launch/queue/exec breakdown of one simulated timeline.

    TKLQT (Eq. 2) stops being one opaque scalar: for every kernel,
    ``t_l = t_launch + t_queue`` with queue time = max(0, host-issue done
    − device free), so ``tklqt_s`` below is a *real sum over kernels*
    that per-operator attribution can slice."""
    rows: list                     # [(name, operator, launch_s, queue_s, exec_s)]
    launch_s: float
    queue_s: float
    exec_s: float

    @property
    def tklqt_s(self) -> float:
        return self.launch_s + self.queue_s


def decompose_events(events: Sequence) -> DispatchDecomposition:
    """Break a KernelEvent timeline into launch/queue/exec components."""
    rows = []
    launch = queue = exec_ = 0.0
    for e in events:
        rows.append((e.name, getattr(e, "operator", ""),
                     e.t_launch, e.t_queue, e.duration))
        launch += e.t_launch
        queue += e.t_queue
        exec_ += e.duration
    return DispatchDecomposition(rows, launch, queue, exec_)


def offload_cost_s(platform: PlatformSpec, nbytes: float,
                   transfers: int = 1) -> float:
    """Modeled host<->device transfer time for ``nbytes`` of KV blocks
    crossing the coupling fabric in ``transfers`` separate copies.

    This is the offload tax the paged KV cache pays per eviction/restore:
    a per-transfer latency floor (PCIe doorbell / C2C handshake) plus the
    bytes over the sustained link bandwidth.  LC (PCIe) and CC (C2C)
    platforms differ by an order of magnitude here — the axis the paper's
    coupling story predicts should dominate the offload/recompute tradeoff.
    """
    if nbytes < 0:
        raise ValueError(f"nbytes must be >= 0, got {nbytes}")
    return (transfers * platform.link_lat_s
            + nbytes / (platform.link_bw * platform.link_efficiency))


def allreduce_cost_s(platform: PlatformSpec, nbytes: float,
                     tp: int = 1) -> float:
    """Modeled time for one all-reduce of ``nbytes`` payload across a
    ``tp``-way tensor-parallel group.

    Ring all-reduce wire model: each device sends/receives
    ``2*(tp-1)/tp * nbytes`` over the inter-device fabric, paid at the
    platform's sustained link bandwidth, plus a per-hop latency floor —
    ``2*(tp-1)`` ring steps.  On LC parts the TP fabric is the same
    PCIe complex the KV offload crosses; on CC parts it is NVLink-class,
    so the same ``link_bw`` axis that separates LC/CC offload tax also
    separates their collective tax (Kundu et al.'s distributed-inference
    model collapses to this term for decode-size payloads, where latency
    floors dominate bandwidth).
    """
    if nbytes < 0:
        raise ValueError(f"nbytes must be >= 0, got {nbytes}")
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if tp == 1:
        return 0.0
    steps = 2 * (tp - 1)
    wire = 2.0 * (tp - 1) / tp * nbytes
    return (steps * platform.link_lat_s
            + wire / (platform.link_bw * platform.link_efficiency))


def dispatch_fanout_s(platform: PlatformSpec, tp: int = 1) -> float:
    """Modeled host cost of issuing ONE logical launch to ``tp`` device
    streams: the CPU pays the per-launch overhead once per device (the
    driver enqueues per-stream), which is exactly how kernel-launch
    overheads multiply with device count in multi-GPU serving (Chung et
    al.) — the CPU-bound region widens with tp."""
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    return platform.host_cost_ns * 1e-9 * tp


def kernel_duration(platform: PlatformSpec, flops: float, bts: float) -> float:
    """Modeled device time (seconds) for one kernel."""
    t_c = flops / (platform.peak_flops * platform.mxu_efficiency)
    t_m = bts / (platform.hbm_bw * platform.bw_efficiency)
    return max(t_c, t_m) + platform.null_duration_ns * 1e-9


def simulate(kernels: Sequence, platform: PlatformSpec, *,
             batch_scale: float = 1.0,
             host_scale: Optional[Sequence[float]] = None) -> list[KernelEvent]:
    """Run the in-order queue model over a kernel list.

    kernels: objects with .name, .flops, .bytes and optional
             .host_dispatch_s (measured host time for this op).
    batch_scale: multiply flops/bytes (trace-once, sweep-batch analytically —
                 every kernel in these workloads is linear in batch).
    host_scale: optional per-kernel relative host cost (measured host time /
                measured null time); launch_i = platform_launch * rel_i.
    """
    t_host = 0.0
    device_free = 0.0
    events = []
    base_launch = platform.host_cost_ns * 1e-9
    for i, k in enumerate(kernels):
        rel = 1.0
        if host_scale is not None:
            rel = max(host_scale[i], 1.0)
        launch = base_launch * rel
        launch_begin = t_host
        t_host = t_host + launch                 # host issues the call, moves on
        dur = kernel_duration(platform, k.flops * batch_scale,
                              k.bytes * batch_scale)
        start = max(t_host, device_free)         # queue behind running kernels
        end = start + dur
        device_free = end
        events.append(KernelEvent(k.name, launch_begin, t_host, start, end,
                                  operator=getattr(k, "operator", "")))
    return events

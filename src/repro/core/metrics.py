"""SKIP metrics (paper Eqs. 1-5) computed over a simulated/measured timeline."""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from typing import Sequence

from repro.core.device_model import KernelEvent


@dataclass
class SkipReport:
    platform: str
    n_kernels: int
    tklqt: float                  # Eq. 2: sum of launch+queue times
    akd: float                    # Eq. 3: average kernel duration
    il: float                     # Eq. 4: inference latency
    gpu_idle: float               # Eq. 5: IL - sum kernel durations
    cpu_idle: float               # IL - host busy time
    queue_share: float            # fraction of TKLQT that is queuing
    top_k: list                   # [(kernel name, count, total launch tax)]

    def row(self) -> dict:
        return {
            "platform": self.platform, "n_kernels": self.n_kernels,
            "tklqt_us": self.tklqt * 1e6, "akd_us": self.akd * 1e6,
            "il_us": self.il * 1e6, "gpu_idle_us": self.gpu_idle * 1e6,
            "cpu_idle_us": self.cpu_idle * 1e6,
            "queue_share": self.queue_share,
        }


def report(events: Sequence[KernelEvent], platform: str,
           launch_overhead_s: float, k: int = 5) -> SkipReport:
    n = len(events)
    tklqt = sum(e.t_l for e in events)                       # Eq. 2
    durs = sum(e.duration for e in events)
    akd = durs / n if n else 0.0                             # Eq. 3
    il = (events[-1].kernel_end - events[0].launch_begin) if n else 0.0  # Eq. 4
    gpu_idle = il - durs                                     # Eq. 5
    host_busy = sum(e.t_launch for e in events)
    cpu_idle = max(il - host_busy, 0.0)
    queue = sum(e.t_queue for e in events)
    queue_share = queue / tklqt if tklqt else 0.0

    tax = Counter()
    cnt = Counter()
    for e in events:
        tax[e.name] += e.t_l
        cnt[e.name] += 1
    top = sorted(tax, key=tax.get, reverse=True)[:k]
    top_k = [(name, cnt[name], tax[name]) for name in top]
    return SkipReport(platform, n, tklqt, akd, il, gpu_idle, cpu_idle,
                      queue_share, top_k)

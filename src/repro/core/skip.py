"""SKIP facade: trace -> measure -> simulate -> classify -> plan -> execute.

Since the launch-plan runtime refactor, SKIP is a thin convenience layer
over ``repro.runtime``: tracing produces a ``Trace``, every execution path
(eager, chain-fused, whole-graph, cost-aware auto) is a ``LaunchPlan``,
``Planner`` compares candidate plans analytically against the TKLQT device
model, and ``PlanExecutor`` compiles/caches/runs the winner.  The legacy
methods below keep their signatures and delegate.

Typical use:

    skip = SKIP.trace(forward_fn, *example_args)
    skip.measure_host()                      # real dispatch costs, this host
    rep = skip.report("GH200", batch=8)      # modeled platform timeline
    sweep = skip.batch_sweep("GH200")        # TKLQT curve + inflection
    recs = skip.recommend(length=16)         # PS=1 chains (Eq. 6)
    outcome = skip.fuse(length=16)           # chain plan: fuse + measure
    choice = skip.plan("GH200")              # cost-aware auto LaunchPlan
    ex = skip.executor(choice.plan)          # compiled-segment executor

    res = SKIP.characterize(cfg, params,     # MEASURED serving sweep:
        scenario="chatbot", batches=(1,2,4)) # scenario x batch telemetry
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Optional, Sequence, Union

from repro.core import boundedness as bnd
from repro.core import proximity as prox
from repro.core.device_model import PLATFORMS, PlatformSpec, simulate
from repro.core.fusion import FusionOutcome, apply_fusion
from repro.core.metrics import SkipReport, report
from repro.core.tracing import Trace, trace_fn

# NOTE: repro.runtime is imported lazily inside methods — importing it at
# module top would close a cycle (runtime -> core.tracing -> core ->
# skip -> runtime) and break `import repro.runtime` as a first import.
if TYPE_CHECKING:  # pragma: no cover
    from repro.runtime import LaunchPlan, PlanChoice, PlanExecutor, Planner

DEFAULT_BATCHES = (1, 2, 4, 8, 16, 32, 64, 128)


@dataclass
class SKIP:
    trace_: Trace
    args: tuple
    base_batch: int = 1
    host_measured: bool = False

    # ------------------------------------------------------------ build
    @classmethod
    def trace(cls, fn, *args, base_batch: int = 1) -> "SKIP":
        return cls(trace_=trace_fn(fn, *args), args=args,
                   base_batch=base_batch)

    def measure_host(self, repeats: int = 3):
        from repro.runtime import PlanExecutor
        PlanExecutor(self.trace_).measure_host(*self.args, repeats=repeats)
        self.host_measured = True

    # ------------------------------------------------------------ modeling
    def _host_scale(self):
        if not self.host_measured:
            return None
        ts = [k.host_dispatch_s for k in self.trace_.kernels]
        null = min(t for t in ts if t > 0) if any(ts) else 1.0
        return [t / null if t > 0 else 1.0 for t in ts]

    def timeline(self, platform: str, batch: Optional[int] = None,
                 use_host_scale: bool = True):
        """use_host_scale=True: launch costs follow THIS host's measured
        per-op dispatch profile (JAX eager reality).  False: the platform's
        nullKernel constant for every op (the paper's C++-runtime physics —
        use for reproducing paper figures)."""
        spec = PLATFORMS[platform]
        scale = (batch or self.base_batch) / self.base_batch
        hs = self._host_scale() if use_host_scale else None
        return simulate(self.trace_.kernels, spec, batch_scale=scale,
                        host_scale=hs)

    def report(self, platform: str, batch: Optional[int] = None,
               top_k: int = 5, use_host_scale: bool = True) -> SkipReport:
        spec = PLATFORMS[platform]
        ev = self.timeline(platform, batch, use_host_scale=use_host_scale)
        return report(ev, platform, spec.launch_overhead_ns * 1e-9, k=top_k)

    def batch_sweep(self, platform: str,
                    batches: Sequence[int] = DEFAULT_BATCHES,
                    use_host_scale: bool = True):
        reps = [self.report(platform, b, use_host_scale=use_host_scale)
                for b in batches]
        return bnd.classify_sweep(batches, reps), reps

    # ------------------------------------------------------------ planning
    def planner(self, platform: Union[str, PlatformSpec] = "TPU-v5e",
                batch: Optional[int] = None,
                use_host_scale: bool = True) -> "Planner":
        from repro.runtime import Planner
        scale = (batch or self.base_batch) / self.base_batch
        hs = self._host_scale() if use_host_scale else None
        return Planner(self.trace_, platform, batch_scale=scale,
                       host_scale=hs)

    def plan(self, platform: Union[str, PlatformSpec] = "TPU-v5e",
             lengths: Sequence[int] = (2, 4, 8, 16, 32),
             batch: Optional[int] = None) -> "PlanChoice":
        """Cost-aware auto plan: lowest modeled TKLQT among candidates."""
        return self.planner(platform, batch=batch).auto(lengths=lengths)

    def executor(self, plan: Optional["LaunchPlan"] = None) -> "PlanExecutor":
        from repro.runtime import PlanExecutor
        return PlanExecutor(self.trace_, plan)

    # ------------------------------------------------------------ measured
    @staticmethod
    def characterize(cfg, params, **kw):
        """Measured serving characterization: drive the live ServeEngine
        with a named traffic scenario, sweep batch sizes, aggregate
        TTFT/ITL/E2E percentiles and measured launch tax, and classify the
        CPU/GPU-bound inflection from the measured curve.  Thin facade
        over ``repro.telemetry.characterize.characterize`` (same kwargs:
        scenario, batches, plan, platform, n_requests, seed, workload...).
        """
        from repro.telemetry.characterize import characterize
        return characterize(cfg, params, **kw)

    @staticmethod
    def autotune(cfg, params, **kw):
        """Measurement-driven plan autotuning: characterize, gate the
        candidate plans by the measured CPU/GPU-bound region, benchmark
        them on the live engine, and return the persisted-plan-table
        result.  Thin facade over ``repro.runtime.autotune.autotune``.
        """
        from repro.runtime.autotune import autotune
        return autotune(cfg, params, **kw)

    # ------------------------------------------------------------ fusion
    def recommend(self, length: int = 8, threshold: float = 1.0):
        return prox.mine_chains(self.trace_.kernel_names, length, threshold)

    def recommend_sweep(self, lengths=(2, 4, 8, 16, 32, 64, 128, 256)):
        return prox.sweep_lengths(self.trace_.kernel_names, lengths)

    def fuse(self, length: int = 8, repeats: int = 3) -> FusionOutcome:
        return apply_fusion(self.trace_, *self.args, length=length,
                            repeats=repeats)

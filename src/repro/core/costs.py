"""Per-primitive FLOP/byte cost model for traced kernels.

Used by the device model to derive modeled kernel durations on each
platform (per-kernel roofline: max(flops/peak, bytes/bw) + fixed overhead).
"""
from __future__ import annotations

import math


def _numel(aval) -> int:
    return math.prod(aval.shape) if aval.shape else 1


def _bytes(aval) -> int:
    return _numel(aval) * aval.dtype.itemsize


def eqn_costs(eqn) -> tuple[float, float]:
    """Returns (flops, bytes) for one jaxpr eqn."""
    prim = eqn.primitive.name
    in_avals = [v.aval for v in eqn.invars if hasattr(v, "aval")]
    out_avals = [v.aval for v in eqn.outvars if hasattr(v, "aval")]
    in_b = sum(_bytes(a) for a in in_avals if hasattr(a, "shape"))
    out_b = sum(_bytes(a) for a in out_avals if hasattr(a, "shape"))
    bts = in_b + out_b

    if prim == "dot_general":
        dn = eqn.params["dimension_numbers"]
        (lc, rc), (lb, rb) = dn
        lhs = in_avals[0]
        out_elems = sum(_numel(a) for a in out_avals)
        k = math.prod(lhs.shape[d] for d in lc) or 1
        return 2.0 * out_elems * k, bts
    if prim in ("conv_general_dilated",):
        # rough: out_elems * 2 * prod(kernel spatial) * in_channels
        out_elems = sum(_numel(a) for a in out_avals)
        rhs = in_avals[1]
        return 2.0 * out_elems * _numel(rhs) / max(rhs.shape[-1], 1), bts
    if prim in ("exp", "tanh", "log", "logistic", "erf", "rsqrt", "sqrt",
                "sin", "cos", "pow", "cumsum", "cumlogsumexp"):
        return 4.0 * sum(_numel(a) for a in out_avals), bts
    if prim.startswith("reduce_") or prim in ("argmax", "argmin", "sort",
                                              "top_k"):
        return float(sum(_numel(a) for a in in_avals)), bts
    # elementwise / data movement default
    return float(sum(_numel(a) for a in out_avals)), bts

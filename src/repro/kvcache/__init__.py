"""Paged KV-cache subsystem: block-table allocation, pooled device pages,
and a host-memory offload tier priced by the CPU-GPU coupling fabric."""
from repro.kvcache.allocator import BlockPool  # noqa: F401
from repro.kvcache.offload import HostOffloadTier  # noqa: F401
from repro.kvcache.paged import PagedKVCache, default_num_blocks  # noqa: F401

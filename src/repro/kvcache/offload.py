"""Host-memory offload tier for cold KV blocks, priced per coupling fabric.

Evicted blocks are staged in host arrays (the stand-in for pinned host
memory on this CPU-only container) and restored on demand.  Every
transfer is priced through ``core.device_model.offload_cost_s`` with the
platform's host<->device link (PCIe for LC parts, NVLink-C2C for CC), so
telemetry can report the MODELED offload tax per architecture while the
byte counts themselves are measured from real evictions — the same
measured-host / modeled-device split the rest of the repo uses.
"""
from __future__ import annotations

from repro.core.device_model import PLATFORMS, PlatformSpec, offload_cost_s


class HostOffloadTier:
    """Staging store for evicted KV blocks + transfer-cost accounting."""

    def __init__(self, platform, tp: int = 1):
        self.spec: PlatformSpec = (platform if isinstance(platform,
                                                          PlatformSpec)
                                   else PLATFORMS[platform])
        if tp < 1:
            raise ValueError(f"tp must be >= 1, got {tp}")
        # under tensor parallelism the KV pages are head-sharded, so each
        # device stages only its 1/tp slice over its own host link — the
        # per-device bytes (what each DMA engine actually moves) are what
        # the link pricing sees, and the shards transfer concurrently
        self.tp = tp
        self._store: dict = {}       # rid -> (host leaf arrays, n_blocks)
        self.offload_bytes = 0
        self.restore_bytes = 0
        self.evictions = 0
        self.restores = 0
        self.modeled_tax_s = 0.0     # total transfer time over the link
        self._m_bytes = None
        self._m_moves = None
        self._m_tax = None

    def bind_metrics(self, registry) -> None:
        """Publish transfer accounting into a ``MetricsRegistry``; the
        ``direction`` label separates evictions from restores."""
        self._m_bytes = registry.counter(
            "kvcache_offload_bytes_total",
            "per-device bytes moved over the host link",
            labels=("direction",))
        self._m_moves = registry.counter(
            "kvcache_offload_transfers_total",
            "eviction/restore operations", labels=("direction",))
        self._m_tax = registry.counter(
            "kvcache_offload_modeled_tax_seconds_total",
            "modeled host-link transfer time")

    def _charge(self, direction: str, nbytes: int, tax: float) -> None:
        if self._m_bytes is not None:
            self._m_bytes.inc(nbytes, direction=direction)
            self._m_moves.inc(direction=direction)
            self._m_tax.inc(tax)

    def holds(self, rid) -> bool:
        return rid in self._store

    def stored_blocks(self, rid) -> int:
        return self._store[rid][1] if rid in self._store else 0

    def evict(self, rid, host_leaves: list, n_blocks: int) -> tuple:
        """Stage ``rid``'s gathered pages host-side; returns
        (bytes_moved, modeled_transfer_s).  One DMA per block is the
        transfer count the latency floor multiplies — paged eviction is
        many small copies, exactly where a high-latency LC link hurts
        most.  This is the single pricing site: callers surface the
        returned tax rather than re-deriving it."""
        nbytes = sum(a.nbytes for a in host_leaves) // self.tp
        tax = offload_cost_s(self.spec, nbytes, transfers=max(n_blocks, 1))
        self._store[rid] = (host_leaves, n_blocks)
        self.offload_bytes += nbytes
        self.evictions += 1
        self.modeled_tax_s += tax
        self._charge("evict", nbytes, tax)
        return nbytes, tax

    def restore(self, rid) -> tuple:
        """Pop ``rid``'s staged pages for scatter back to device; returns
        (host_leaves, n_blocks, bytes_moved, modeled_transfer_s)."""
        host_leaves, n_blocks = self._store.pop(rid)
        nbytes = sum(a.nbytes for a in host_leaves) // self.tp
        tax = offload_cost_s(self.spec, nbytes, transfers=max(n_blocks, 1))
        self.restore_bytes += nbytes
        self.restores += 1
        self.modeled_tax_s += tax
        self._charge("restore", nbytes, tax)
        return host_leaves, n_blocks, nbytes, tax

    def drop(self, rid) -> None:
        """Forget a finished request's staged blocks (if any)."""
        self._store.pop(rid, None)

    def clear(self) -> None:
        self._store.clear()
        self.offload_bytes = 0
        self.restore_bytes = 0
        self.evictions = 0
        self.restores = 0
        self.modeled_tax_s = 0.0

"""Paged KV cache: pool bookkeeping + functional ops on the pages pytree.

``PagedKVCache`` owns the geometry (block size, pool size, blocks per
slot) and the ``BlockPool`` allocator; the device pages themselves are a
plain cache pytree (``models.make_paged_cache`` — leaves shaped
``(n_superblocks, P, bs, HKV, hd)``) that the engine threads through
``forward`` functionally.  Methods that touch pages take and return the
pytree rather than mutating hidden state, so jit boundaries stay clean.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.inference.kv_quant import KV_DTYPES
from repro.kvcache.allocator import BlockPool
from repro.models import make_paged_cache


class PagedKVCache:
    """Geometry + allocator for a block-table paged KV cache."""

    def __init__(self, cfg, *, num_blocks: int, block_size: int,
                 max_len: int, dtype=None, kv_dtype: str = "bf16"):
        if max_len < 1:
            raise ValueError(f"max_len must be >= 1, got {max_len}")
        if kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"kv_dtype must be one of {KV_DTYPES}, got {kv_dtype!r}")
        self.cfg = cfg
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.max_len = max_len
        self.kv_dtype = kv_dtype
        # every block-table row spans the full max_len so the gathered
        # logical view has ONE static shape (ceil(max_len/bs) pages) —
        # no recompiles as sequences grow, and bitwise-comparable masked
        # attention against the contiguous cache when bs divides max_len
        self.nb_per_slot = -(-max_len // block_size)
        self.pool = BlockPool(num_blocks, block_size)
        self.dtype = dtype or cfg.cdtype

    # page id guaranteed out of range: scatters drop it, gathers clamp it
    @property
    def sentinel(self) -> int:
        return self.num_blocks

    def make_pages(self):
        """Fresh zeroed pages pytree for ``forward`` (quantized layout when
        ``kv_dtype="int8"``).  Stamps the pool with the per-block byte
        size so ``kv_bytes_saved`` prices shared blocks correctly."""
        pages = make_paged_cache(self.cfg, self.num_blocks, self.block_size,
                                 self.dtype, kv_dtype=self.kv_dtype)
        self.pool.block_bytes = self.block_bytes(pages, 1)
        return pages

    # ------------------------------------------------------------ tables
    def table_row(self, owner) -> np.ndarray:
        return self.pool.table_row(owner, self.nb_per_slot, self.sentinel)

    def block_tables(self, owners: list) -> np.ndarray:
        """(B, nb_per_slot) int32 table; ``None`` entries (inactive rows)
        become all-sentinel rows whose writes are dropped."""
        rows = [self.table_row(o) if o is not None
                else np.full(self.nb_per_slot, self.sentinel, np.int32)
                for o in owners]
        return np.stack(rows).astype(np.int32)

    # ------------------------------------------------- functional page ops
    def zero_pages(self, pages, ids: list):
        """Copy-on-free: zero-fill the freed pages before the pool hands
        them to the next owner (no cross-request KV leaks, and masked
        attention over stale entries stays exact-zero)."""
        if not ids:
            return pages
        idx = jnp.asarray(ids, jnp.int32)
        return jax.tree.map(lambda p: p.at[:, idx].set(0), pages)

    def gather_host(self, pages, ids: list) -> list:
        """Copy ``ids``' page contents device->host (the offload DMA);
        returns one np.ndarray per cache leaf, in jax.tree.leaves order."""
        idx = jnp.asarray(ids, jnp.int32)
        return [np.asarray(leaf[:, idx]) for leaf in jax.tree.leaves(pages)]

    def scatter_host(self, pages, ids: list, host_leaves: list):
        """Copy host->device into freshly allocated pages (the restore)."""
        idx = jnp.asarray(ids, jnp.int32)
        leaves, treedef = jax.tree.flatten(pages)
        new = [leaf.at[:, idx].set(jnp.asarray(h).astype(leaf.dtype))
               for leaf, h in zip(leaves, host_leaves)]
        return jax.tree.unflatten(treedef, new)

    def copy_pages(self, pages, src_id: int, dst_id: int):
        """Copy-on-write divergence: duplicate block ``src_id``'s page
        contents into freshly allocated block ``dst_id`` across every
        cache leaf, so the subsequent write lands on a private copy."""
        return jax.tree.map(lambda p: p.at[:, dst_id].set(p[:, src_id]),
                            pages)

    def block_bytes(self, pages, n_blocks: int = 1) -> int:
        """Bytes of KV held by ``n_blocks`` pool blocks across all layers."""
        total = 0
        for leaf in jax.tree.leaves(pages):
            per_block = leaf.dtype.itemsize * int(np.prod(
                (leaf.shape[0],) + leaf.shape[2:]))
            total += per_block * n_blocks
        return total

    def reset(self) -> None:
        block_bytes = self.pool.block_bytes
        self.pool = BlockPool(self.num_blocks, self.block_size)
        self.pool.block_bytes = block_bytes


def default_num_blocks(max_batch: int, max_len: int, block_size: int,
                       num_blocks: Optional[int] = None,
                       kv_dtype: str = "bf16",
                       hd: Optional[int] = None,
                       payload_bytes: int = 2) -> int:
    """Pool size: explicit, else sized by KV BYTES — enough bytes for
    every slot at full length in the native cache dtype
    (capacity-equivalent to the contiguous cache).  A quantized pool
    holds the SAME byte budget, so with ``kv_dtype="int8"`` (and ``hd``
    given, for the per-entry byte math) the default grows by
    ``payload_bytes*hd / (hd+4)`` blocks (~1.88x for bf16 at hd=64) —
    that's where the extra admission capacity comes from.
    ``payload_bytes`` is the native dtype's itemsize (2 for bf16)."""
    if num_blocks is not None:
        if num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {num_blocks}")
        return num_blocks
    base = max_batch * (-(-max_len // block_size))
    if kv_dtype == "bf16" or hd is None:
        return base
    ratio = (payload_bytes * hd) / (hd + 4)
    return int(base * ratio)

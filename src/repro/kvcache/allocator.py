"""Block-table paged KV allocation: fixed-size token pages from one pool.

The pool is pure host-side bookkeeping — device pages live in the cache
pytree (``models.make_paged_cache``); this class only decides WHICH page
ids a sequence owns.  Allocation is deterministic (lowest free id first)
so seeded engine runs place blocks identically run-to-run, and freed ids
return to the pool sorted — the copy-on-free discipline (pages are
zero-filled by the cache layer before reuse) means a fresh allocation
never leaks a previous occupant's KV.
"""
from __future__ import annotations

import numpy as np


class BlockPool:
    """Fixed-size token-block pool with per-owner block lists."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: list[int] = list(range(num_blocks))
        self._owned: dict = {}            # owner -> [block ids, logical order]
        self._m_used = None
        self._m_util = None
        self._m_allocs = None
        self._m_frees = None

    def bind_metrics(self, registry) -> None:
        """Publish pool occupancy into a ``MetricsRegistry``: gauges track
        the live state, counters the cumulative block churn."""
        self._m_used = registry.gauge(
            "kvcache_blocks_used", "KV pages currently owned by sequences")
        self._m_util = registry.gauge(
            "kvcache_block_utilization", "used / total KV pages")
        self._m_allocs = registry.counter(
            "kvcache_blocks_allocated_total", "KV pages handed out")
        self._m_frees = registry.counter(
            "kvcache_blocks_freed_total", "KV pages returned to the pool")
        self._refresh_gauges()

    def _refresh_gauges(self) -> None:
        if self._m_used is not None:
            self._m_used.set(self.used_blocks)
            self._m_util.set(self.utilization)

    # ------------------------------------------------------------ queries
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def utilization(self) -> float:
        return self.used_blocks / self.num_blocks

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` KV entries."""
        return -(-n_tokens // self.block_size)

    def owned(self, owner) -> list:
        return list(self._owned.get(owner, ()))

    def owners(self) -> list:
        return list(self._owned)

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    # ------------------------------------------------------------ mutation
    def alloc(self, owner, n: int) -> list:
        """Append ``n`` blocks to ``owner``'s list; lowest free ids first."""
        if n < 0:
            raise ValueError(f"cannot alloc {n} blocks")
        if n > len(self._free):
            raise MemoryError(
                f"block pool exhausted: want {n}, have {len(self._free)} "
                f"free of {self.num_blocks}")
        ids = self._free[:n]
        del self._free[:n]
        self._owned.setdefault(owner, []).extend(ids)
        if self._m_allocs is not None and n:
            self._m_allocs.inc(n)
        self._refresh_gauges()
        return ids

    def free(self, owner) -> list:
        """Release all of ``owner``'s blocks back to the pool (sorted);
        returns the freed ids so the cache layer can zero those pages."""
        ids = self._owned.pop(owner, [])
        self._free = sorted(self._free + list(ids))
        if self._m_frees is not None and ids:
            self._m_frees.inc(len(ids))
        self._refresh_gauges()
        return list(ids)

    def ensure(self, owner, n_tokens: int) -> list:
        """Grow ``owner`` to cover ``n_tokens`` entries; returns the newly
        allocated ids (empty when already covered).  Raises MemoryError
        when the pool cannot satisfy the growth — the engine's
        evict-or-preempt policy decides what to do then."""
        have = len(self._owned.get(owner, ()))
        need = self.blocks_for(n_tokens)
        if need <= have:
            return []
        return self.alloc(owner, need - have)

    def trim(self, owner, n_tokens: int) -> list:
        """Shrink ``owner`` to the blocks covering ``n_tokens`` entries,
        releasing the tail ids (speculative-decode rollback: blocks grown
        for a verify window whose draft tokens were rejected).  Returns
        the freed ids so the cache layer can zero those pages — same
        copy-on-free discipline as ``free``."""
        ids = self._owned.get(owner)
        keep = self.blocks_for(n_tokens)
        if not ids or len(ids) <= keep:
            return []
        freed = ids[keep:]
        del ids[keep:]
        self._free = sorted(self._free + freed)
        if self._m_frees is not None and freed:
            self._m_frees.inc(len(freed))
        self._refresh_gauges()
        return list(freed)

    def table_row(self, owner, n_entries: int, sentinel: int) -> np.ndarray:
        """(n_entries,) int32 block-table row, padded with ``sentinel``
        (an out-of-range page id: gathers clamp, scatters drop)."""
        row = np.full(n_entries, sentinel, np.int32)
        ids = self._owned.get(owner, ())
        row[:len(ids)] = ids[:n_entries]
        return row

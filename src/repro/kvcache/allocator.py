"""Block-table paged KV allocation: fixed-size token pages from one pool.

The pool is pure host-side bookkeeping — device pages live in the cache
pytree (``models.make_paged_cache``); this class only decides WHICH page
ids a sequence owns.  Allocation is deterministic (lowest free id first)
so seeded engine runs place blocks identically run-to-run, and freed ids
return to the pool sorted — the copy-on-free discipline (pages are
zero-filled by the cache layer before reuse) means a fresh allocation
never leaks a previous occupant's KV.

Blocks are reference-counted so prefix sharing can map several owners'
leading block-table entries onto ONE physical page: ``adopt`` raises a
block's refcount into a second owner's list, ``free``/``trim`` only
return a block to the free list when its last reference drops, and
``cow`` implements copy-on-write — before an owner writes into a block
it shares, the engine swaps in a fresh private block and copies the page
contents (copy-then-divergence).
"""
from __future__ import annotations

import numpy as np


class BlockPool:
    """Fixed-size token-block pool with per-owner block lists."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: list[int] = list(range(num_blocks))
        self._owned: dict = {}            # owner -> [block ids, logical order]
        self._refs: dict[int, int] = {}   # block id -> reference count
        self.cow_copies_total = 0         # cumulative copy-on-write events
        self.peak_shared_blocks = 0       # high-water mark of shared pages
        self.block_bytes = 0              # per-block KV bytes (set by cache)
        self._m_used = None
        self._m_util = None
        self._m_allocs = None
        self._m_frees = None
        self._m_shared = None
        self._m_cow = None
        self._m_saved = None

    def bind_metrics(self, registry) -> None:
        """Publish pool occupancy into a ``MetricsRegistry``: gauges track
        the live state, counters the cumulative block churn."""
        self._m_used = registry.gauge(
            "kvcache_blocks_used", "KV pages currently owned by sequences")
        self._m_util = registry.gauge(
            "kvcache_block_utilization", "used / total KV pages")
        self._m_allocs = registry.counter(
            "kvcache_blocks_allocated_total", "KV pages handed out")
        self._m_frees = registry.counter(
            "kvcache_blocks_freed_total", "KV pages returned to the pool")
        self._m_shared = registry.gauge(
            "kv_shared_blocks", "KV pages with more than one live owner")
        self._m_cow = registry.counter(
            "kv_cow_copies_total", "copy-on-write page divergences")
        self._m_saved = registry.gauge(
            "kv_bytes_saved", "KV bytes deduplicated by prefix sharing")
        self._m_cow.inc(self.cow_copies_total)
        self._refresh_gauges()

    def _refresh_gauges(self) -> None:
        shared = self.shared_blocks
        if shared > self.peak_shared_blocks:
            self.peak_shared_blocks = shared
        if self._m_used is not None:
            self._m_used.set(self.used_blocks)
            self._m_util.set(self.utilization)
        if self._m_shared is not None:
            self._m_shared.set(shared)
            self._m_saved.set(self.bytes_saved)

    # ------------------------------------------------------------ queries
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def utilization(self) -> float:
        return self.used_blocks / self.num_blocks

    @property
    def shared_blocks(self) -> int:
        """Physical blocks currently referenced by more than one owner."""
        return sum(1 for r in self._refs.values() if r > 1)

    @property
    def extra_refs(self) -> int:
        """References beyond the first — each one is a whole block some
        owner did NOT have to allocate."""
        return sum(r - 1 for r in self._refs.values())

    @property
    def bytes_saved(self) -> int:
        """KV bytes deduplicated by sharing (``block_bytes`` is stamped by
        the cache layer once the pages pytree exists)."""
        return self.extra_refs * self.block_bytes

    def ref_count(self, bid: int) -> int:
        """Live references to block ``bid`` (0 when free)."""
        return self._refs.get(bid, 0)

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` KV entries."""
        return -(-n_tokens // self.block_size)

    def owned(self, owner) -> list:
        return list(self._owned.get(owner, ()))

    def owners(self) -> list:
        return list(self._owned)

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    # ------------------------------------------------------------ mutation
    def alloc(self, owner, n: int) -> list:
        """Append ``n`` blocks to ``owner``'s list; lowest free ids first."""
        if n < 0:
            raise ValueError(f"cannot alloc {n} blocks")
        if n > len(self._free):
            raise MemoryError(
                f"block pool exhausted: want {n}, have {len(self._free)} "
                f"free of {self.num_blocks}")
        ids = self._free[:n]
        del self._free[:n]
        self._owned.setdefault(owner, []).extend(ids)
        for bid in ids:
            self._refs[bid] = 1
        if self._m_allocs is not None and n:
            self._m_allocs.inc(n)
        self._refresh_gauges()
        return ids

    def adopt(self, owner, ids: list) -> list:
        """Map ``ids`` (another owner's live blocks, logical order) into
        ``owner``'s list WITHOUT allocating: each block's refcount rises
        and the physical page is shared until a ``cow`` diverges it.
        Returns the adopted ids."""
        for bid in ids:
            if self._refs.get(bid, 0) < 1:
                raise ValueError(f"cannot adopt free block {bid}")
        own = self._owned.setdefault(owner, [])
        for bid in ids:
            self._refs[bid] += 1
            own.append(bid)
        self._refresh_gauges()
        return list(ids)

    def cow(self, owner, index: int) -> tuple:
        """Copy-on-write: ``owner`` is about to write into the shared block
        at position ``index`` of its list — swap in a fresh private block
        and drop the shared reference.  Returns ``(old_id, new_id)`` so
        the cache layer copies the page contents before the write lands.
        Raises MemoryError when no free block exists (the engine's
        evict-or-preempt policy decides what to do then)."""
        ids = self._owned.get(owner)
        if not ids or index >= len(ids):
            raise ValueError(f"{owner!r} has no block at index {index}")
        old = ids[index]
        if self._refs.get(old, 0) < 2:
            raise ValueError(f"block {old} is not shared; cow is a no-op")
        if not self._free:
            raise MemoryError(
                f"block pool exhausted: cow needs 1 free block of "
                f"{self.num_blocks}")
        new = self._free.pop(0)
        self._refs[new] = 1
        self._refs[old] -= 1
        ids[index] = new
        self.cow_copies_total += 1
        if self._m_allocs is not None:
            self._m_allocs.inc(1)
        if self._m_cow is not None:
            self._m_cow.inc(1)
        self._refresh_gauges()
        return old, new

    def _drop_refs(self, ids: list) -> list:
        """Decrement refcounts; return the ids whose LAST reference dropped
        (only those return to the free list / get zeroed)."""
        physical = []
        for bid in ids:
            n = self._refs.get(bid, 0) - 1
            if n <= 0:
                self._refs.pop(bid, None)
                physical.append(bid)
            else:
                self._refs[bid] = n
        return physical

    def free(self, owner) -> list:
        """Release all of ``owner``'s blocks; blocks still referenced by a
        sharer survive untouched.  Returns the PHYSICALLY freed ids so the
        cache layer can zero those pages."""
        ids = self._owned.pop(owner, [])
        physical = self._drop_refs(ids)
        self._free = sorted(self._free + physical)
        if self._m_frees is not None and physical:
            self._m_frees.inc(len(physical))
        self._refresh_gauges()
        return physical

    def ensure(self, owner, n_tokens: int) -> list:
        """Grow ``owner`` to cover ``n_tokens`` entries; returns the newly
        allocated ids (empty when already covered).  Raises MemoryError
        when the pool cannot satisfy the growth — the engine's
        evict-or-preempt policy decides what to do then."""
        have = len(self._owned.get(owner, ()))
        need = self.blocks_for(n_tokens)
        if need <= have:
            return []
        return self.alloc(owner, need - have)

    def trim(self, owner, n_tokens: int) -> list:
        """Shrink ``owner`` to the blocks covering ``n_tokens`` entries,
        releasing the tail ids (speculative-decode rollback: blocks grown
        for a verify window whose draft tokens were rejected).  Returns
        the freed ids so the cache layer can zero those pages — same
        copy-on-free discipline as ``free``."""
        ids = self._owned.get(owner)
        keep = self.blocks_for(n_tokens)
        if not ids or len(ids) <= keep:
            return []
        dropped = ids[keep:]
        del ids[keep:]
        physical = self._drop_refs(dropped)
        self._free = sorted(self._free + physical)
        if self._m_frees is not None and physical:
            self._m_frees.inc(len(physical))
        self._refresh_gauges()
        return physical

    def table_row(self, owner, n_entries: int, sentinel: int) -> np.ndarray:
        """(n_entries,) int32 block-table row, padded with ``sentinel``
        (an out-of-range page id: gathers clamp, scatters drop)."""
        row = np.full(n_entries, sentinel, np.int32)
        ids = self._owned.get(owner, ())
        row[:len(ids)] = ids[:n_entries]
        return row

"""Gemma2-27B — local/global alternating attention, logit softcaps
[arXiv:2408.00118].  head_dim=128 (d_model/n_heads=144 is NOT the head dim
for gemma2-27b; it uses 32 heads x 128)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    block_pattern=("attn_local", "attn"),   # alternating local/global
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    embed_scale=True,
    tie_embeddings=True,
    act="gelu",
    attn_scale=0.06250,                      # gemma2 query_pre_attn_scalar=(d/h)
))

"""Kimi K2 — trillion-param MoE, 384 experts top-8 + 1 shared
[arXiv:2501.kimi2; paper-table, unverified]."""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=2048,
    vocab_size=163840,
    block_pattern=("attn",),
    moe_slots=(0,),
    moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048, n_shared_experts=1,
                  capacity_factor=1.0, dispatch_chunks=4),
))

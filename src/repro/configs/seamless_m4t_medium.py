"""SeamlessM4T-medium backbone — encoder-decoder transformer
[arXiv:2308.11596].  The speech/text frontend is a STUB: input_specs()
provides precomputed frame embeddings (per assignment spec)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,                 # decoder depth
    n_encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    block_pattern=("attn",),
    act="gelu",
    glu=False,
    frontend="audio_frames",
    n_frontend_tokens=4096,      # encoder frame-embedding length (stub)
))

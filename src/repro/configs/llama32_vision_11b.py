"""Llama-3.2-Vision-11B backbone — self-attn decoder with interleaved
cross-attention image layers (every 5th layer) [hf:meta-llama/Llama-3.2-11B-Vision].

Vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (per assignment spec).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    block_pattern=("attn", "attn", "attn", "attn", "xattn"),
    frontend="vision_patches",
    n_frontend_tokens=1601,      # 1 tile x (40x40 patches + cls)
))

"""The paper's own four benchmark workloads (Table III).

Used by the SKIP-JAX reproduction benchmarks (TKLQT sweeps, fusion mining,
platform comparison).  BERT/XLM-R are encoder-only (non-causal, no decode);
GPT2 / Llama-3.2-1B are decoders.
"""
from repro.configs.base import ModelConfig, register

BERT_BASE = register(ModelConfig(
    name="bert-base-uncased",
    family="encoder",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=30522,
    act="gelu",
    glu=False,
))

XLM_ROBERTA = register(ModelConfig(
    name="xlm-roberta-base",
    family="encoder",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=250002,
    act="gelu",
    glu=False,
))

GPT2 = register(ModelConfig(
    name="gpt2",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=50257,
    act="gelu",
    glu=False,
))

LLAMA_32_1B = register(ModelConfig(
    name="llama-3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500_000.0,
    tie_embeddings=True,
))

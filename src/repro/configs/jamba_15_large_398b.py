"""Jamba-1.5-Large 398B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].  Each 8-layer Jamba block: attention at slot 4, Mamba
elsewhere; MoE MLP every other layer (odd slots).

Sub-quadratic-ish: attention layers are 1/8 of the stack and decode is linear
in KV length, so long_500k runs (per assignment: run for hybrid).
"""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    moe_slots=(1, 3, 5, 7),
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576, capacity_factor=1.25,
                  dispatch_chunks=4),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    subquadratic=True,
))

"""RWKV6 (Finch) 3B — attention-free, data-dependent decay [arXiv:2404.05892].

Sub-quadratic: long_500k decode runs with O(1)-per-token recurrent state.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,                  # wkv head size 64
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    block_pattern=("rwkv6",),
    subquadratic=True,
    glu=False,                   # rwkv channel-mix is its own shape
))

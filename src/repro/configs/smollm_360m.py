"""SmolLM-360M — llama-arch small dense GQA [hf:HuggingFaceTB/SmolLM-360M].

15 query heads / 5 kv heads: NOT divisible by the 16-way model axis — the
sharding rules engine falls back to replicating the head axis and shards
d_ff / vocab instead (see repro/distributed/sharding.py).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
))

"""Architecture registry — importing this package registers all configs."""
from repro.configs.base import (  # noqa: F401
    SHAPES, MambaConfig, ModelConfig, MoEConfig, ShapeSpec,
    applicable_shapes, get_config, list_configs, reduced, register,
)

# assigned architectures
from repro.configs import internlm2_20b      # noqa: F401
from repro.configs import codeqwen15_7b      # noqa: F401
from repro.configs import smollm_360m        # noqa: F401
from repro.configs import gemma2_27b         # noqa: F401
from repro.configs import moonshot_v1_16b_a3b  # noqa: F401
from repro.configs import kimi_k2_1t_a32b    # noqa: F401
from repro.configs import seamless_m4t_medium  # noqa: F401
from repro.configs import rwkv6_3b           # noqa: F401
from repro.configs import jamba_15_large_398b  # noqa: F401
from repro.configs import llama32_vision_11b  # noqa: F401

# the paper's own workloads (Table III)
from repro.configs import paper_workloads    # noqa: F401

ASSIGNED = (
    "internlm2-20b",
    "codeqwen1.5-7b",
    "smollm-360m",
    "gemma2-27b",
    "moonshot-v1-16b-a3b",
    "kimi-k2-1t-a32b",
    "seamless-m4t-medium",
    "rwkv6-3b",
    "jamba-1.5-large-398b",
    "llama-3.2-vision-11b",
)

PAPER_WORKLOADS = (
    "bert-base-uncased",
    "xlm-roberta-base",
    "gpt2",
    "llama-3.2-1b",
)

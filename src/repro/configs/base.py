"""Model / run configuration dataclasses and the architecture registry.

Every assigned architecture registers a ``ModelConfig`` here (one module per
arch under ``repro/configs``).  Configs are plain frozen dataclasses so they
hash and can be closed over by jit without retracing surprises.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    n_shared_experts: int = 0     # DeepSeek/Moonlight-style always-on experts
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # EP dispatch: number of token chunks to scan over (bounds dispatch buffer)
    dispatch_chunks: int = 1


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    # --- block pattern -----------------------------------------------------
    # The layer stack is a scan over "superblocks"; each superblock applies
    # `block_pattern` in order.  n_layers must be divisible by len(pattern).
    # Entries: "attn" | "attn_local" | "xattn" | "mamba" | "rwkv6"
    block_pattern: Tuple[str, ...] = ("attn",)
    # Which pattern slots use MoE MLP instead of dense (indices into pattern).
    moe_slots: Tuple[int, ...] = ()
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    # --- attention details --------------------------------------------------
    rope_theta: float = 10000.0
    sliding_window: int = 0       # for "attn_local" entries
    attn_softcap: float = 0.0     # gemma2: 50.0
    final_softcap: float = 0.0    # gemma2: 30.0
    qkv_bias: bool = False        # qwen-style
    attn_scale: float = 0.0       # 0 -> 1/sqrt(head_dim)
    # --- misc ----------------------------------------------------------------
    tie_embeddings: bool = False
    embed_scale: bool = False     # gemma: scale embeddings by sqrt(d_model)
    norm_eps: float = 1e-5
    act: str = "silu"             # silu (swiglu) | gelu (plain mlp, enc-only era)
    glu: bool = True              # gated mlp
    # --- encoder-decoder ------------------------------------------------------
    n_encoder_layers: int = 0     # >0 => enc-dec; n_layers is the decoder depth
    # --- multimodal stubs ------------------------------------------------------
    frontend: str = "none"        # none | audio_frames | vision_patches
    n_frontend_tokens: int = 0    # patches/frames emitted by the stub frontend
    # --- dtypes ---------------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # --- sub-quadratic? (controls long_500k applicability) -------------------
    subquadratic: bool = False

    # ------------------------------------------------------------------ helpers
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_superblocks(self) -> int:
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern len {len(self.block_pattern)}")
        return self.n_layers // len(self.block_pattern)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    """One benchmark cell: an input-shape configuration."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# registry
_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers registration side effects)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """Which benchmark shapes apply to an arch (long_500k only if sub-quadratic)."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.subquadratic:
            continue
        out.append(s.name)
    return out


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    pat = cfg.block_pattern
    kw = dict(
        n_layers=len(pat) * 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=503,           # prime-ish: catches padding assumptions
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.moe is not None:
        # capacity_factor 4.0: tiny-test token counts make Switch-style
        # dropping path-dependent; a generous capacity keeps tests exact.
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2), d_expert=64,
            n_shared_experts=min(cfg.moe.n_shared_experts, 1),
            capacity_factor=4.0)
    if cfg.mamba is not None:
        kw["mamba"] = dataclasses.replace(cfg.mamba, d_state=8, d_conv=4)
    if cfg.n_encoder_layers:
        kw["n_encoder_layers"] = 2
    if cfg.n_frontend_tokens:
        kw["n_frontend_tokens"] = 8
    if cfg.sliding_window:
        kw["sliding_window"] = 8
    kw.update(overrides)
    return cfg.replace(**kw)

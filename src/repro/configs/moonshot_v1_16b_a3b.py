"""Moonlight-16B-A3B (kimi/moonshot) — MoE 64 experts top-6, 2 shared
[hf:moonshotai/Moonlight-16B-A3B]."""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    block_pattern=("attn",),
    moe_slots=(0,),
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared_experts=2),
))

"""LaunchPlan: the unit of the plan -> compile -> execute lifecycle.

A plan partitions a flattened kernel trace into an ordered, exact cover of
contiguous segments.  Each segment compiles to ONE XLA executable, so
``n_launches == len(segments)`` is the dispatch count the paper's TKLQT
model prices.  Strategies:

  eager        one segment per eqn (per-op dispatch, PyTorch-eager analogue)
  whole_graph  one segment for the whole jaxpr (torch.compile analogue)
  chain(L)     proximity-mined deterministic chains of length L (paper Eq. 6)
  auto         cost-aware boundaries from ``runtime.planner.Planner``
  fused        rule windows lowered to fused Pallas kernels
               (``runtime.rules``), remainder from a base plan

``rules`` tags segments that execute as ONE fused kernel instead of an
eqn replay: ``(segment_index, rule_name)`` pairs resolved against the
``runtime.rules`` registry at compile time.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from repro.core.proximity import fusion_segments


def segment_label(kernels: Sequence, seg: Sequence[int]) -> str:
    """Display name of one plan segment: the first member kernel's name,
    prefixed with the fused count when the segment spans several."""
    name = kernels[seg[0]].name
    return name if len(seg) == 1 else f"fused[{len(seg)}]:{name}"


@dataclass(frozen=True)
class LaunchPlan:
    strategy: str                       # eager | whole_graph | chain | auto |
                                        # fused | custom
    segments: tuple                     # tuple[tuple[int, ...], ...]
    length: Optional[int] = None        # chain length, when strategy == "chain"
    rules: tuple = ()                   # tuple[(segment_index, rule_name)]

    @property
    def n_launches(self) -> int:
        return len(self.segments)

    @property
    def n_fused_rules(self) -> int:
        return len(self.rules)

    def rule_names(self) -> list:
        return [name for _, name in self.rules]

    @property
    def n_kernels(self) -> int:
        return sum(len(s) for s in self.segments)

    @property
    def max_segment(self) -> int:
        return max((len(s) for s in self.segments), default=0)

    def key(self) -> tuple:
        """Hashable identity used by the compiled-segment cache."""
        return (self.strategy, self.length, self.segments, self.rules)

    def validate(self, n_kernels: Optional[int] = None) -> "LaunchPlan":
        """Segments must be an exact in-order cover of the kernel indices —
        that is the invariant that makes any plan numerically equivalent to
        eager execution (program order is preserved)."""
        flat = [i for seg in self.segments for i in seg]
        n = n_kernels if n_kernels is not None else len(flat)
        if flat != list(range(n)):
            raise ValueError(
                "plan segments are not an exact in-order cover of "
                f"range({n}): {flat[:8]}...")
        return self

    def describe(self) -> str:
        return (f"LaunchPlan({self.strategy}"
                + (f", L={self.length}" if self.length else "")
                + (f", {self.n_fused_rules} fused" if self.rules else "")
                + f": {self.n_launches} launches / {self.n_kernels} kernels, "
                  f"max segment {self.max_segment})")

    # ------------------------------------------------------------ builders
    @staticmethod
    def eager(n_kernels: int) -> "LaunchPlan":
        return LaunchPlan("eager", tuple((i,) for i in range(n_kernels)))

    @staticmethod
    def whole_graph(n_kernels: int) -> "LaunchPlan":
        return LaunchPlan("whole_graph", (tuple(range(n_kernels)),))

    @staticmethod
    def chain(kernel_names: Sequence[str], length: int,
              mining=None) -> "LaunchPlan":
        segs = fusion_segments(kernel_names, length, mining=mining)
        return LaunchPlan("chain", tuple(tuple(s) for s in segs),
                          length=length).validate(len(kernel_names))

    @staticmethod
    def from_segments(segments: Sequence[Sequence[int]],
                      strategy: str = "custom",
                      length: Optional[int] = None) -> "LaunchPlan":
        return LaunchPlan(strategy, tuple(tuple(s) for s in segments),
                          length=length).validate()

"""Unified launch-plan runtime: one plan -> compile -> execute subsystem.

Everything that turns a flattened kernel trace into dispatched work flows
through here: ``LaunchPlan`` partitions the trace, ``Planner`` picks
boundaries analytically against the TKLQT device model, ``PlanExecutor``
compiles each segment once (process-wide cache) and runs it.  The legacy
entry points — ``core.tracing.Executor``, ``core.fusion.apply_fusion``,
``core.skip.SKIP`` — are thin facades over these types.
"""
from repro.runtime.executor import (PlanExecutor, cache_stats,  # noqa: F401
                                    clear_cache)
from repro.runtime.plan import LaunchPlan                       # noqa: F401
from repro.runtime.planner import (PlanChoice, PlanEvaluation,  # noqa: F401
                                   Planner, simulate_plan)
from repro.runtime.rules import (DEFAULT_RULES, find_matches,  # noqa: F401
                                 fused_plan, get_rule)

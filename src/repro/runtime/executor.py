"""PlanExecutor: compile and run a Trace under a LaunchPlan.

Each plan segment becomes one jitted callable (= one host dispatch, the
``cudaLaunchKernel`` analogue the paper counts).  Compiled segments live in
a process-wide LRU cache keyed by (trace, plan, input shapes/dtypes), so
re-planning or re-instantiating an executor over the SAME trace (e.g.
comparing eager vs chain vs auto during plan search) never pays the
segment-build + jit cost twice.  Distinct traces never share entries —
their jitted closures capture the trace's own constants — which is why
the cache is bounded: old traces' entries age out instead of pinning
their constant arrays forever.
"""
from __future__ import annotations

import statistics
import time
from collections import OrderedDict
from typing import Optional

import jax
import jax.extend.core as jexc

from repro.core.tracing import Trace, _is_drop, _read
from repro.runtime.plan import LaunchPlan, segment_label
from repro.runtime.rules import get_rule, segment_free_outs

# (trace.token, plan.key(), input signature) -> [(jitted fn, free vars, outs)]
_SEG_CACHE: OrderedDict = OrderedDict()
_CACHE_STATS = {"hits": 0, "misses": 0}
_CACHE_MAX_ENTRIES = 64


def cache_stats() -> dict:
    return dict(_CACHE_STATS)


def clear_cache() -> None:
    _SEG_CACHE.clear()
    _CACHE_STATS.update(hits=0, misses=0)


def _args_signature(args) -> tuple:
    """Shape/dtype signature of a flattened arg pytree."""
    sig = []
    for leaf in jax.tree.leaves(args):
        shape = getattr(leaf, "shape", ())
        dtype = getattr(leaf, "dtype", type(leaf).__name__)
        sig.append((tuple(shape), str(dtype)))
    return tuple(sig)


class PlanExecutor:
    """Executes a trace segment-by-segment under a LaunchPlan.

    ``recorder`` (a ``repro.telemetry.spans.SpanRecorder``) captures one
    host-dispatch span per segment launch — the measured counterpart of
    the simulated host lane in ``core.export``.  Timestamps are RAW
    ``perf_counter`` values: fine on their own, but do not share one
    recorder with ``ServeEngine``, whose spans sit on its virtual serving
    clock — the engine instead re-lays these segment times onto its clock
    itself (``_record_segments``) so merged traces stay aligned.
    """

    def __init__(self, trace: Trace, plan: Optional[LaunchPlan] = None, *,
                 recorder=None):
        self.trace = trace
        self.plan = (plan or LaunchPlan.eager(len(trace.kernels)))
        self.plan.validate(len(trace.kernels))
        self.recorder = recorder
        self._compiled = None
        self._seg_ops = None

    def segment_operators(self) -> list:
        """Per-segment {canonical op -> member-kernel count} maps (lazily
        built once per executor; the plan is immutable)."""
        if self._seg_ops is None:
            from repro.telemetry.attribution import segment_ops
            self._seg_ops = [segment_ops(self.trace.kernels, seg)
                             for seg in self.plan.segments]
        return self._seg_ops

    # ------------------------------------------------------------ compile
    def _build(self):
        key = (self.trace.token, self.plan.key(),
               _args_signature(self.trace.example_args))
        cached = _SEG_CACHE.get(key)
        if cached is not None:
            _CACHE_STATS["hits"] += 1
            _SEG_CACHE.move_to_end(key)
            self._compiled = cached
            return cached
        _CACHE_STATS["misses"] += 1

        flat = self.trace.flat_eqns
        rule_map = dict(self.plan.rules)
        seg_fns = []
        for si, seg in enumerate(self.plan.segments):
            eqns, free, outs = segment_free_outs(flat, seg)

            if si in rule_map:
                # rule-tagged segment: ONE fused kernel replaces the
                # eqn replay (match re-bound here so cached plans stay
                # self-describing; Pallas interprets off-TPU)
                rule = get_rule(rule_map[si])
                match = rule.bind(self.trace, seg[0])
                if match is None:
                    raise ValueError(
                        f"plan tags segment {si} with rule "
                        f"{rule_map[si]!r} but the trace window no "
                        "longer matches")
                fused_fn, outs = rule.lower(
                    match, free,
                    interpret=jax.default_backend() != "tpu")
                seg_fns.append((jax.jit(fused_fn), free, outs))
                continue

            def seg_fn(vals, _eqns=eqns, _free=free):
                env = dict(zip(_free, vals))

                def read(v):
                    if isinstance(v, jexc.Literal):
                        return v.val
                    if isinstance(v, tuple):
                        if v[0] == "const":
                            return v[1]
                        return read(v[1])
                    return env[v]

                results = []
                for eqn, invars in _eqns:
                    invals = [read(v) for v in invars]
                    out = eqn.primitive.bind(*invals, **eqn.params)
                    if not eqn.primitive.multiple_results:
                        out = [out]
                    for ov, o in zip(eqn.outvars, out):
                        if not _is_drop(ov):
                            env[ov] = o
                            results.append(o)
                return results

            seg_fns.append((jax.jit(seg_fn), free, outs))
        _SEG_CACHE[key] = seg_fns
        while len(_SEG_CACHE) > _CACHE_MAX_ENTRIES:
            _SEG_CACHE.popitem(last=False)
        self._compiled = seg_fns
        return seg_fns

    # ------------------------------------------------------------ execute
    def run(self, *args, measure: bool = False):
        """Execute all segments; returns (flat outputs, host time/segment)."""
        trace = self.trace
        closed = trace.closed
        segs = self._compiled or self._build()
        env = {}
        for cv, cval in zip(closed.jaxpr.constvars, closed.consts):
            env[cv] = cval
        flat_args = jax.tree.leaves(args)
        for iv, val in zip(closed.jaxpr.invars, flat_args):
            env[iv] = val

        host_times = []
        rec = self.recorder
        for si, (jfn, free, outs) in enumerate(segs):
            vals = [env[v] if not isinstance(v, tuple) else v[1]
                    for v in free]
            t0 = time.perf_counter()
            res = jfn(vals)
            t1 = time.perf_counter()
            if measure:
                jax.block_until_ready(res)
            host_times.append(t1 - t0)
            if rec is not None and rec.enabled:
                rec.add(segment_label(self.trace.kernels,
                                      self.plan.segments[si]),
                        "dispatch", t0, t1, tid=1, segment=si,
                        ops=self.segment_operators()[si])
            for v, o in zip(outs, res):
                env[v] = o

        def read_out(v):
            if isinstance(v, jexc.Literal):
                return v.val
            r = trace.env_map.get(v, v)
            return _read(env, r)

        outputs = [read_out(v) for v in closed.jaxpr.outvars]
        return outputs, host_times

    def call(self, *args):
        """Like run(), but returns outputs re-packed into the traced
        function's original output pytree (engine-facing API)."""
        return self.call_timed(*args)[0]

    def call_timed(self, *args):
        """call() plus the measured per-segment host dispatch times —
        the engine's measured launch tax for one invocation."""
        outputs, host_times = self.run(*args)
        if self.trace.out_tree is not None:
            outputs = jax.tree.unflatten(self.trace.out_tree, outputs)
        return outputs, host_times

    def measure_host(self, *args, repeats: int = 3):
        """Warm up (compile) then measure median per-segment dispatch time."""
        self.run(*args)  # warmup/compile
        all_times = []
        for _ in range(repeats):
            _, ts = self.run(*args, measure=False)
            all_times.append(ts)
        med = [statistics.median(x) for x in zip(*all_times)]
        if self.plan.n_launches == len(self.trace.kernels):
            for k, t in zip(self.trace.kernels, med):
                k.host_dispatch_s = t
        return med

    @property
    def n_launches(self) -> int:
        return self.plan.n_launches

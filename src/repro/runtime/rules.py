"""Fusion-rule registry: substitute fused Pallas kernels into LaunchPlans.

A ``FusionRule`` pattern-matches a contiguous eqn window in a ``Trace``
(by primitive-name sequence, then by exact dataflow + shape checks),
and lowers the whole window to ONE fused kernel launch from
``repro.kernels.fused``.  ``fused_plan`` overlays verified matches onto
any base ``LaunchPlan`` — each window becomes a single rule-tagged
segment, and ``PlanExecutor`` dispatches the fused kernel instead of
replaying the member eqns.  This closes the paper's loop: characterize
the decode stream, find the CPU-bound launch-dominated windows, and
replace multi-kernel subgraphs with fused kernels that cut both the
launch count and the intermediate HBM traffic.

Safety: a match is only substituted after a numeric-equivalence check —
the window replay and the fused kernel run on synthetic inputs drawn
from the window's avals and must agree within ``tol``.  Windows whose
intermediates escape (consumed outside the window beyond what the fused
kernel returns) are rejected at bind time, so every fused plan stays an
exact, numerically-equivalent cover of the trace.

The shipped rules target the fp32 decode hot path (reduced configs and
CPU CI); bf16 traces interleave ``convert_element_type`` eqns and simply
do not match — a safe no-op, never a wrong substitution.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.extend.core as jexc
import numpy as np

from repro.core.tracing import Trace, _is_drop
from repro.runtime.plan import LaunchPlan

# square .. mul is the 9-eqn RMSNorm core the decode trace emits at every
# block boundary (fp32: the astype round trips are no-ops and elided)
_RMSNORM_CORE = ("square", "reduce_sum", "broadcast_in_dim", "div", "add",
                 "rsqrt", "mul", "broadcast_in_dim", "mul")

DEFAULT_TOL = 1e-4


def _base(v):
    """Base of a rewritten invar: a jaxpr Var, a Literal, or a const value
    wrapped as ("const", value)."""
    while isinstance(v, tuple):
        if v[0] == "const":
            return v
        v = v[1]
    return v


def _read_ref(env, v):
    """Read a rewritten invar ref against a var->value env."""
    b = _base(v)
    if isinstance(b, jexc.Literal):
        return b.val
    if isinstance(b, tuple):          # ("const", value)
        return b[1]
    return env[b]


def segment_free_outs(flat_eqns, seg):
    """Free inputs and non-drop outputs of one plan segment.

    Free inputs are the vars read before being defined inside the
    segment (consts and literals excluded — they are baked into the
    eqn invars).  Shared with ``PlanExecutor._build``.
    """
    eqns = [flat_eqns[i] for i in seg]
    defined = set()
    free = []
    for eqn, invars in eqns:
        for v in invars:
            b = _base(v)
            if isinstance(b, (tuple, jexc.Literal)):
                continue
            if b not in defined and b not in free:
                free.append(b)
        for ov in eqn.outvars:
            if not _is_drop(ov):
                defined.add(ov)
    outs = [ov for eqn, _ in eqns for ov in eqn.outvars if not _is_drop(ov)]
    return eqns, free, outs


def live_outs(trace: Trace, start: int, stop: int) -> set:
    """Window outvars consumed after the window or returned by the trace."""
    window = {ov for i in range(start, stop)
              for ov in trace.flat_eqns[i][0].outvars if not _is_drop(ov)}
    live = set()
    for j in range(stop, len(trace.flat_eqns)):
        for v in trace.flat_eqns[j][1]:
            b = _base(v)
            if not isinstance(b, (tuple, jexc.Literal)) and b in window:
                live.add(b)
    for ov in trace.closed.jaxpr.outvars:
        if isinstance(ov, jexc.Literal):
            continue
        b = _base(trace.env_map.get(ov, ov))
        if b in window:
            live.add(b)
    return live


@dataclass
class RuleMatch:
    """One verified occurrence of a rule in a trace."""
    rule_name: str
    start: int
    stop: int                          # exclusive eqn index
    inputs: dict                       # role -> rewritten invar ref
    provides: dict                     # outvar -> fused-result index
    eps: float
    max_abs_err: float = float("nan")  # numeric check result (nan = unchecked)

    @property
    def indices(self) -> tuple:
        return tuple(range(self.start, self.stop))


def _literal_operand(invars):
    for v in invars:
        if isinstance(v, jexc.Literal):
            return v
    return None


def _var_operands(invars):
    return [v for v in invars if not isinstance(v, jexc.Literal)]


@dataclass(frozen=True)
class RMSNormRule:
    """The RMSNorm window family: plain norm, residual+norm, norm+matmul.

    ``residual`` prepends the block-boundary ``add``; ``matmul`` appends
    the projection ``dot_general``.  All three lower to the fused Pallas
    kernels in ``repro.kernels.fused`` (interpret mode off-TPU).
    """
    name: str
    residual: bool = False
    matmul: bool = False

    @property
    def pattern(self) -> tuple:
        pat = _RMSNORM_CORE
        if self.residual:
            pat = ("add",) + pat
        if self.matmul:
            pat = pat + ("dot_general",)
        return pat

    # ------------------------------------------------------------ bind
    def bind(self, trace: Trace, start: int) -> Optional[RuleMatch]:
        flat = trace.flat_eqns
        stop = start + len(self.pattern)
        if stop > len(flat):
            return None
        eqns = [flat[i] for i in range(start, stop)]
        if tuple(e.primitive.name for e, _ in eqns) != self.pattern:
            return None

        off = 1 if self.residual else 0
        (sq, rs, bc1, dv, ad, rq, m1, bc2, m2) = eqns[off:off + 9]

        def out(e):
            return e[0].outvars[0]

        x_ref = sq[1][0]
        x_b = _base(x_ref)
        if isinstance(x_b, jexc.Literal):
            return None
        x_aval = sq[0].invars[0].aval
        if len(x_aval.shape) < 1:
            return None
        d = x_aval.shape[-1]
        axis = len(x_aval.shape) - 1

        # the norm core must be one connected chain over the last axis
        if rs[0].params.get("axes") != (axis,):
            return None
        if _base(rs[1][0]) is not out(sq):
            return None
        if _base(bc1[1][0]) is not out(rs):
            return None
        # div is non-commutative: the sum must be the dividend and the
        # literal D the divisor (sum/D = mean, never D/sum)
        if _base(dv[1][0]) is not out(bc1):
            return None
        lit_d = dv[1][1] if isinstance(dv[1][1], jexc.Literal) else None
        if lit_d is None or float(lit_d.val) != float(d):
            return None
        lit_eps = _literal_operand(ad[1])
        if lit_eps is None:
            return None
        if not any(_base(v) is out(dv) for v in ad[1]):
            return None
        if _base(rq[1][0]) is not out(ad):
            return None
        m1_bases = [_base(v) for v in _var_operands(m1[1])]
        if out(rq) not in m1_bases or x_b not in m1_bases:
            return None
        w_ref = bc2[1][0]
        w_aval = bc2[0].invars[0].aval
        if tuple(w_aval.shape) != (d,):
            return None
        if bc2[0].params.get("broadcast_dimensions") != (axis,):
            return None
        m2_bases = [_base(v) for v in _var_operands(m2[1])]
        if out(m1) not in m2_bases or out(bc2) not in m2_bases:
            return None

        inputs = {"x": x_ref, "weight": w_ref}
        provides = {out(m2): 0}

        if self.residual:
            add0 = eqns[0]
            if out(add0) is not x_b:
                return None
            a_ref, b_ref = add0[1][0], add0[1][1]
            for v, ref in ((add0[0].invars[0], a_ref),
                           (add0[0].invars[1], b_ref)):
                if isinstance(_base(ref), jexc.Literal):
                    return None
                if tuple(v.aval.shape) != tuple(x_aval.shape):
                    return None
            inputs = {"x": a_ref, "residual": b_ref, "weight": w_ref}
            # fused result order: (normed, pre-norm sum)
            provides = {out(m2): 0, out(add0): 1}

        if self.matmul:
            dot = eqns[-1]
            dims = dot[0].params.get("dimension_numbers")
            if dims != (((axis,), (0,)), ((), ())):
                return None
            if _base(dot[1][0]) is not out(m2):
                return None
            p_ref = dot[1][1]
            p_aval = dot[0].invars[1].aval
            if len(p_aval.shape) != 2 or p_aval.shape[0] != d:
                return None
            inputs["w_proj"] = p_ref
            # fused result order: (projection, normed)
            provides = {out(dot): 0, out(m2): 1}

        # every escaping intermediate must be one the kernel returns
        if not live_outs(trace, start, stop) <= set(provides):
            return None
        return RuleMatch(self.name, start, stop, inputs, provides,
                         eps=float(lit_eps.val))

    # ------------------------------------------------------------ lower
    def lower(self, match: RuleMatch, free: Sequence, interpret: bool = True):
        """Fused callable over the segment's free-var values, plus the
        ordered outvars it defines (``PlanExecutor`` seg_fn contract)."""
        from repro.kernels.fused import residual_rmsnorm, rmsnorm_matmul

        inputs, eps = match.inputs, match.eps
        outs = sorted(match.provides, key=match.provides.get)
        idx = [match.provides[o] for o in outs]
        residual, matmul = self.residual, self.matmul

        def fused_fn(vals, _free=tuple(free)):
            env = dict(zip(_free, vals))
            x = _read_ref(env, inputs["x"])
            w = _read_ref(env, inputs["weight"])
            if matmul:
                res = rmsnorm_matmul(x, w, _read_ref(env, inputs["w_proj"]),
                                     eps=eps, interpret=interpret)
            elif residual:
                res = residual_rmsnorm(x, w,
                                       _read_ref(env, inputs["residual"]),
                                       eps=eps, interpret=interpret)
            else:
                res = residual_rmsnorm(x, w, eps=eps, interpret=interpret)
            return [res[i] for i in idx]

        return fused_fn, outs


# priority order: longest window first, residual before bare norm
REGISTRY = {
    "rmsnorm_matmul": RMSNormRule("rmsnorm_matmul", matmul=True),
    "residual_rmsnorm": RMSNormRule("residual_rmsnorm", residual=True),
    "rmsnorm": RMSNormRule("rmsnorm"),
}
DEFAULT_RULES = tuple(REGISTRY)


def get_rule(name: str):
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown fusion rule {name!r}; "
                       f"registered: {sorted(REGISTRY)}") from None


# per-(rule, window signature) numeric-check cache: binding is structural,
# so one verified signature covers every repetition across layers
_VERIFY_CACHE: dict = {}


def _window_signature(trace: Trace, match: RuleMatch, free) -> tuple:
    avals = tuple((tuple(getattr(v, "aval", None).shape),
                   str(getattr(v, "aval", None).dtype))
                  if hasattr(v, "aval") else ("const",) for v in free)
    return (match.rule_name, match.eps, avals)


def verify_match(trace: Trace, match: RuleMatch) -> float:
    """Numeric equivalence: window replay vs fused kernel on synthetic
    inputs drawn from the free-var avals.  Returns max abs error over the
    provided outputs; cached per window signature."""
    seg = match.indices
    eqns, free, _ = segment_free_outs(trace.flat_eqns, seg)
    key = _window_signature(trace, match, free)
    if key in _VERIFY_CACHE:
        return _VERIFY_CACHE[key]

    rng = np.random.default_rng(0)
    vals = []
    for v in free:
        aval = v.aval
        if np.issubdtype(np.dtype(aval.dtype), np.floating):
            sample = rng.standard_normal(aval.shape)
        else:
            sample = np.ones(aval.shape)
        vals.append(jax.numpy.asarray(sample.astype(aval.dtype)))

    env = dict(zip(free, vals))
    for eqn, invars in eqns:
        invals = [_read_ref(env, v) for v in invars]
        out = eqn.primitive.bind(*invals, **eqn.params)
        if not eqn.primitive.multiple_results:
            out = [out]
        for ov, o in zip(eqn.outvars, out):
            if not _is_drop(ov):
                env[ov] = o

    rule = get_rule(match.rule_name)
    fused_fn, outs = rule.lower(match, free)
    fused = fused_fn(vals)
    err = 0.0
    for ov, o in zip(outs, fused):
        ref = np.asarray(env[ov], np.float64)
        err = max(err, float(np.max(np.abs(ref - np.asarray(o, np.float64)))))
    _VERIFY_CACHE[key] = err
    match.max_abs_err = err
    return err


def find_matches(trace: Trace, rules: Sequence[str] = DEFAULT_RULES, *,
                 verify: bool = True, tol: float = DEFAULT_TOL) -> list:
    """Non-overlapping rule matches, scanned left to right with the
    registry's priority order at each position.  With ``verify`` (the
    default) every match must pass its numeric-equivalence check."""
    names = trace.kernel_names
    matched: list = []
    pos = 0
    while pos < len(names):
        hit = None
        for rn in rules:
            rule = get_rule(rn)
            if names[pos] != rule.pattern[0]:
                continue
            m = rule.bind(trace, pos)
            if m is None:
                continue
            if verify:
                err = verify_match(trace, m)
                m.max_abs_err = err
                if not (err <= tol):
                    continue
            hit = m
            break
        if hit is not None:
            matched.append(hit)
            pos = hit.stop
        else:
            pos += 1
    return matched


def fused_plan(trace: Trace, base: Optional[LaunchPlan] = None,
               rules: Sequence[str] = DEFAULT_RULES, *,
               verify: bool = True, tol: float = DEFAULT_TOL,
               matches: Optional[list] = None) -> LaunchPlan:
    """Overlay rule windows onto ``base`` (default: eager).

    Every matched window becomes one rule-tagged segment; base segments
    are split around the windows, so the result remains an exact
    in-order cover and the plan stays numerically equivalent.
    """
    n = len(trace.kernels)
    if base is None:
        base = LaunchPlan.eager(n)
    if matches is None:
        matches = find_matches(trace, rules, verify=verify, tol=tol)
    window_of = {}
    for m in matches:
        for i in m.indices:
            window_of[i] = m
    segments: list = []
    plan_rules: list = []
    cur: list = []
    for seg in base.segments:
        for i in seg:
            m = window_of.get(i)
            if m is None:
                cur.append(i)
                continue
            if cur:
                segments.append(tuple(cur))
                cur = []
            if i == m.start:
                plan_rules.append((len(segments), m.rule_name))
                segments.append(m.indices)
        if cur:
            segments.append(tuple(cur))
            cur = []
    return LaunchPlan("fused", tuple(segments),
                      rules=tuple(plan_rules)).validate(n)

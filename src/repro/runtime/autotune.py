"""Measurement-driven plan autotuner: characterize -> region -> benchmark
-> persisted plan table.

This closes the paper's optimization loop over the live serving engine:

1. ``characterize()`` (PR 2's measured sweep) drives the engine with a
   traffic scenario and classifies each batch point CPU- or GPU-bound
   from the MEASURED decode-step curve (``core.boundedness``).
2. In the measured CPU-bound region the bottleneck is host dispatch, so
   the candidate plans are the launch-minimizing family — ``eager`` (the
   baseline), ``chain`` (proximity chains), ``fused`` (rule-substituted
   Pallas kernels).  Whole-graph-style plans are excluded there: the
   paper's Table I compile/capture tax cannot amortize at low batch.
   In the GPU-bound region launches hide behind the device queue, so the
   single-executable family — ``jit``, ``whole_graph`` — competes.
3. Every candidate is benchmarked on the live engine (warmup pass, then
   a measured pass over the same recorded workload) and the fastest
   measured mean decode step wins, ties broken by fewer dispatches.
4. The winners persist as a ``PlanTable`` that
   ``ServeEngine(plan="autotuned", plan_table=...)`` resolves at init —
   the engine serves each slot-pool size with the plan the measurements
   picked for it.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Optional, Sequence

CPU_BOUND_CANDIDATES = ("eager", "chain", "fused")
GPU_BOUND_CANDIDATES = ("jit", "whole_graph")

# relative step-time band inside which two candidates count as tied and
# the lower dispatch count (the TKLQT-friendly plan) wins
TIE_REL_TOL = 0.02

PLAN_TABLE_VERSION = 1


@dataclass
class CandidateResult:
    """One (batch, plan) cell of the autotune benchmark."""
    plan: str
    mean_decode_step_s: float
    decode_launch_tax_s: float
    dispatches_per_decode_step: float
    fused_dispatches_per_decode_step: float
    tokens_per_s: float
    decode_steps: int

    def row(self) -> dict:
        return {
            "plan": self.plan,
            "mean_decode_step_us": round(self.mean_decode_step_s * 1e6, 1),
            "decode_launch_tax_us": round(self.decode_launch_tax_s * 1e6, 1),
            "dispatches_per_decode_step":
                round(self.dispatches_per_decode_step, 2),
            "fused_dispatches_per_decode_step":
                round(self.fused_dispatches_per_decode_step, 2),
            "tokens_per_s": round(self.tokens_per_s, 1),
            "decode_steps": self.decode_steps,
        }

    @classmethod
    def from_row(cls, row: dict) -> "CandidateResult":
        return cls(
            plan=row["plan"],
            mean_decode_step_s=row["mean_decode_step_us"] * 1e-6,
            decode_launch_tax_s=row["decode_launch_tax_us"] * 1e-6,
            dispatches_per_decode_step=row["dispatches_per_decode_step"],
            fused_dispatches_per_decode_step=row.get(
                "fused_dispatches_per_decode_step", 0.0),
            tokens_per_s=row["tokens_per_s"],
            decode_steps=row["decode_steps"],
        )


@dataclass
class AutotuneEntry:
    batch: int
    region: str                     # "CPU-bound" | "GPU-bound" (measured)
    selected: str
    candidates: list = field(default_factory=list)  # [CandidateResult]

    def row(self) -> dict:
        return {"batch": self.batch, "region": self.region,
                "selected": self.selected,
                "candidates": [c.row() for c in self.candidates]}

    @classmethod
    def from_row(cls, row: dict) -> "AutotuneEntry":
        return cls(batch=row["batch"], region=row["region"],
                   selected=row["selected"],
                   candidates=[CandidateResult.from_row(c)
                               for c in row.get("candidates", [])])


@dataclass
class PlanTable:
    """Persisted (batch -> plan) decisions for one (arch, scenario).

    ``d_model`` pins the measured model's width so a table autotuned on
    a ``reduced()`` toy config (same ``arch`` name!) is never silently
    applied to the full model.
    """
    arch: str
    scenario: str
    platform: str
    d_model: int = 0
    entries: dict = field(default_factory=dict)  # batch -> AutotuneEntry

    def lookup(self, batch: int) -> str:
        """Plan for a slot-pool size: exact entry, else the nearest
        measured batch at or below (the region boundary is monotone in
        batch), else the smallest measured batch."""
        if not self.entries:
            return "auto"
        if batch in self.entries:
            return self.entries[batch].selected
        below = [b for b in self.entries if b <= batch]
        key = max(below) if below else min(self.entries)
        return self.entries[key].selected

    # ------------------------------------------------------------ io
    def to_dict(self) -> dict:
        return {
            "version": PLAN_TABLE_VERSION,
            "arch": self.arch, "scenario": self.scenario,
            "platform": self.platform, "d_model": self.d_model,
            "entries": {str(b): e.row()
                        for b, e in sorted(self.entries.items())},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "PlanTable":
        version = d.get("version", 0)
        if version != PLAN_TABLE_VERSION:
            raise ValueError(
                f"plan table version {version} != {PLAN_TABLE_VERSION}; "
                "re-run repro.launch.autotune")
        return cls(arch=d.get("arch", ""), scenario=d.get("scenario", ""),
                   platform=d.get("platform", ""),
                   d_model=d.get("d_model", 0),
                   entries={int(b): AutotuneEntry.from_row(e)
                            for b, e in d.get("entries", {}).items()})

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as fh:
            json.dump(self.to_dict(), fh, indent=2, allow_nan=False)
        return path

    @classmethod
    def load(cls, path: str) -> "PlanTable":
        with open(path) as fh:
            return cls.from_dict(json.load(fh))

    @classmethod
    def from_any(cls, obj) -> "PlanTable":
        """Coerce a PlanTable, a to_dict() payload, or a file path."""
        if isinstance(obj, cls):
            return obj
        if isinstance(obj, dict):
            return cls.from_dict(obj)
        if isinstance(obj, (str, os.PathLike)):
            return cls.load(os.fspath(obj))
        raise TypeError(f"cannot build a PlanTable from {type(obj).__name__}")


@dataclass
class AutotuneResult:
    table: PlanTable
    characterization: object       # telemetry CharacterizationResult

    def summary(self) -> dict:
        return {
            "table": self.table.to_dict(),
            "characterization": self.characterization.summary(),
        }


def _candidate_from_point(plan: str, p) -> CandidateResult:
    """CandidateResult from a telemetry ``MeasuredPoint``."""
    return CandidateResult(
        plan=plan,
        mean_decode_step_s=p.mean_decode_step_s,
        decode_launch_tax_s=p.decode_launch_tax_s,
        dispatches_per_decode_step=p.dispatches_per_decode_step,
        fused_dispatches_per_decode_step=p.fused_dispatches_per_decode_step,
        tokens_per_s=p.tokens_per_s,
        decode_steps=p.decode_steps,
    )


def benchmark_plan(cfg, params, workload, *, batch: int, plan: str,
                   platform: str = "TPU-v5e",
                   max_len: int = 256) -> CandidateResult:
    """Measure one candidate plan on the live engine (warmup + measure)."""
    from repro.telemetry.characterize import run_point
    p = run_point(cfg, params, workload, batch=batch, plan=plan,
                  platform=platform, max_len=max_len, warmup=True)
    return _candidate_from_point(plan, p)


def select(candidates: Sequence[CandidateResult],
           tie_rel_tol: float = TIE_REL_TOL) -> str:
    """Fastest measured mean decode step; within ``tie_rel_tol`` of the
    fastest, the lowest dispatch count wins (fewer launches = lower
    TKLQT at equal speed)."""
    if not candidates:
        raise ValueError("no candidates to select from")
    fastest = min(c.mean_decode_step_s for c in candidates)
    near = [c for c in candidates
            if c.mean_decode_step_s <= fastest * (1.0 + tie_rel_tol)]
    near.sort(key=lambda c: (c.dispatches_per_decode_step,
                             c.mean_decode_step_s))
    return near[0].plan


def autotune(cfg, params, *, scenario: str = "chatbot",
             batches: Sequence[int] = (1, 2, 4, 8),
             platform: str = "TPU-v5e",
             characterization=None, characterize_plan: str = "eager",
             cpu_candidates: Sequence[str] = CPU_BOUND_CANDIDATES,
             gpu_candidates: Sequence[str] = GPU_BOUND_CANDIDATES,
             n_requests: int = 12, seed: int = 0,
             prompt_cap: Optional[int] = 24, output_cap: Optional[int] = 8,
             time_scale: float = 1.0, max_len: int = 256,
             workload=None) -> AutotuneResult:
    """Characterize, gate candidates by the measured region, benchmark,
    and emit the plan table (see module docstring for the full loop)."""
    from repro.telemetry.characterize import characterize
    if characterization is None:
        characterization = characterize(
            cfg, params, scenario=scenario, batches=batches,
            plan=characterize_plan, platform=platform,
            n_requests=n_requests, seed=seed, prompt_cap=prompt_cap,
            output_cap=output_cap, time_scale=time_scale, max_len=max_len,
            workload=workload)
    workload = characterization.workload
    by_batch = {p.batch: p for p in characterization.points}

    table = PlanTable(arch=cfg.name, scenario=characterization.scenario,
                      platform=platform, d_model=cfg.d_model)
    for batch in batches:
        region = characterization.boundedness.classify(batch)
        names = cpu_candidates if region == "CPU-bound" else gpu_candidates
        cands = []
        for name in names:
            point = by_batch.get(batch)
            if name == characterization.plan and point is not None:
                # the characterization sweep already measured this plan
                cands.append(_candidate_from_point(name, point))
                continue
            cands.append(benchmark_plan(cfg, params, workload, batch=batch,
                                        plan=name, platform=platform,
                                        max_len=max_len))
        table.entries[batch] = AutotuneEntry(
            batch=batch, region=region, selected=select(cands),
            candidates=cands)
    return AutotuneResult(table=table, characterization=characterization)

"""Planner: compare candidate LaunchPlans analytically, pick the winner.

The queue model of ``core.device_model`` runs at *segment* granularity
here: one host launch per segment, device time = sum of the member
kernels' modeled durations.  That is exactly the paper's fusion economics
— fusing a chain removes (len-1) launches but not the device work — so
``Planner.auto`` can choose segment boundaries that minimize modeled
TKLQT (or IL) for a target PlatformSpec before anything is compiled.

The auto partitioner walks the kernel stream and keeps extending the
current segment while kernels stay launch-dominated (modeled duration <
modeled host dispatch cost, i.e. the CPU-bound region TKLQT identifies);
a device-bound kernel breaks the segment and stays solo, because its
launch hides behind the running device queue and fusing it buys no TKLQT.
Whole-graph compilation would trivially minimize TKLQT but pays the
compile-time tax the paper's Table I measures, so it is excluded from
``auto`` by default and kept as an explicit strategy.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

from repro.core.device_model import (KernelEvent, PLATFORMS, PlatformSpec,
                                     allreduce_cost_s, dispatch_fanout_s,
                                     kernel_duration)
from repro.core.metrics import SkipReport, report
from repro.core.tracing import Trace
from repro.runtime.plan import LaunchPlan, segment_label

DEFAULT_LENGTHS = (2, 4, 8, 16, 32)


def simulate_plan(kernels: Sequence, plan: LaunchPlan, spec: PlatformSpec, *,
                  batch_scale: float = 1.0,
                  host_scale: Optional[Sequence[float]] = None,
                  tp: int = 1,
                  collective_bytes: Union[float, Sequence, None] = None,
                  draft_launches: int = 0) -> list[KernelEvent]:
    """In-order queue model over plan segments (one launch per segment).

    Rule-tagged segments (``plan.rules``) are priced as ONE fused kernel:
    the member flops still run, but the memory traffic collapses to the
    widest member tensor — the fused kernel keeps intermediates in VMEM,
    so only the segment-boundary arrays cross HBM.  Plain multi-eqn
    segments keep the sum of member durations (XLA dispatches them as one
    executable but the member kernels still round-trip memory).

    ``tp`` prices a tensor-parallel execution of the same stream: the host
    issues every segment's launch once PER DEVICE STREAM (launch cost x
    tp — the multi-GPU widening of the CPU-bound region), while each
    device runs 1/tp of the segment's flops/bytes.  ``collective_bytes``
    adds all-reduce payload on top, priced over the platform's coupling
    link via ``allreduce_cost_s`` and serialized on the device timeline
    (decode-size payloads are latency-floor dominated, so overlap is not
    assumed).  Pass a per-segment sequence to localize payloads at their
    psum sites (each nonzero entry pays its own ring-latency floor), or
    one scalar total priced as a single aggregate all-reduce after the
    final segment (no per-site latency knowledge).

    ``draft_launches`` prepends that many speculative-draft dispatches to
    the host timeline: the draft model is its own single-device stream
    whose kernels are tiny (device time hides behind the queue) but whose
    LAUNCHES serialize on the host before the batched verify can issue —
    the launch-tax side of the speculation trade.  Each costs one tp=1
    ``dispatch_fanout_s`` of host time and no modeled device work.
    """
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if draft_launches < 0:
        raise ValueError(
            f"draft_launches must be >= 0, got {draft_launches}")
    n_segs = len(plan.segments)
    if collective_bytes is None:
        coll = [0.0] * n_segs
    elif isinstance(collective_bytes, (int, float)):
        coll = [0.0] * n_segs
        if n_segs:
            coll[-1] = float(collective_bytes)
    else:
        if len(collective_bytes) != n_segs:
            raise ValueError(
                f"collective_bytes has {len(collective_bytes)} entries "
                f"for {n_segs} plan segments")
        coll = list(collective_bytes)
    rule_segs = {si for si, _ in plan.rules}
    t_host = 0.0
    device_free = 0.0
    events = []
    draft_cost = dispatch_fanout_s(spec, 1)     # draft runs single-device
    for di in range(draft_launches):
        launch_begin = t_host
        t_host += draft_cost
        events.append(KernelEvent(f"draft_launch[{di}]", launch_begin,
                                  t_host, t_host, t_host))
    base_launch = dispatch_fanout_s(spec, tp)   # one launch per device stream
    work_scale = batch_scale / tp
    for si, seg in enumerate(plan.segments):
        rel = 1.0
        if host_scale is not None and len(seg) == 1:
            # singleton segments keep this op's measured host profile;
            # fused segments dispatch as one executable at the base cost
            rel = max(host_scale[seg[0]], 1.0)
        launch_begin = t_host
        t_host = t_host + base_launch * rel
        if si in rule_segs:
            dur = kernel_duration(
                spec,
                sum(kernels[i].flops for i in seg) * work_scale,
                max(kernels[i].bytes for i in seg) * work_scale)
        else:
            dur = sum(kernel_duration(spec, kernels[i].flops * work_scale,
                                      kernels[i].bytes * work_scale)
                      for i in seg)
        if coll[si]:                # zero-byte sites pay no latency floor
            dur += allreduce_cost_s(spec, coll[si], tp)
        start = max(t_host, device_free)
        end = start + dur
        device_free = end
        # operator provenance rides onto the modeled event when the
        # segment is homogeneous (always true for eager singletons);
        # mixed fused segments stay untagged — attribution splits those
        # fractionally from segment_ops instead
        ops = {getattr(kernels[i], "operator", "") for i in seg}
        events.append(KernelEvent(segment_label(kernels, seg),
                                  launch_begin, t_host, start, end,
                                  operator=ops.pop() if len(ops) == 1
                                  else ""))
    return events


@dataclass
class PlanEvaluation:
    plan: LaunchPlan
    report: SkipReport

    @property
    def tklqt(self) -> float:
        return self.report.tklqt

    @property
    def il(self) -> float:
        return self.report.il


@dataclass
class PlanChoice:
    plan: LaunchPlan
    report: SkipReport
    evaluated: list                     # every PlanEvaluation considered


class Planner:
    """Analytic plan search over one trace for one target platform."""

    def __init__(self, trace: Trace,
                 platform: Union[str, PlatformSpec] = "TPU-v5e", *,
                 batch_scale: float = 1.0,
                 host_scale: Optional[Sequence[float]] = None,
                 tp: int = 1,
                 collective_bytes: Union[float, Sequence, None] = None,
                 draft_launches: int = 0):
        self.trace = trace
        self.spec = (PLATFORMS[platform] if isinstance(platform, str)
                     else platform)
        self.batch_scale = batch_scale
        self.host_scale = host_scale
        # tensor-parallel pricing: launch streams multiply, per-device
        # work divides, collective payload rides the coupling link
        self.tp = tp
        self.collective_bytes = collective_bytes
        # speculative pricing: the draft's dispatches serialize before
        # the verify stream (see simulate_plan)
        self.draft_launches = draft_launches

    # ------------------------------------------------------------ plans
    def eager(self) -> LaunchPlan:
        return LaunchPlan.eager(len(self.trace.kernels))

    def whole_graph(self) -> LaunchPlan:
        return LaunchPlan.whole_graph(len(self.trace.kernels))

    def chain(self, length: int) -> LaunchPlan:
        return LaunchPlan.chain(self.trace.kernel_names, length)

    def cost_partition(self, max_segment: int = 128) -> LaunchPlan:
        """TKLQT-aware boundaries: fuse runs of launch-dominated kernels,
        leave device-bound kernels solo (their launches are hidden)."""
        launch_s = self.spec.host_cost_ns * 1e-9
        segs, cur = [], []
        for i, k in enumerate(self.trace.kernels):
            dur = kernel_duration(self.spec, k.flops * self.batch_scale,
                                  k.bytes * self.batch_scale)
            if dur >= launch_s:
                if cur:
                    segs.append(cur)
                    cur = []
                segs.append([i])
            else:
                cur.append(i)
                if len(cur) >= max_segment:
                    segs.append(cur)
                    cur = []
        if cur:
            segs.append(cur)
        return LaunchPlan("auto", tuple(tuple(s) for s in segs)).validate(
            len(self.trace.kernels))

    def fused_rules(self, lengths: Sequence[int] = DEFAULT_LENGTHS,
                    rules: Optional[Sequence[str]] = None,
                    verify: bool = True) -> LaunchPlan:
        """Fusion-rule plan: verified rule windows become single fused
        Pallas kernel launches, the remainder keeps the cost-aware auto
        partition — the paper's 'substitute fused kernels in the
        CPU-bound region' move, as a LaunchPlan."""
        from repro.runtime.rules import DEFAULT_RULES, fused_plan
        base = self.auto(lengths=lengths).plan
        return fused_plan(self.trace, base=base,
                          rules=rules or DEFAULT_RULES, verify=verify)

    # ------------------------------------------------------------ search
    def evaluate(self, plan: LaunchPlan) -> SkipReport:
        ev = simulate_plan(self.trace.kernels, plan, self.spec,
                           batch_scale=self.batch_scale,
                           host_scale=self.host_scale, tp=self.tp,
                           collective_bytes=self.collective_bytes,
                           draft_launches=self.draft_launches)
        return report(ev, self.spec.name, self.spec.launch_overhead_ns * 1e-9)

    def compare(self, plans: Sequence[LaunchPlan],
                objective: str = "tklqt") -> list[PlanEvaluation]:
        evals = [PlanEvaluation(p, self.evaluate(p)) for p in plans]
        evals.sort(key=lambda e: (getattr(e, objective), e.report.il,
                                  e.plan.n_launches))
        return evals

    def auto(self, lengths: Sequence[int] = DEFAULT_LENGTHS,
             objective: str = "tklqt",
             include_whole_graph: bool = False,
             include_eager: bool = False) -> PlanChoice:
        """Pick the candidate plan with the lowest modeled TKLQT (or IL).

        Candidates: the cost-aware partition plus every chain(L); the
        winner's modeled objective is therefore never worse than the best
        fixed-length chain plan.
        """
        n = len(self.trace.kernels)
        cands = [self.cost_partition()]
        cands += [self.chain(L) for L in lengths if 1 < L <= max(n, 1)]
        if include_whole_graph:
            cands.append(self.whole_graph())
        if include_eager:
            cands.append(self.eager())
        evals = self.compare(cands, objective=objective)
        best = evals[0]
        return PlanChoice(best.plan, best.report, evals)

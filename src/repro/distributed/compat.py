"""Version compatibility shims for jax APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to
``jax.shard_map``, and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma`` along the way.  All repo code calls the
wrapper below with the new-style name.
"""
from __future__ import annotations

import inspect

import jax

try:
    _shard_map = jax.shard_map                       # jax >= 0.5
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

_CHECK_KW = ("check_vma" if "check_vma"
             in inspect.signature(_shard_map).parameters else "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    kwargs = {_CHECK_KW: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)


def require_device_count(n: int, *, what: str = "mesh") -> None:
    """Fail fast — and actionably — when a mesh/axis request exceeds the
    visible device count.

    Without this, ``jax.make_mesh`` surfaces the shortfall as an XLA
    reshape error deep inside device assignment.  Raised here instead,
    with the fix inline: on the CPU backend devices are simulated, so the
    remedy is an env var, not new hardware.
    """
    if n < 1:
        raise ValueError(f"{what} needs a positive device count, got {n}")
    have = jax.device_count()
    if n > have:
        backend = jax.default_backend()
        hint = (
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"(before importing jax) to simulate {n} host devices"
            if backend == "cpu" else
            f"run on a host with >= {n} {backend} devices")
        raise ValueError(
            f"{what} needs {n} devices but jax.device_count() == {have} "
            f"on backend {backend!r}; {hint}")


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis, callable inside shard_map.

    ``jax.lax.axis_size`` only exists in newer jax; older releases keep the
    axis env reachable through the core module.  Both return a python int
    usable in shapes (a ``psum(1, axis)`` fallback would be traced).
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    from jax._src.core import get_axis_env
    return get_axis_env().axis_size(axis_name)

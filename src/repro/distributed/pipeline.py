"""Pipeline parallelism: GPipe-style microbatch schedule over a `pipe`
mesh axis, built from shard_map + collective_permute.

The layer stack is split into P stages (stage-major stacked params, like
the scan-over-layers layout).  A scan over `n_micro + P - 1` ticks drives
the classic pipeline diagram: stage 0 injects microbatch t at tick t,
activations hop stage->stage+1 via ppermute each tick, the last stage
emits microbatch t at tick t + P - 1.  Bubble fraction = (P-1)/(ticks).

This is the orthogonal third axis to DP/TP for 1000+ node scale-out:
mesh ("pipe", "data", "model") composes with everything else in
distributed/sharding.py (stage params are just a leading-dim shard).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map


def pipeline_forward(stage_fn: Callable, stage_params, x_micro, mesh,
                     axis: str = "pipe"):
    """Run a P-stage pipeline over microbatches.

    stage_fn: (params_for_one_stage, x) -> y       (same shape)
    stage_params: pytree with leading dim P (stage-major)
    x_micro: (n_micro, mb, ...) microbatched input
    Returns (n_micro, mb, ...) outputs (from the last stage).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    def per_device(params_local, x_stream):
        # params_local: one stage's params (leading dim 1 squeezed)
        params_local = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        zero = jnp.zeros_like(x_stream[0])

        def tick(buf, t):
            # stage 0 injects microbatch t (zeros once drained)
            idx = jnp.clip(t, 0, n_micro - 1)
            inject = jnp.where(t < n_micro, x_stream[idx], zero)
            xin = jnp.where(stage == 0, inject, buf)
            y = stage_fn(params_local, xin)
            # shift activations one stage down the ring
            perm = [(i, i + 1) for i in range(n_stages - 1)]
            nxt = jax.lax.ppermute(y, axis, perm)
            return nxt, y

        _, ys = jax.lax.scan(tick, zero, jnp.arange(ticks))
        return ys[None]                                    # (1, ticks, ...)

    spec_p = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(
        per_device, mesh=mesh,
        in_specs=(spec_p, P()),
        out_specs=P(axis),
        check_vma=False)
    ys = fn(stage_params, x_micro)                 # (P, ticks, mb, ...)
    # last stage emits microbatch t at tick t + P - 1
    return ys[n_stages - 1, n_stages - 1:]


def reference_forward(stage_fn: Callable, stage_params, x_micro):
    """Sequential oracle: apply all stages to each microbatch in order."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]

    def run_one(x):
        for s in range(n_stages):
            p = jax.tree.map(lambda a: a[s], stage_params)
            x = stage_fn(p, x)
        return x

    return jax.vmap(run_one)(x_micro)

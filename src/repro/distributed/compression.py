"""Int8 gradient compression with error feedback, for data-parallel
all-reduce (a distributed-optimization trick for bandwidth-bound meshes).

Each leaf is quantized per-tensor to int8 against its local absmax, summed
across the data axis in int32, then dequantized; the quantization error is
fed back into the next step's gradients (error feedback keeps SGD-style
convergence).  Wire volume drops ~4x vs f32 / ~2x vs bf16.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import axis_size, shard_map


def _quantize(x, err):
    xf = x.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_err = xf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def compressed_psum(grads, errors, axis: str):
    """Per-leaf int8 all-reduce over `axis` with error feedback.

    Call INSIDE shard_map.  Returns (mean grads f32, new error state).
    """
    n = axis_size(axis)

    def one(g, e):
        q, scale, new_e = _quantize(g, e)
        # the wire carries int8 payloads + one f32 scale per shard; the
        # scale-weighted sum happens locally after the gather
        q_all = jax.lax.all_gather(q, axis)                  # (n, ...) int8
        s_all = jax.lax.all_gather(scale, axis)              # (n,)
        val = jnp.tensordot(s_all, q_all.astype(jnp.float32), axes=(0, 0))
        return (val / n).astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


def init_error_state(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def make_compressed_dp_grad(loss_fn, mesh, axis: str = "data"):
    """Explicit-DP gradient step: batch sharded over `axis`, params
    replicated, gradients mean-reduced through the int8 compressed psum.

    Returns grad_step(params, errors, batch) -> (grads, new_errors, loss).
    """

    def shard_fn(params, errors, local_batch):
        (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, local_batch)
        g, new_e = compressed_psum(g, errors, axis)
        loss = jax.lax.pmean(loss, axis)
        return g, new_e, loss

    def apply(params, errors, batch):
        def rep(t):
            return jax.tree.map(lambda _: P(), t)
        bspec = jax.tree.map(lambda _: P(axis), batch)
        return shard_map(
            shard_fn, mesh=mesh,
            in_specs=(rep(params), rep(errors), bspec),
            out_specs=(rep(params), rep(errors), P()),
            check_vma=False,
        )(params, errors, batch)

    return apply

"""Sharding-rule engine: param/cache/activation PartitionSpecs with
divisibility-aware fallback.

Rules map pytree leaf paths to *candidate* specs; any axis that does not
divide the corresponding dimension is dropped (replicated) — this is what
makes e.g. smollm's 15-head attention or 8-KV-head caches lower cleanly on a
16-way model axis without special cases.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


# ------------------------------------------------------------------ helpers
def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def valid_spec(shape, spec: P, mesh: Mesh) -> P:
    """Drop spec axes that don't divide the dim (replicate instead)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axis in zip(shape, entries):
        out.append(axis if axis and dim % _axis_size(mesh, axis) == 0 else None)
    return P(*out)


def shardings_for(tree, spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda x, s: NamedSharding(mesh, valid_spec(x.shape, s, mesh)),
        tree, spec_tree)


# ------------------------------------------------------------------ params
def _param_spec(path_keys, leaf_shape, cfg: ModelConfig, tp: str,
                stacked: bool, fsdp_experts: bool = False) -> P:
    """Candidate spec for one param leaf (before divisibility fallback)."""
    name = path_keys[-1]
    inblock = stacked  # stacked block params carry a leading superblock dim
    pre = (None,) if inblock else ()

    def mk(*dims):
        return P(*(pre + dims))

    # --- embeddings / head
    if name == "embed":
        return P(tp, None)
    if name == "lm_head":
        return P(None, tp)
    # --- norms and scalars
    if name in ("scale", "bias", "xgate", "w0", "u", "ln_scale", "ln_bias",
                "conv_b", "dt_b", "D"):
        return mk(*(None,) * len(leaf_shape[1 if inblock else 0:]))
    # --- MoE
    if "moe" in path_keys:
        if name == "router":
            return mk(None, None)
        if name in ("w_in", "w_gate"):         # (E, D, F)
            return mk(tp, None, "data" if fsdp_experts else None)
        if name == "w_out":                    # (E, F, D)
            return mk(tp, "data" if fsdp_experts else None, None)
        if name in ("shared_in", "shared_gate"):
            return mk(None, tp)
        if name == "shared_out":
            return mk(tp, None)
    # --- rwkv time/channel mix
    if name in ("mix_r", "mix_k", "mix_v", "mix_w", "mix_g"):
        return mk(None)
    if name in ("wA",):
        return mk(None, None)
    if name in ("wB",):
        return mk(None, None)
    # --- mamba
    if name == "in_proj":
        return mk(None, tp)
    if name == "conv_w":
        return mk(None, tp)
    if name == "x_proj":
        return mk(tp, None)
    if name == "dt_w":
        return mk(None, tp)
    if name == "A_log":
        return mk(tp, None)
    if name == "out_proj":
        return mk(tp, None)
    # --- rwkv channel-mix lives under "mlp": (D,F)/(F,D) like a dense MLP
    if "mlp" in path_keys and name == "wk":
        return mk(None, tp)
    if "mlp" in path_keys and name == "wv":
        return mk(tp, None)
    # --- attention & generic projections (head-aligned check done by caller)
    if name in ("wq", "wk", "wv", "wg", "wr"):
        return mk(None, tp)
    if name in ("bq", "bk", "bv"):
        return mk(tp)
    if name == "wo":
        return mk(tp, None)
    # --- dense mlp / rwkv channel
    if name in ("w_in", "w_gate", "wk"):
        return mk(None, tp)
    if name in ("w_out", "wv"):
        return mk(tp, None)
    return mk(*(None,) * len(leaf_shape[1 if inblock else 0:]))


def _head_aligned(name, path_keys, shape, cfg: ModelConfig, mesh, tp,
                  stacked) -> bool:
    """Attention projections: only shard the flattened head dim if the shard
    boundary falls between heads (H % tp == 0)."""
    if "moe" in path_keys or "mlp" in path_keys:
        return True
    if name in ("wq", "wo", "wg", "wr"):
        return cfg.n_heads % mesh.shape[tp] == 0
    if name in ("wk", "wv", "bk", "bv"):
        return cfg.n_kv_heads % mesh.shape[tp] == 0
    if name == "bq":
        return cfg.n_heads % mesh.shape[tp] == 0
    return True


def param_specs(params, cfg: ModelConfig, mesh: Mesh, tp: str = "model",
                fsdp_experts: bool = False):
    """PartitionSpec pytree for a param pytree.

    fsdp_experts: ZeRO-3 storage for MoE expert weights — d_ff additionally
    sharded over "data"; gathered just-in-time inside the MoE shard_map.
    """
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def key_of(p):
        return getattr(p, "key", getattr(p, "name", str(p)))

    specs = []
    for path, leaf in flat:
        keys = [key_of(p) for p in path]
        stacked = "blocks" in keys or "enc_blocks" in keys
        name = keys[-1]
        # rwkv wk/wv live under "mlp" (channel mix) or "mixer" (time mix)
        spec = _param_spec(keys, leaf.shape, cfg, tp, stacked, fsdp_experts)
        if not _head_aligned(name, keys, leaf.shape, cfg, mesh, tp, stacked):
            spec = P(*((None,) * len(leaf.shape)))
        spec = valid_spec(leaf.shape, spec, mesh)
        specs.append(spec)
    treedef = jax.tree.structure(params)
    return jax.tree.unflatten(treedef, specs)


# ------------------------------------------------------------------ cache
KV_REPLICATE_BUDGET = 4e9   # bytes/device a replicated-over-tp cache may use


def cache_specs(cache, cfg: ModelConfig, mesh: Mesh,
                dp=("data",), tp: str = "model"):
    """KV caches: batch over dp; heads over tp when divisible.  When heads
    don't divide: REPLICATE over tp if the per-device cache fits the budget
    (attention then needs NO collectives at decode); otherwise shard the
    sequence dim over tp (distributed online-softmax)."""
    dpt = tuple(dp)
    n_dp = math.prod(mesh.shape[a] for a in dpt)
    kv_total = sum(
        leaf.size * leaf.dtype.itemsize
        for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]
        if getattr(path[-1], "key", getattr(path[-1], "name", "")) in ("k", "v"))
    kv_fits = (kv_total / max(n_dp, 1)) <= KV_REPLICATE_BUDGET

    def spec_for(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = keys[-1]
        shape = leaf.shape
        if name in ("k", "v"):                 # (n_sb, B, T, HKV, hd)
            if cfg.n_kv_heads % mesh.shape[tp] == 0:
                s = P(None, dpt, None, tp, None)
            elif kv_fits:
                s = P(None, dpt, None, None, None)
            else:
                s = P(None, dpt, tp, None, None)
            return valid_spec(shape, s, mesh)
        if name == "h":                        # mamba (n_sb, B, di, ds)
            return valid_spec(shape, P(None, dpt, tp, None), mesh)
        if name == "conv":                     # (n_sb, B, K-1, di)
            return valid_spec(shape, P(None, dpt, None, tp), mesh)
        if name == "s":                        # rwkv (n_sb, B, H, hd, hd)
            return valid_spec(shape, P(None, dpt, None, None, None), mesh)
        if name in ("shift", "shift_c"):       # (n_sb, B, D)
            return valid_spec(shape, P(None, dpt, None), mesh)
        return valid_spec(shape, P(*(None,) * len(shape)), mesh)

    flat = jax.tree_util.tree_flatten_with_path(cache)[0]
    specs = [spec_for(p, leaf) for p, leaf in flat]
    return jax.tree.unflatten(jax.tree.structure(cache), specs)


def paged_cache_specs(pages, cfg: ModelConfig, mesh: Mesh,
                      tp: str = "model"):
    """Specs for the block-table paged pages pytree (``make_paged_cache``).

    Page pools are global (shared across batch rows through block tables),
    so there is no batch axis to put ``data`` on; the KV-head axis shards
    over ``tp`` exactly like the contiguous cache — leaves are
    ``k_pages``/``v_pages`` shaped (n_sb, P, bs, HKV, hd), plus
    ``k_scale``/``v_scale`` (n_sb, P, bs, HKV) when the pool is quantized
    (the per-(token, head) scales shard on the same head axis as their
    payload).  Indivisible head counts fall back to replication
    (divisibility handled by ``valid_spec``)."""
    def spec_for(path, leaf):
        name = getattr(path[-1], "key", getattr(path[-1], "name", ""))
        if name in ("k_pages", "v_pages"):
            return valid_spec(leaf.shape, P(None, None, None, tp, None),
                              mesh)
        if name in ("k_scale", "v_scale"):
            return valid_spec(leaf.shape, P(None, None, None, tp), mesh)
        return valid_spec(leaf.shape, P(*(None,) * len(leaf.shape)), mesh)

    flat = jax.tree_util.tree_flatten_with_path(pages)[0]
    specs = [spec_for(p, leaf) for p, leaf in flat]
    return jax.tree.unflatten(jax.tree.structure(pages), specs)


# ------------------------------------------------------------------ activations
def make_shd(mesh: Mesh, dp=("data",), tp: str = "model",
             seq_shard: bool = False):
    """Activation-sharding hook passed into model forward.

    seq_shard=True puts the residual stream in Megatron-style sequence
    parallelism: (B, S, D) sharded (dp, tp, None).  GSPMD then all-gathers S
    before attention/MLP and reduce-scatters after — activation memory for
    remat-saved layer boundaries drops by the tp size.
    """
    dpt = tuple(dp)

    def shd(name: str, x):
        if name in ("act", "resid"):
            if seq_shard and x.ndim == 3:
                spec = P(dpt, tp, *((None,) * (x.ndim - 2)))
            else:
                spec = P(dpt, *((None,) * (x.ndim - 1)))
        elif name == "logits":
            spec = P(dpt, None, tp)
        elif name == "q_decode":
            spec = P(dpt, *((None,) * (x.ndim - 1)))
        elif name in ("q_heads", "kv_heads"):
            # attention runs HEAD-parallel: full sequence per device, heads
            # over tp (kv heads fall back to replicated when indivisible).
            # Without this GSPMD keeps attention context-parallel and the
            # backward all-reduces dK/dV per flash block (dominant wire
            # cost on MoE/GQA trains).
            spec = P(dpt, None, tp, None)
        elif name == "wkv":
            # batch-overshard across every divisible axis (recurrent mixers
            # with non-TP-shardable head counts)
            axes, prod = [], 1
            for a in dpt + (tp,):
                if x.shape[0] % (prod * mesh.shape[a]) == 0:
                    axes.append(a)
                    prod *= mesh.shape[a]
            spec = P(tuple(axes), *((None,) * (x.ndim - 1)))
        else:
            spec = P(*(None,) * x.ndim)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, valid_spec(x.shape, spec, mesh)))

    return shd

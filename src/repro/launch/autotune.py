"""Plan autotuner launcher: characterize -> benchmark candidates -> table.

    PYTHONPATH=src python -m repro.launch.autotune --arch smollm-360m \
        --reduced --scenario chatbot --batches 1,8 --requests 12 \
        --out-dir autotune-out

Runs the measured characterization sweep (default plan: ``eager``, the
paper's per-op dispatch stream), classifies each batch point CPU- or
GPU-bound from the measured decode-step curve, benchmarks the
region-appropriate candidate plans on the live ServeEngine, and writes:

  plan_table.json   the persisted winners — load with
                    ``ServeEngine(plan="autotuned", plan_table=...)``
                    or ``repro.launch.serve --plan autotuned
                    --plan-table plan_table.json``
  autotune.json     full summary: per-batch candidates + the
                    characterization sweep that gated them
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from repro.configs import get_config, reduced
from repro.core.device_model import PLATFORMS
from repro.models import init_params
from repro.runtime.autotune import (CPU_BOUND_CANDIDATES,
                                    GPU_BOUND_CANDIDATES, autotune)
from repro.workload import list_scenarios, load_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--scenario", default="chatbot",
                    choices=list_scenarios())
    ap.add_argument("--batches", default="1,2,4,8",
                    help="comma-separated slot-pool sizes to autotune")
    ap.add_argument("--platform", default="TPU-v5e",
                    choices=sorted(PLATFORMS))
    ap.add_argument("--characterize-plan", default="eager",
                    help="plan driving the region-detection sweep "
                         "(eager = the paper's per-op dispatch stream)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prompt-cap", type=int, default=24)
    ap.add_argument("--output-cap", type=int, default=8)
    ap.add_argument("--time-scale", type=float, default=1.0)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--replay", default=None,
                    help="autotune over a recorded workload JSONL instead "
                         "of generating from the scenario")
    ap.add_argument("--out-dir", default="autotune-out")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    workload = load_workload(args.replay) if args.replay else None
    batches = [int(b) for b in args.batches.split(",")]

    result = autotune(
        cfg, params, scenario=args.scenario, batches=batches,
        platform=args.platform, characterize_plan=args.characterize_plan,
        n_requests=args.requests, seed=args.seed,
        prompt_cap=args.prompt_cap or None,
        output_cap=args.output_cap or None, time_scale=args.time_scale,
        max_len=args.max_len, workload=workload)

    for batch, entry in sorted(result.table.entries.items()):
        fam = (CPU_BOUND_CANDIDATES if entry.region == "CPU-bound"
               else GPU_BOUND_CANDIDATES)
        print(f"batch={batch:<3d} {entry.region:<9s} "
              f"candidates={','.join(fam)}")
        for c in sorted(entry.candidates,
                        key=lambda c: c.mean_decode_step_s):
            mark = "*" if c.plan == entry.selected else " "
            r = c.row()
            print(f"  {mark} {c.plan:<12s} "
                  f"step={r['mean_decode_step_us']}us "
                  f"tax={r['decode_launch_tax_us']}us "
                  f"disp/step={r['dispatches_per_decode_step']} "
                  f"fused/step={r['fused_dispatches_per_decode_step']} "
                  f"tok/s={r['tokens_per_s']}")

    os.makedirs(args.out_dir, exist_ok=True)
    table_path = result.table.save(
        os.path.join(args.out_dir, "plan_table.json"))
    summary_path = os.path.join(args.out_dir, "autotune.json")
    with open(summary_path, "w") as fh:
        json.dump(result.summary(), fh, indent=2, allow_nan=False)
    print(json.dumps({
        "selected": {str(b): e.selected
                     for b, e in sorted(result.table.entries.items())},
        "regions": {str(b): e.region
                    for b, e in sorted(result.table.entries.items())},
        "artifacts": {"plan_table": table_path, "summary": summary_path},
    }))


if __name__ == "__main__":
    main()

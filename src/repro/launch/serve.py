"""Serving launcher: continuous-batching engine over synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
        --requests 8 --max-batch 4
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.inference.engine import Request, ServeEngine
from repro.models import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_batch=args.max_batch,
                      max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(i, prompt=list(rng.integers(0, cfg.vocab_size, 12)),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    print(json.dumps({
        "arch": cfg.name, "requests": len(done),
        "tokens_out": eng.stats.tokens_out,
        "decode_steps": eng.stats.decode_steps,
        "mean_occupancy": round(float(np.mean(eng.stats.slot_occupancy)), 2),
        "tok_per_s": round(eng.stats.tokens_out / dt, 1),
    }))


if __name__ == "__main__":
    main()

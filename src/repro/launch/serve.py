"""Serving launcher: continuous-batching engine over synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
        --requests 8 --max-batch 4

Pick an execution plan with ``--plan``: the default ``jit`` serves via
whole-step jax.jit closures; ``eager`` / ``chain`` / ``auto`` /
``whole_graph`` / ``fused`` route prefill/decode through the launch-plan
runtime and report real per-step dispatch counts plus modeled TKLQT for
``--platform``.  ``--plan autotuned --plan-table plan_table.json`` loads
the measured winners written by ``repro.launch.autotune``.

Pick a KV cache with ``--cache``: ``paged`` serves through the
block-table paged allocator (``--block-size`` tokens per block,
``--num-blocks`` pool size, ``--prefill-chunk`` chunked prefill), with
``--offload host`` staging evicted blocks in host memory priced by
``--platform``'s coupling link; the JSON report then carries block-pool
utilization, preemption, and offload-traffic counters.

Pick a tensor-parallel degree with ``--tp``: ``--tp N`` serves through
the sharded backend (params/KV head-sharded over an N-way model mesh,
shard_map prefill/decode with psum'd partial outputs) and the JSON
report carries per-device dispatch counts plus collective-payload
counters priced over ``--platform``'s coupling link.  Needs N visible
devices — on CPU set ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

Turn on speculative decoding with ``--speculative``: a truncated-target
draft (``--draft-layers`` superblocks, default half) proposes
``--spec-k`` tokens per round and the target verifies them in one
batched forward — emitted tokens stay byte-identical to greedy, and the
JSON report carries accept-rate / steps-per-emitted-token / draft
dispatch-stream counters priced by ``--platform``.  ``--spec-inflection``
feeds the measured CPU->GPU-bound inflection batch to the depth policy
(deep while dispatch-bound, off past the inflection).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core.device_model import PLATFORMS
from repro.core.export import save_request_trace
from repro.core.fusion import json_sanitize
from repro.inference.engine import (CACHE_MODES, OFFLOAD_MODES,
                                    PLAN_STRATEGIES, Request, ServeEngine)
from repro.inference.kv_quant import KV_DTYPES
from repro.configs import get_config, reduced
from repro.models import init_params
from repro.telemetry.critical_path import (SLO, analyze, record_goodput,
                                           triage)
from repro.telemetry.tracing import RequestTracer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--plan", default="jit", choices=PLAN_STRATEGIES)
    ap.add_argument("--plan-table", default=None,
                    help="plan_table.json from repro.launch.autotune "
                         "(required with --plan autotuned)")
    ap.add_argument("--platform", default="TPU-v5e",
                    choices=sorted(PLATFORMS))
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: 1 = single-device "
                         "LocalBackend, N>1 = sharded backend over an "
                         "N-way model mesh")
    ap.add_argument("--cache", default="contiguous", choices=CACHE_MODES)
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block (paged cache)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="block-pool size; default fits every slot at "
                         "--max-len (no memory pressure)")
    ap.add_argument("--kv-dtype", default="bf16", choices=KV_DTYPES,
                    help="paged KV storage dtype: int8 quantizes pages "
                         "per-(token, head) with f32 scales (entry cost "
                         "hd+4 bytes vs 2*hd) and dequantizes at load; "
                         "the default pool sizes up by the byte ratio")
    ap.add_argument("--share-prefix", action="store_true",
                    help="copy-on-write prefix sharing: requests whose "
                         "prompts share a verified token prefix map their "
                         "leading full blocks to the same pool pages "
                         "(paged cache only)")
    ap.add_argument("--offload", default="none", choices=OFFLOAD_MODES,
                    help="host: evict cold blocks to host memory and "
                         "restore on resume; none: preempt + recompute")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="admit prompts in chunks of this many tokens, "
                         "interleaved with decode steps")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the warmup pass; measured fields (launch "
                         "tax, TTFT, ITL) then include jit-compile time")
    ap.add_argument("--speculative", action="store_true",
                    help="draft-propose / batched-verify decoding "
                         "(greedy-lossless; needs --plan jit)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per round (>= 1)")
    ap.add_argument("--spec-inflection", type=int, default=None,
                    help="measured CPU->GPU-bound inflection batch for "
                         "the launch-tax-aware depth policy (from "
                         "launch.characterize); default: always deep")
    ap.add_argument("--draft-layers", type=int, default=None,
                    help="superblocks in the truncated-target draft "
                         "(default: half the target's)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the engine's MetricsRegistry here after "
                         "the measured run: Prometheus text exposition "
                         "when the path ends in .prom, else a JSON "
                         "snapshot")
    ap.add_argument("--trace-out", default=None,
                    help="write the per-request critical-path trace "
                         "(Perfetto/chrome JSON, one track per request)")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="TTFT SLO in ms for goodput accounting "
                         "(0 disables; unset = no TTFT bound)")
    ap.add_argument("--slo-itl-ms", type=float, default=None,
                    help="mean-ITL SLO in ms for goodput accounting "
                         "(0 disables; unset = no ITL bound)")
    ap.add_argument("--attribution", action="store_true",
                    help="include the per-operator launch/queue/exec "
                         "attribution of one decode step plus the live "
                         "boundedness verdict in the report (needs a "
                         "launch-plan mode, not --plan jit)")
    args = ap.parse_args()
    if args.attribution and args.plan == "jit":
        ap.error("--attribution needs a launch-plan mode (--plan eager/"
                 "chain/auto/whole_graph/fused): plan=jit dispatches one "
                 "whole-step executable with no kernel-level provenance "
                 "to attribute")

    if args.cache != "paged" and (args.kv_dtype != "bf16"
                                  or args.share_prefix):
        ap.error("--kv-dtype/--share-prefix need --cache paged (the "
                 "contiguous cache has no block pool to quantize or share)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    draft_cfg = None
    if args.speculative:
        # actionable CLI validation before any params materialize
        if args.plan != "jit":
            ap.error(f"--speculative needs --plan jit, got {args.plan} "
                     "(the launch-plan runtime replays fixed single-token "
                     "streams; model the draft/verify trade with "
                     "launch.characterize --spec-sweep instead)")
        if args.spec_k < 1:
            ap.error(f"--spec-k must be >= 1, got {args.spec_k} "
                     "(drop --speculative to serve without a draft)")
        from repro.inference.speculative import (default_draft_config,
                                                 validate_draft)
        if args.draft_layers is not None:
            if not 1 <= args.draft_layers <= cfg.n_superblocks:
                ap.error(f"--draft-layers must be in [1, "
                         f"{cfg.n_superblocks}] for {cfg.name} "
                         f"({cfg.n_superblocks} superblocks), got "
                         f"{args.draft_layers}")
            draft_cfg = cfg.replace(
                name=f"{cfg.name}-draft{args.draft_layers}sb",
                n_layers=args.draft_layers * len(cfg.block_pattern))
        else:
            draft_cfg = default_draft_config(cfg)
        try:
            validate_draft(cfg, draft_cfg, args.spec_k)
        except ValueError as e:
            ap.error(str(e))
    params = init_params(jax.random.PRNGKey(0), cfg)
    tracer = RequestTracer()
    eng = ServeEngine(cfg, params, max_batch=args.max_batch,
                      tracer=tracer,
                      max_len=args.max_len, plan=args.plan,
                      platform=args.platform, plan_table=args.plan_table,
                      tp=args.tp,
                      cache=args.cache, block_size=args.block_size,
                      num_blocks=args.num_blocks, offload=args.offload,
                      kv_dtype=args.kv_dtype,
                      share_prefix=args.share_prefix,
                      prefill_chunk=args.prefill_chunk,
                      speculative=args.speculative, draft_config=draft_cfg,
                      spec_k=args.spec_k,
                      spec_inflection=args.spec_inflection)

    def make_requests():
        rng = np.random.default_rng(0)
        return [Request(i, prompt=list(rng.integers(0, cfg.vocab_size, 12)),
                        max_new_tokens=args.max_new)
                for i in range(args.requests)]

    if not args.no_warmup:
        # pay tracing/planning/jit before measuring: the reported launch
        # tax and TTFT/ITL are steady-state serving, not compile time
        eng.run(make_requests())
        eng.reset()
        # reset() keeps the (shareable) tracer; drop warmup lifecycles so
        # the triage decomposition covers the measured run only
        tracer.clear()
    reqs = make_requests()
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    st = eng.stats
    occ = st.slot_occupancy
    report = {
        "arch": cfg.name,
        "requests": sum(1 for r in done if r.status == "done"),
        "plan": st.plan,
        "cache": args.cache,
        "slot_occupancy": {
            "mean": round(float(np.mean(occ)), 2) if occ else 0.0,
            "peak": int(max(occ)) if occ else 0,
        },
        "block_pool_utilization": {
            "mean": round(st.mean_block_pool_utilization, 3),
            "peak": round(st.peak_block_pool_utilization, 3),
        },
        "kv_dtype": args.kv_dtype,
        "share_prefix": args.share_prefix,
        "num_blocks": (eng.kv.num_blocks
                       if args.cache == "paged" else 0),
        "prefix_adoptions": st.prefix_adoptions,
        "shared_prefix_tokens": st.shared_prefix_tokens,
        "kv_cow_copies": (eng.kv.pool.cow_copies_total
                          if args.cache == "paged" else 0),
        "preemptions": st.preemptions,
        "rejected": st.rejected,
        "prefill_chunks": st.prefill_chunks,
        "offload_bytes": st.offload_bytes,
        "restore_bytes": st.restore_bytes,
        "modeled_offload_tax_us": round(st.modeled_offload_tax_s * 1e6, 1),
        "tokens_out": st.tokens_out,
        "decode_steps": st.decode_steps,
        "decode_dispatches": st.decode_dispatches,
        "tp": st.tp,
        "per_device_dispatches": {str(d): n for d, n in
                                  sorted(st.per_device_dispatches.items())},
        "collectives": st.collectives,
        "collective_bytes": st.collective_bytes,
        "collective_bytes_per_decode_step": round(
            st.collective_bytes_per_decode_step, 1),
        "modeled_collective_tax_us": round(
            st.modeled_collective_tax_s * 1e6, 1),
        "dispatches_per_decode_step": round(
            st.dispatches_per_decode_step, 2),
        "fused_dispatches_per_decode_step": round(
            st.fused_dispatches_per_decode_step, 2),
        "rule_hits": dict(st.rule_hits),
        "prefill_dispatches": st.prefill_dispatches,
        "modeled_tklqt_us": round(st.modeled_tklqt_s * 1e6, 1),
        "measured_launch_tax_per_step_us": round(
            st.launch_tax_per_step_s * 1e6, 1),
        "mean_occupancy": round(float(np.mean(occ)), 2) if occ else 0.0,
        "tok_per_s": round(st.tokens_out / dt, 1),
        "ttft_ms": {rid: round(t * 1e3, 3)
                    for rid, t in sorted(st.ttft_s.items())},
        "mean_ttft_ms": round(st.mean_ttft_s * 1e3, 3),
        "mean_itl_ms": round(st.mean_itl_s * 1e3, 3),
        "speculative": args.speculative,
        "spec_k": args.spec_k if args.speculative else 0,
        "draft": draft_cfg.name if draft_cfg is not None else None,
        "spec_rounds": st.spec_rounds,
        "proposed": st.proposed,
        "accepted": st.accepted,
        "corrections": st.corrections,
        "accept_rate": round(st.accept_rate, 3),
        "steps_per_emitted_token": round(st.steps_per_emitted_token, 3),
        "draft_dispatches": st.draft_dispatches,
        "modeled_draft_launch_tax_us": round(
            st.modeled_draft_launch_tax_s * 1e6, 1),
    }
    if args.attribution:
        pd = eng._planned_decode
        rep = pd.attribution if pd is not None else None
        report["attribution"] = None if rep is None else {
            "complete": rep.complete,
            "total_events": rep.total_events,
            "accounted_launches": float(rep.accounted_launches),
            "tklqt_us": round(rep.tklqt_s * 1e6, 3),
            "rows": rep.as_dicts(),
        }
        report["boundedness"] = (eng.monitor.summary()
                                 if eng.monitor is not None else None)
    # critical-path decomposition + goodput BEFORE the registry export,
    # so --metrics-out snapshots carry the goodput families
    slo = SLO.resolve(None, args.slo_ttft_ms, args.slo_itl_ms)
    analysis = analyze(tracer)
    tri = triage(analysis, slo)
    if "slo_report" in tri:
        record_goodput(eng.registry, tri["slo_report"])
    report["triage"] = tri
    if args.trace_out:
        save_request_trace(
            analysis, args.trace_out, platform=args.platform,
            host_spans=(eng.telemetry.spans
                        if eng.telemetry is not None else ()))
        report["trace_out"] = args.trace_out
    if args.metrics_out:
        if args.metrics_out.endswith(".prom"):
            with open(args.metrics_out, "w") as fh:
                fh.write(eng.registry.to_prometheus())
        else:
            with open(args.metrics_out, "w") as fh:
                json.dump(json_sanitize(eng.registry.snapshot()), fh,
                          indent=2, allow_nan=False)
        report["metrics_out"] = args.metrics_out
    # strict JSON even when a measured field degenerates to inf/nan —
    # the same json_safe leaf conversion the bench artifacts use
    print(json.dumps(json_sanitize(report), allow_nan=False))


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import — jax locks the device
count at first init.  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-20b \
        --shape train_4k [--multi-pod] [--out results/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Per cell, writes JSON with memory_analysis, cost_analysis, the HLO-parsed
roofline terms, and the collective schedule summary.
"""
import argparse
import json
import time
import traceback

from repro.configs import ASSIGNED, SHAPES, applicable_shapes, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import build_roofline, model_flops
from repro.launch.steps import StepOptions, build_step
from repro.models import active_param_count


def embed_param_count(cfg) -> int:
    n = cfg.vocab_size * cfg.d_model
    return n if cfg.tie_embeddings else 2 * n


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             options: StepOptions | None = None, out_dir: str | None = None,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    bundle = build_step(cfg, shape, mesh, options=options)
    lowered = bundle.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_d = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
    }
    p_sds = bundle.in_sds[0]
    n_active = active_param_count(p_sds, cfg)
    mf = model_flops(cfg, shape, n_active, embed_param_count(cfg))
    hlo_text = compiled.as_text()
    roof = build_roofline(compiled, cfg, shape, mesh,
                          model_flops_total=mf, hlo_text=hlo_text)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "memory": mem_d,
        "roofline": roof.to_dict(),
        "options": None if options is None else options.__dict__,
    }
    if verbose:
        r = rec["roofline"]
        print(f"[{arch} x {shape_name} x {rec['mesh']}] ok "
              f"compile={t_compile:.0f}s "
              f"tC={r['t_compute']*1e3:.2f}ms tM={r['t_memory']*1e3:.2f}ms "
              f"tX={r['t_collective']*1e3:.2f}ms dom={r['dominant']} "
              f"useful={r['useful_flops_frac']:.2f} "
              f"roofline={r['roofline_frac']:.3f} "
              f"temp={mem_d['temp_bytes']/1e9:.1f}GB", flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = f"{arch}__{shape_name}__{rec['mesh']}.json"
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ASSIGNED:
            for sh in applicable_shapes(get_config(arch)):
                cells.append((arch, sh))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch, sh in cells:
        for mp in meshes:
            tag = f"{arch}__{sh}__{'2x16x16' if mp else '16x16'}"
            if args.skip_existing and os.path.exists(
                    os.path.join(args.out, tag + ".json")):
                print(f"[{tag}] exists, skipping", flush=True)
                continue
            try:
                run_cell(arch, sh, multi_pod=mp, out_dir=args.out)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((tag, repr(e)))
                print(f"[{tag}] FAILED: {e!r}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nall cells ok")


if __name__ == "__main__":
    main()

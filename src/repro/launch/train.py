"""Training launcher.

Local run (reduced config, real optimization on this host):
    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --reduced --steps 50 --batch 4 --seq 64

Production posture: the same Trainer drives the pjit train_step built by
launch/steps.py on the mesh from launch/mesh.py; on a real multi-host TPU
deployment each host runs this entry point under `jax.distributed`.
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.training.loop import TrainConfig, Trainer
from repro.training.optim import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="simulated failure (restart resumes)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    data_cfg = DataConfig(batch=args.batch, seq_len=args.seq,
                          vocab_size=cfg.vocab_size)
    tc = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt_dir, fail_at_step=args.fail_at)
    oc = OptConfig(lr=args.lr, total_steps=args.steps, warmup_steps=5)
    trainer = Trainer(cfg, data_cfg, tc, oc)
    out = trainer.run()
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    print(json.dumps({
        "arch": cfg.name, "steps": out["final_step"],
        "loss_first": round(first, 4), "loss_last": round(last, 4),
        "wall_s": round(out["wall_s"], 1),
        "stragglers": out["stragglers"],
    }))


if __name__ == "__main__":
    main()

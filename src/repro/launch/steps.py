"""Step builders: jitted, sharded train/prefill/decode steps + input specs.

``build_step(cfg, shape, mesh, ...)`` returns a ``StepBundle`` whose
``lower()`` produces the AOT artifact used by both the dry-run and the
roofline analysis.  No device memory is ever allocated for the full-size
configs — everything flows through ShapeDtypeStructs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import (
    cache_specs, make_shd, param_specs, valid_spec)
from repro.launch.mesh import dp_axes_of, tp_axis_of
from repro.layers.moe import MeshContext
from repro.models import forward, init_params, loss_fn, make_cache
from repro.training.optim import OptConfig, opt_init, opt_update


def encoder_len(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Source-sequence length for enc-dec / VLM stubs."""
    if cfg.n_encoder_layers or cfg.frontend != "none":
        return cfg.n_frontend_tokens
    return 0


def batch_specs(cfg: ModelConfig, shape: ShapeSpec):
    """ShapeDtypeStructs for one global batch of this shape cell."""
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
           "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    el = encoder_len(cfg, shape)
    if cfg.n_encoder_layers:
        out["encoder_tokens"] = jax.ShapeDtypeStruct((b, el, cfg.d_model),
                                                     cfg.cdtype)
    elif cfg.frontend == "vision_patches":
        out["frontend_embeds"] = jax.ShapeDtypeStruct(
            (b, el, cfg.d_model), cfg.cdtype)
    return out


def batch_pspecs(batch, mesh: Mesh):
    dp = dp_axes_of(mesh)
    return jax.tree.map(
        lambda x: valid_spec(x.shape, P(dp, *((None,) * (x.ndim - 1))), mesh),
        batch)


@dataclass(frozen=True)
class StepOptions:
    """Perf/memory levers — the §Perf hillclimb iterates these."""
    microbatches: int = 0          # 0 = auto (fit activation budget)
    seq_shard: bool = True         # Megatron-style sequence-parallel residuals
    remat_policy: str = "nothing"  # nothing | dots | dots_no_batch
    loss_chunks: int = 0           # 0 = auto (vocab-dependent)
    zero1: bool = True             # shard optimizer state over data axis
    donate: bool = True
    act_budget_bytes: float = 4e9  # per-device activation target for auto-µb


def default_options(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                    base: Optional[StepOptions] = None) -> StepOptions:
    """Napkin-math defaults: pick microbatches so remat-saved layer
    boundaries (B_loc x S_loc x D x 2B x n_layers) fit the budget."""
    import dataclasses as _dc
    opt = base or StepOptions()
    dp = 1
    for a in dp_axes_of(mesh):
        dp *= mesh.shape[a]
    tp = mesh.shape["model"]
    if shape.kind != "train":
        return _dc.replace(opt, microbatches=1,
                           loss_chunks=opt.loss_chunks or 1)
    b_loc = max(shape.global_batch // dp, 1)
    s_loc = shape.seq_len // tp if (opt.seq_shard and
                                    shape.seq_len % tp == 0) else shape.seq_len
    per_layer = b_loc * s_loc * cfg.d_model * 2
    total = per_layer * cfg.n_layers
    mb = opt.microbatches
    if mb == 0:
        mb = 1
        while total / mb > opt.act_budget_bytes and mb < b_loc:
            mb *= 2
        mb = min(mb, b_loc)
    lc = opt.loss_chunks
    if lc == 0:
        lc = 8 if cfg.vocab_size >= 100_000 else 1
        while shape.seq_len % max(lc, 1):
            lc //= 2
        lc = max(lc, 1)
    return _dc.replace(opt, microbatches=mb, loss_chunks=lc)


@dataclass
class StepBundle:
    name: str
    fn: Callable
    jitted: Any
    in_sds: tuple                 # ShapeDtypeStructs (positional)
    cfg: ModelConfig
    shape: ShapeSpec
    mesh: Mesh

    def lower(self):
        with self.mesh:
            return self.jitted.lower(*self.in_sds)


def params_sds(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init_params(k, cfg), key)


FSDP_THRESHOLD_BYTES = 10e9


def needs_fsdp(cfg: ModelConfig, mesh: Mesh, p_sds=None) -> bool:
    """TP-sharded params exceed the per-device budget -> ZeRO-3 the experts."""
    if cfg.moe is None:
        return False
    p_sds = p_sds if p_sds is not None else params_sds(cfg)
    total = sum(s.size * s.dtype.itemsize for s in jax.tree.leaves(p_sds))
    return total / mesh.shape["model"] > FSDP_THRESHOLD_BYTES


def _mesh_ctx(mesh: Mesh, fsdp: bool = False) -> MeshContext:
    return MeshContext(mesh=mesh, dp_axes=dp_axes_of(mesh),
                       tp_axis=tp_axis_of(mesh),
                       fsdp_axis="data" if fsdp else None)


def _opt_specs(opt_sds, p_specs, mesh: Mesh, zero1: bool):
    """Optimizer-state specs mirror param specs; ZeRO-1 additionally shards
    the leading dim over the data axis."""

    def mirror(sds_leaf, spec):
        spec = list(spec) + [None] * (len(sds_leaf.shape) - len(spec))
        spec = spec[:len(sds_leaf.shape)]
        used = {a for e in spec if e for a in
                (e if isinstance(e, tuple) else (e,))}
        if zero1 and "data" not in used:
            if spec and spec[0] is None and sds_leaf.shape \
                    and sds_leaf.shape[0] % mesh.shape["data"] == 0:
                spec = ["data"] + spec[1:]
        return valid_spec(sds_leaf.shape, P(*spec), mesh)

    def per_state(state, pspec_tree):
        out = {}
        for k, v in state.items():
            if k == "step":
                out[k] = P()
            elif k in ("m",):
                out[k] = jax.tree.map(lambda s, ps: mirror(s, ps), v, pspec_tree)
            elif k == "v":
                # adamw: same shape as params; adafactor: {"vr","vc"}/{"v"} dicts
                def leaf_is_state(x):
                    return isinstance(x, dict) and (
                        set(x) <= {"vr", "vc", "v"})
                def spec_v(sub, ps):
                    if isinstance(sub, dict):
                        o = {}
                        if "vr" in sub:
                            o["vr"] = valid_spec(sub["vr"].shape,
                                                 P(*list(ps)[:-1]), mesh)
                            o["vc"] = valid_spec(
                                sub["vc"].shape,
                                P(*(list(ps)[:-2] + [list(ps) and list(ps)[-1]])),
                                mesh)
                        if "v" in sub:
                            o["v"] = mirror(sub["v"], ps)
                        return o
                    return mirror(sub, ps)
                out[k] = jax.tree.map(spec_v, v, pspec_tree,
                                      is_leaf=leaf_is_state)
            else:
                out[k] = jax.tree.map(lambda s: P(*(None,) * len(s.shape)), v)
        return out

    return per_state(opt_sds, p_specs)


def build_train_step(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, *,
                     opt_cfg: Optional[OptConfig] = None,
                     options: Optional[StepOptions] = None,
                     remat: bool = True) -> StepBundle:
    opt_cfg = opt_cfg or OptConfig(
        kind="adafactor" if (cfg.moe and cfg.moe.n_experts >= 256) else "adamw")
    opts = default_options(cfg, shape, mesh, options)
    p_sds = params_sds(cfg)
    fsdp = needs_fsdp(cfg, mesh, p_sds)
    dist = _mesh_ctx(mesh, fsdp)
    shd = make_shd(mesh, dp=dist.dp_axes, tp=dist.tp_axis,
                   seq_shard=opts.seq_shard)
    dp = dp_axes_of(mesh)
    mb = max(opts.microbatches, 1)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    lkw = dict(dist=dist, shd=shd, remat=remat,
               remat_policy=opts.remat_policy, loss_chunks=opts.loss_chunks)

    def train_step(params, opt_state, batch):
        if mb == 1:
            (loss, (ce, aux)), grads = grad_fn(params, batch, cfg, **lkw)
        else:
            def resh(x):
                y = x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
                spec = valid_spec(y.shape, P(None, dp, *((None,) * (y.ndim - 2))),
                                  mesh)
                return jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, spec))

            mbatch = jax.tree.map(resh, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, mbx):
                g, ls, c, a = carry
                (li, (ci, ai)), gi = grad_fn(params, mbx, cfg, **lkw)
                g = jax.tree.map(lambda x, y: x + y.astype(jnp.float32), g, gi)
                return (g, ls + li, c + ci, a + ai), None

            (grads, loss, ce, aux), _ = jax.lax.scan(
                acc, (g0, 0.0, 0.0, 0.0), mbatch)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss, ce, aux = loss / mb, ce / mb, aux / mb
        new_params, new_opt, om = opt_update(opt_cfg, grads, opt_state, params)
        metrics = {"loss": loss, "ce": ce, "aux": aux, **om}
        return new_params, new_opt, metrics

    o_sds = jax.eval_shape(lambda p: opt_init(opt_cfg, p), p_sds)
    b_sds = batch_specs(cfg, shape)

    p_specs = param_specs(p_sds, cfg, mesh, fsdp_experts=fsdp)
    o_specs = _opt_specs(o_sds, p_specs, mesh, opts.zero1)
    b_pspecs = batch_pspecs(b_sds, mesh)
    donate = opts.donate

    def to_sh(t):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), t)
    in_sh = (to_sh(p_specs), to_sh(o_specs), to_sh(b_pspecs))
    out_sh = (to_sh(p_specs), to_sh(o_specs),
              jax.tree.map(lambda _: NamedSharding(mesh, P()),
                           jax.eval_shape(lambda: {
                               "loss": jnp.zeros(()), "ce": jnp.zeros(()),
                               "aux": jnp.zeros(()), "lr": jnp.zeros(()),
                               "grad_norm": jnp.zeros(())})))
    jitted = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1) if donate else ())
    return StepBundle("train", train_step, jitted, (p_sds, o_sds, b_sds),
                      cfg, shape, mesh)


def build_serve_step(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, *,
                     mode: str = "decode",
                     options: Optional[StepOptions] = None) -> StepBundle:
    """mode='decode': one new token against a seq_len KV cache.
    mode='prefill': process seq_len tokens, filling the cache."""
    opts = default_options(cfg, shape, mesh, options)
    donate = opts.donate
    p_sds = params_sds(cfg)
    fsdp = needs_fsdp(cfg, mesh, p_sds)
    dist = _mesh_ctx(mesh, fsdp)
    shd = make_shd(mesh, dp=dist.dp_axes, tp=dist.tp_axis,
                   seq_shard=(opts.seq_shard and mode == "prefill"))
    b, s = shape.global_batch, shape.seq_len
    el = encoder_len(cfg, shape)

    def serve_decode(params, tokens, cache, cache_index):
        logits, _, new_cache = forward(
            params, tokens, cfg, cache=cache, cache_index=cache_index,
            dist=dist, shd=shd)
        return logits, new_cache

    def serve_prefill(params, tokens, cache, cache_index, **enc):
        logits, _, new_cache = forward(
            params, tokens, cfg, cache=cache, cache_index=cache_index,
            dist=dist, shd=shd, **enc)
        return logits, new_cache

    cache_sds = jax.eval_shape(
        lambda: make_cache(cfg, b, s, src_len=max(el, 1)))
    p_specs = param_specs(p_sds, cfg, mesh, fsdp_experts=fsdp)
    c_specs = cache_specs(cache_sds, cfg, mesh, dp=dist.dp_axes)
    def to_sh(t):
        return jax.tree.map(lambda sp: NamedSharding(mesh, sp), t)
    dp = dist.dp_axes

    if mode == "decode":
        tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        tok_sh = NamedSharding(mesh, valid_spec((b, 1), P(dp, None), mesh))
        idx_sds = jax.ShapeDtypeStruct((), jnp.int32)
        in_sh = (to_sh(p_specs), tok_sh, to_sh(c_specs),
                 NamedSharding(mesh, P()))
        logits_sh = NamedSharding(
            mesh, valid_spec((b, 1, cfg.vocab_size), P(dp, None, "model"), mesh))
        jitted = jax.jit(serve_decode, in_shardings=in_sh,
                         out_shardings=(logits_sh, to_sh(c_specs)),
                         donate_argnums=(2,) if donate else ())
        in_sds = (p_sds, tok_sds, cache_sds, idx_sds)
        return StepBundle("decode", serve_decode, jitted, in_sds, cfg, shape, mesh)

    # prefill (encoder inputs, when present, are positional for AOT lowering)
    tok_sds = jax.ShapeDtypeStruct((b, s), jnp.int32)
    tok_sh = NamedSharding(mesh, valid_spec((b, s), P(dp, None), mesh))
    idx_sds = jax.ShapeDtypeStruct((), jnp.int32)
    enc_sds = {}
    if cfg.n_encoder_layers:
        enc_sds["encoder_tokens"] = jax.ShapeDtypeStruct((b, el, cfg.d_model),
                                                         cfg.cdtype)
    elif cfg.frontend == "vision_patches":
        enc_sds["frontend_embeds"] = jax.ShapeDtypeStruct((b, el, cfg.d_model),
                                                          cfg.cdtype)
    enc_sh = [NamedSharding(mesh, valid_spec(v.shape, P(dp, None, None), mesh))
              for v in enc_sds.values()]
    logits_sh = NamedSharding(
        mesh, valid_spec((b, s, cfg.vocab_size), P(dp, None, "model"), mesh))
    names = list(enc_sds)

    def serve_prefill_pos(params, tokens, cache, cache_index, *enc_vals):
        return serve_prefill(params, tokens, cache, cache_index,
                             **dict(zip(names, enc_vals)))

    jitted = jax.jit(
        serve_prefill_pos,
        in_shardings=(to_sh(p_specs), tok_sh, to_sh(c_specs),
                      NamedSharding(mesh, P()), *enc_sh),
        out_shardings=(logits_sh, to_sh(c_specs)),
        donate_argnums=(2,) if donate else ())
    in_sds = (p_sds, tok_sds, cache_sds, idx_sds, *enc_sds.values())
    return StepBundle("prefill", serve_prefill_pos, jitted, in_sds,
                      cfg, shape, mesh)


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of one benchmark
    cell (weak-type-correct, shardable, no device allocation) — the
    dry-run contract.  For trains: {tokens, labels, ...}; for serving:
    {params, tokens, cache, cache_index, ...}."""
    from repro.configs import SHAPES, get_config
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return batch_specs(cfg, shape)
    b, s = shape.global_batch, shape.seq_len
    el = encoder_len(cfg, shape)
    out = {
        "params": params_sds(cfg),
        "tokens": jax.ShapeDtypeStruct(
            (b, 1 if shape.kind == "decode" else s), jnp.int32),
        "cache": jax.eval_shape(
            lambda: make_cache(cfg, b, s, src_len=max(el, 1))),
        "cache_index": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if shape.kind == "prefill":
        if cfg.n_encoder_layers:
            out["encoder_tokens"] = jax.ShapeDtypeStruct(
                (b, el, cfg.d_model), cfg.cdtype)
        elif cfg.frontend == "vision_patches":
            out["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, el, cfg.d_model), cfg.cdtype)
    return out


def build_step(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
               options: Optional[StepOptions] = None, **kw) -> StepBundle:
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, options=options, **kw)
    if shape.kind == "prefill":
        return build_serve_step(cfg, shape, mesh, mode="prefill",
                                options=options, **kw)
    return build_serve_step(cfg, shape, mesh, mode="decode",
                            options=options, **kw)

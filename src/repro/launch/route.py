"""Replica-fleet serving launcher: workload traffic through one router.

    PYTHONPATH=src python -m repro.launch.route --arch smollm-360m \
        --reduced --replicas 2 --scenario chatbot --requests 16

Builds a ``ReplicaFleet`` of ``--replicas`` full serving engines (each
takes the same ``--plan`` / ``--cache`` / ``--tp`` options as
``repro.launch.serve``), generates open-loop traffic from a named
workload scenario, and drains it through the ``RequestRouter`` with a
pluggable ``--policy``.  ``--stream`` prints one JSON line per emitted
token as replicas produce them; the final line is the fleet report
(per-replica stats, routing counters, TTFT percentiles, throughput).

Elasticity under load: ``--remove-at K`` drains replica 0 after the Kth
routing decision (its queued requests re-enter the router queue; its
admitted ones finish in place), ``--add-at M`` attaches a fresh replica
after the Mth — the same ``launch.elastic.plan_fleet`` arithmetic a
device-pool change would trigger.  ``--metrics-out`` writes the fleet
snapshot: aggregated ``fleet_*``/``router_*`` families with per-replica
labels plus each replica's full registry dump.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.core.export import save_request_trace
from repro.core.fusion import json_sanitize
from repro.inference.engine import (CACHE_MODES, PLAN_STRATEGIES, Request,
                                    ServeEngine)
from repro.inference.fleet import ReplicaFleet
from repro.inference.kv_quant import KV_DTYPES
from repro.inference.router import POLICIES, RequestRouter
from repro.configs import get_config, reduced
from repro.models import init_params
from repro.telemetry.critical_path import (SLO, analyze, record_goodput,
                                           triage)
from repro.telemetry.metrics import percentile
from repro.telemetry.tracing import RequestTracer
from repro.workload import get_scenario, list_scenarios, sample_requests


def build_requests(wl) -> list:
    """Workload records -> engine Requests (arrival times preserved)."""
    return [Request(w.rid, prompt=list(w.prompt),
                    max_new_tokens=w.max_new_tokens, arrival_s=w.arrival_s)
            for w in wl.requests]


def fleet_report(router, report, fleet, wall_s: float) -> dict:
    """Assemble the CLI's JSON report from one routed drain."""
    per_replica = {}
    ttft_all = []
    tokens = 0
    adoptions = shared_tokens = peak_shared = 0
    for rep in fleet.live():
        st = rep.engine.stats
        ttft = sorted(st.ttft_s.values())
        ttft_all.extend(ttft)
        tokens += st.tokens_out
        kv = rep.engine.kv
        rep_peak = kv.pool.peak_shared_blocks if kv is not None else 0
        adoptions += st.prefix_adoptions
        shared_tokens += st.shared_prefix_tokens
        peak_shared += rep_peak
        per_replica[str(rep.rid)] = {
            "state": rep.state,
            "dispatched": rep.dispatched,
            "tokens_out": st.tokens_out,
            "decode_steps": st.decode_steps,
            "decode_dispatches": st.decode_dispatches,
            "preemptions": st.preemptions,
            "prefix_adoptions": st.prefix_adoptions,
            "shared_prefix_tokens": st.shared_prefix_tokens,
            "kv_shared_blocks_peak": rep_peak,
            "mean_ttft_ms": round(st.mean_ttft_s * 1e3, 3),
            "clock_s": round(rep.engine.now, 6),
        }
    return {
        "replicas": len(fleet.replicas),
        "policy": report.policy,
        "requests_done": len(report.completed),
        "dispatches": report.dispatches,
        "requeued": report.requeued,
        "token_events": report.token_events,
        "fleet_tokens_out": tokens,
        "prefix_adoptions": adoptions,
        "shared_prefix_tokens": shared_tokens,
        "kv_shared_blocks_peak": peak_shared,
        "makespan_s": round(report.clock_s, 6),
        "fleet_tok_per_s": round(tokens / report.clock_s, 1)
        if report.clock_s else 0.0,
        "wall_tok_per_s": round(tokens / wall_s, 1) if wall_s else 0.0,
        "ttft_ms": {
            "p50": round(percentile(ttft_all, 50.0) * 1e3, 3),
            "p99": round(percentile(ttft_all, 99.0) * 1e3, 3),
        } if ttft_all else {},
        "assignment": {str(k): v for k, v in
                       sorted(report.assignment.items())},
        "per_replica": per_replica,
    }


def main():
    """Entry point for ``python -m repro.launch.route``."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--policy", default="least-queue-depth",
                    choices=POLICIES)
    ap.add_argument("--scenario", default="chatbot",
                    choices=list_scenarios())
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--time-scale", type=float, default=100.0,
                    help="compress the scenario's arrival timeline so "
                         "reduced-model runs see queueing, not idle gaps")
    ap.add_argument("--prompt-cap", type=int, default=24)
    ap.add_argument("--output-cap", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--plan", default="jit", choices=PLAN_STRATEGIES)
    ap.add_argument("--platform", default="TPU-v5e")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree per replica; the fleet "
                         "is the (data=replicas, model=tp) grid")
    ap.add_argument("--cache", default="contiguous", choices=CACHE_MODES)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=None)
    ap.add_argument("--kv-dtype", default="bf16", choices=KV_DTYPES,
                    help="paged KV storage dtype per replica (int8: "
                         "quantized pages, dequantized at load)")
    ap.add_argument("--share-prefix", action="store_true",
                    help="copy-on-write prefix sharing inside each "
                         "replica's block pool (paged cache only)")
    ap.add_argument("--shared-prefix-tokens", type=int, default=0,
                    help="prepend the same sampled system prompt of this "
                         "many tokens to every request (pairs with "
                         "--policy prefix-affinity and --share-prefix)")
    ap.add_argument("--validate-mesh", action="store_true",
                    help="require the device pool to hold the "
                         "(replicas x tp) fleet mesh (default: simulate "
                         "on whatever devices exist)")
    ap.add_argument("--remove-at", type=int, default=None,
                    help="drain replica 0 after this many dispatches")
    ap.add_argument("--add-at", type=int, default=None,
                    help="attach a fresh replica after this many "
                         "dispatches")
    ap.add_argument("--stream", action="store_true",
                    help="print one JSON line per emitted token")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the warmup drain (measured TTFT then "
                         "includes jit-compile time)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the fleet metrics snapshot (aggregated "
                         "families + per-replica registries) as JSON")
    ap.add_argument("--trace-out", default=None,
                    help="write the per-request critical-path trace "
                         "(Perfetto/chrome JSON, one track per request)")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="TTFT SLO in ms for goodput accounting "
                         "(default: the scenario's registered SLO; "
                         "0 disables)")
    ap.add_argument("--slo-itl-ms", type=float, default=None,
                    help="mean-ITL SLO in ms for goodput accounting "
                         "(default: the scenario's registered SLO; "
                         "0 disables)")
    args = ap.parse_args()
    if args.replicas < 1:
        ap.error(f"--replicas must be >= 1, got {args.replicas}")
    if args.remove_at is not None and args.replicas < 2:
        ap.error("--remove-at needs --replicas >= 2 (the last serving "
                 "replica cannot drain)")
    if args.cache != "paged" and (args.kv_dtype != "bf16"
                                  or args.share_prefix):
        ap.error("--kv-dtype/--share-prefix need --cache paged (the "
                 "contiguous cache has no block pool to quantize or share)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)

    engine_kwargs = dict(max_batch=args.max_batch, max_len=args.max_len,
                         plan=args.plan, platform=args.platform,
                         cache=args.cache, block_size=args.block_size,
                         num_blocks=args.num_blocks,
                         kv_dtype=args.kv_dtype,
                         share_prefix=args.share_prefix)

    wl = sample_requests(args.scenario, args.requests, seed=args.seed,
                         vocab_size=cfg.vocab_size,
                         prompt_cap=args.prompt_cap,
                         output_cap=args.output_cap,
                         time_scale=args.time_scale,
                         shared_prefix=args.shared_prefix_tokens)

    if not args.no_warmup:
        # pay jit/plan compile on a throwaway engine: replicas share the
        # process-wide compiled-segment/jit caches, so the measured drain
        # reports steady-state serving
        warm = ServeEngine(cfg, params, tp=args.tp, **engine_kwargs)
        warm.run(build_requests(wl)[:min(2, args.requests)])

    # one tracer shared by the router and every replica engine: lifecycle
    # events land on one timeline per request regardless of which replica
    # served (or re-served) it — the warmup engine above never sees it
    tracer = RequestTracer()
    fleet = ReplicaFleet(cfg, params, replicas=args.replicas, tp=args.tp,
                         validate_mesh=args.validate_mesh, tracer=tracer,
                         **engine_kwargs)

    def emit(ev):
        print(json.dumps({"stream": {"rid": ev.rid, "replica": ev.replica,
                                     "index": ev.index, "token": ev.token,
                                     "t": round(ev.t, 6)}}))

    router = RequestRouter(fleet, policy=args.policy,
                           on_token=emit if args.stream else None,
                           tracer=tracer)
    actions = []
    if args.remove_at is not None:
        actions.append((args.remove_at,
                        lambda rt: rt.remove_replica(0)))
    if args.add_at is not None:
        actions.append((args.add_at, lambda rt: rt.add_replica()))

    t0 = time.time()
    report = router.route(build_requests(wl), actions=actions)
    wall = time.time() - t0

    out = {"arch": cfg.name, "scenario": args.scenario, "tp": args.tp}
    out.update(fleet_report(router, report, fleet, wall))

    # critical-path decomposition + SLO/goodput accounting.  Goodput
    # families land in the fleet registry BEFORE the snapshot writes, so
    # --metrics-out carries them alongside the router/queue-wait series.
    slo = SLO.resolve(get_scenario(args.scenario),
                      args.slo_ttft_ms, args.slo_itl_ms)
    analysis = analyze(tracer)
    tri = triage(analysis, slo)
    if "slo_report" in tri:
        record_goodput(fleet.registry, tri["slo_report"])
    out["triage"] = tri
    if args.trace_out:
        save_request_trace(analysis, args.trace_out,
                           platform=args.platform,
                           metadata={"scenario": args.scenario,
                                     "policy": args.policy})
        out["trace_out"] = args.trace_out
    if args.metrics_out:
        with open(args.metrics_out, "w") as fh:
            json.dump(json_sanitize(fleet.snapshot()), fh, indent=2,
                      allow_nan=False)
        out["metrics_out"] = args.metrics_out
    print(json.dumps(json_sanitize(out), allow_nan=False))


if __name__ == "__main__":
    main()

"""Elastic scaling controller: reshard a run across device-count changes.

The checkpoint format stores full (unsharded) arrays, so restoring onto a
DIFFERENT mesh is just `restore(..., shardings=specs_for(new_mesh))`.  This
module demonstrates the controller loop: detect a changed device pool,
rebuild the mesh, re-lower the step, restore state, continue.  The straggler
watchdog (training/loop.py) feeds `plan_reshape` on real deployments.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.distributed.sharding import param_specs, shardings_for
from repro.launch.mesh import make_host_mesh


@dataclass
class ElasticPlan:
    old_devices: int
    new_devices: int
    mesh_shape: tuple


def plan_reshape(n_devices: int, lost: int = 0) -> ElasticPlan:
    """Largest (data, model) grid that fits the surviving device pool.
    Prefers shrinking the data axis — model-sharded weights keep layout."""
    avail = n_devices - lost
    model = 1
    for m in (16, 8, 4, 2, 1):
        if avail % m == 0 and m <= avail:
            model = m
            break
    return ElasticPlan(n_devices, avail, (avail // model, model))


def plan_fleet(n_devices: int, tp: int, lost: int = 0) -> ElasticPlan:
    """Serving-fleet variant of ``plan_reshape``: model axis pinned.

    A serving fleet cannot reshard tensor-parallel weights on the fly
    the way training restores can, so the model axis stays at the
    serving ``tp`` and only the data axis (replica count) tracks the
    surviving device pool: ``replicas = (n_devices - lost) // tp``.
    The router then drains surplus replicas (``ReplicaFleet.
    remove_replica``) or attaches new ones — byte-deterministic because
    admitted requests never move between replicas.
    """
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    avail = n_devices - lost
    if avail < tp:
        raise ValueError(
            f"{avail} surviving devices cannot hold even one tp={tp} "
            f"replica (need >= {tp})")
    return ElasticPlan(n_devices, avail, (avail // tp, tp))


def elastic_restore(ckpt: CheckpointManager, step: int, target_tree,
                    cfg: ModelConfig, mesh=None):
    """Restore a checkpoint onto the CURRENT device pool."""
    if mesh is None:
        n = len(jax.devices())
        plan = plan_reshape(n)
        mesh = make_host_mesh(data=plan.mesh_shape[0],
                              model=plan.mesh_shape[1])
    specs = param_specs(target_tree, cfg, mesh)
    sh = shardings_for(target_tree, specs, mesh)
    return ckpt.restore(step, target_tree, shardings=sh), mesh

"""Roofline-term extraction from compiled AOT artifacts.

``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies ONCE, which
undercounts a scanned-layer transformer by ~n_layers.  This module parses the
optimized HLO text instead: it builds the computation call graph, extracts
scan trip counts from while-condition constants, and accumulates

  * dot FLOPs (exact, from dot shapes x contracting dims),
  * HBM byte traffic (operands + outputs of top-level instructions —
    fusions already merge elementwise chains, so this approximates traffic),
  * collective bytes per op kind, with ring-model wire-byte estimates.

Raw ``cost_analysis()`` numbers are reported alongside for transparency.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (3D-torus links counted per collective family).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

# --------------------------------------------------------------- hw constants
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")


@dataclass
class Instr:
    name: str
    out_types: list          # [(dtype, [dims]), ...]
    opcode: str
    operands: list           # operand names
    raw: str

    def out_bytes(self) -> int:
        return sum(DTYPE_BYTES.get(d, 4) * math.prod(dims or [1])
                   for d, dims in self.out_types)


@dataclass
class Computation:
    name: str
    instrs: dict = field(default_factory=dict)   # name -> Instr
    order: list = field(default_factory=list)


def _parse_shapes(type_str: str):
    """'(f32[4,8]{1,0}, s32[])' or 'bf16[48,16]{...}' -> [(dtype, dims)]."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES and dt != "token":
            continue
        out.append((dt, [int(x) for x in dims.split(",")] if dims else []))
    return out


_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        if s.startswith(("HloModule",)):
            continue
        # computation header: "%name (args) -> type {"  or "ENTRY %name ..."
        if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", s)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if s.startswith("ENTRY"):
                    comps["__entry__"] = cur
            continue
        if s.startswith("}"):
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(s)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        # split "type opcode(operands), attrs"
        opm = re.match(r"((?:\([^)]*\))|(?:[\w\[\]{},: ]+?))\s+([\w\-]+)\(", rest)
        if not opm:
            continue
        type_str, opcode = opm.group(1), opm.group(2)
        paren = rest[opm.end() - 1:]
        # operand segment = first balanced parens
        depth, end = 0, 0
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        opstr = paren[1:end]
        attrs = paren[end + 1:]
        operands = _OPERAND_RE.findall(opstr)
        instr = Instr(name, _parse_shapes(type_str), opcode, operands,
                      opstr + "|" + attrs)
        cur.instrs[name] = instr
        cur.order.append(name)
    return comps


def _trip_count(cond: Computation) -> int:
    """Scan conditions compare the induction var against a constant bound
    (jax lowers lax.scan to `while i < N`); take the max positive integer
    constant in the condition computation."""
    consts = []
    for ins in cond.instrs.values():
        if ins.opcode == "constant":
            m = re.match(r"\s*(-?\d+)\s*(?:[|)].*)?$", ins.raw)
            if m:
                try:
                    consts.append(int(m.group(1)))
                except ValueError:
                    pass
    pos = [c for c in consts if c > 0]
    return max(pos) if pos else 1


def _group_size(attr: str, default: int) -> int:
    # replica_groups={{0,1,2,...},{...}} or replica_groups=[8,32]<=[256] forms
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", attr)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attr)
    if m:
        return int(m.group(2))
    return default


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0          # operand bytes (prompt definition)
    wire_bytes: float = 0.0          # ring-model per-device wire traffic
    coll_by_kind: dict = field(default_factory=lambda: defaultdict(float))
    coll_count: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        self.wire_bytes += other.wire_bytes * mult
        self.coll_count += int(other.coll_count * mult)
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] += v * mult


_CALLS_RE = re.compile(r"(?:calls|body|condition|branch_computations)="
                       r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")


def _dus_update_bytes(comp: Computation, ins: Instr):
    """dynamic-update-slice writes IN PLACE: traffic = the update slice,
    not the whole buffer."""
    if len(ins.operands) >= 2:
        upd = comp.instrs.get(ins.operands[1])
        if upd is not None:
            return upd.out_bytes()
    # operand shape unknown (e.g. fusion parameter) — parse from raw types
    shapes = _parse_shapes(ins.raw)
    if len(shapes) >= 2:
        d, dims = shapes[1]
        return DTYPE_BYTES.get(d, 4) * math.prod(dims or [1])
    return ins.out_bytes()


def _write_bytes(ins: Instr, comp: Computation, comps) -> float:
    """HBM bytes written by one instruction (aliasing-aware)."""
    if ins.opcode == "dynamic-update-slice":
        return _dus_update_bytes(comp, ins)
    if ins.opcode == "fusion" and "dynamic-update-slice" in ins.name:
        # in-place DUS fusion: the called computation's root DUS determines
        # the touched bytes
        cm = _CALLS_RE.search(ins.raw)
        if cm:
            for item in re.split(r",\s*", cm.group(1)):
                sub = comps.get(item.strip().lstrip("%"))
                if sub is None:
                    continue
                for sins in sub.instrs.values():
                    if sins.opcode == "dynamic-update-slice":
                        return _dus_update_bytes(sub, sins)
    return ins.out_bytes()


def _bf16_factor(comp: Computation, ins: Instr) -> float:
    """0.5 if this collective moves data that is a bf16<->f32 upcast:
    either fed by a convert-from-bf16 (weight/activation gathers) or
    consumed by a convert-to-bf16 (gradient reductions).  XLA:CPU upcasts
    bf16 dots to f32; the TPU target communicates these at bf16."""
    for o in ins.operands:
        prod = comp.instrs.get(o)
        if prod is None:
            continue
        if prod.opcode == "convert" and prod.operands:
            src = comp.instrs.get(prod.operands[0])
            if src is not None and src.out_types and \
                    src.out_types[0][0] == "bf16":
                return 0.5
        if prod.opcode == "fusion" and "convert" in prod.name:
            return 0.5
    # consumer side: f32 collective immediately converted to bf16
    if ins.out_types and ins.out_types[0][0] == "f32":
        if not hasattr(comp, "_consumers"):
            cons = {}
            for other in comp.instrs.values():
                for o in other.operands:
                    cons.setdefault(o, []).append(other)
            comp._consumers = cons
        for user in comp._consumers.get(ins.name, []):
            if user.opcode == "convert" and user.out_types and \
                    user.out_types[0][0] == "bf16":
                return 0.5
            if user.opcode == "fusion" and "convert" in user.name:
                return 0.5
    return 1.0


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = math.prod(ins.out_types[0][1] or [1]) if ins.out_types else 0
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.raw)
    if not m:
        return 2.0 * out_elems  # degenerate
    cdims = [int(x) for x in m.group(1).split(",") if x]
    lhs = comp.instrs.get(ins.operands[0]) if ins.operands else None
    if lhs is None or not lhs.out_types:
        return 2.0 * out_elems
    lshape = lhs.out_types[0][1]
    k = math.prod(lshape[d] for d in cdims if d < len(lshape)) or 1
    return 2.0 * out_elems * k


_BYTE_OPS = {"dot", "fusion", "convert", "copy", "dynamic-update-slice",
             "dynamic-slice", "gather", "scatter", "transpose", "reduce",
             "broadcast", "concatenate", "pad", "reshape", "slice",
             "convolution", "iota", "compare", "select", "add", "multiply",
             "subtract", "divide", "exponential", "tanh", "rsqrt", "maximum",
             "minimum", "reduce-window", "sort", "bitcast-convert"}


def _comp_cost(comp: Computation, comps, memo, flops_only=False) -> Cost:
    key = (comp.name, flops_only)
    if key in memo:
        return memo[key]
    c = Cost()
    memo[key] = c  # guards recursion (HLO is a DAG; overwritten below)
    for nm in comp.order:
        ins = comp.instrs[nm]
        op = ins.opcode
        if op == "dot" or op == "convolution":
            c.flops += _dot_flops(ins, comp)
        if op in COLLECTIVES and not flops_only:
            # XLA:CPU upcasts bf16 dots to f32, so weight/activation gathers
            # appear at f32 width; the TPU target keeps them bf16 — normalize.
            f32fix = _bf16_factor(comp, ins)
            opb = sum(comp.instrs[o].out_bytes() for o in ins.operands
                      if o in comp.instrs) * f32fix
            p = _group_size(ins.raw, 16)
            c.coll_bytes += opb
            c.coll_count += 1
            c.coll_by_kind[op] += opb
            if op == "all-gather":
                wire = ins.out_bytes() * f32fix * (p - 1) / max(p, 1)
            elif op == "all-reduce":
                wire = 2 * opb * (p - 1) / max(p, 1)
            elif op == "reduce-scatter":
                wire = opb * (p - 1) / max(p, 1)
            elif op == "all-to-all":
                wire = opb * (p - 1) / max(p, 1)
            else:  # collective-permute
                wire = opb
            c.wire_bytes += wire
        if (op in _BYTE_OPS or op in COLLECTIVES) and not flops_only:
            # count each materialized buffer once (its write); reads are the
            # producers' writes — avoids operand double-counting
            c.bytes += _write_bytes(ins, comp, comps)
        if op == "while":
            bm = re.search(r"body=%?([\w.\-]+)", ins.raw)
            cm = re.search(r"condition=%?([\w.\-]+)", ins.raw)
            if bm and bm.group(1) in comps:
                trips = _trip_count(comps[cm.group(1)]) if cm and \
                    cm.group(1) in comps else 1
                c.add(_comp_cost(comps[bm.group(1)], comps, memo, flops_only),
                      trips)
        elif op in ("fusion", "map", "reduce", "reduce-window", "scatter",
                    "sort"):
            # fused bodies: internal values never touch HBM -> flops only
            cm = _CALLS_RE.search(ins.raw)
            if cm:
                for sub in re.split(r",\s*", cm.group(1)):
                    sub = sub.lstrip("%")
                    if sub in comps:
                        c.add(_comp_cost(comps[sub], comps, memo, True), 1.0)
        elif op in ("call", "custom-call", "conditional", "async-start"):
            cm = _CALLS_RE.search(ins.raw)
            if cm:
                for sub in re.split(r",\s*", cm.group(1)):
                    sub = sub.lstrip("%")
                    if sub in comps:
                        c.add(_comp_cost(comps[sub], comps, memo, flops_only),
                              1.0)
    memo[key] = c
    return c


def top_costs(text: str, k: int = 20):
    """Debug: top instructions by bytes*trips and flops*trips — the §Perf
    hillclimb's 'profile'."""
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    # compute trip multiplier per computation by walking from entry
    mult = {entry.name: 1.0}
    order = [entry.name]
    seen = {entry.name}
    i = 0
    while i < len(order):
        comp = comps[order[i]]
        m = mult[comp.name]
        for ins in comp.instrs.values():
            subs = []
            trips = 1.0
            if ins.opcode == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.raw)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.raw)
                if bm:
                    subs = [bm.group(1)]
                    if cm and cm.group(1) in comps:
                        trips = _trip_count(comps[cm.group(1)])
            else:
                cmm = _CALLS_RE.search(ins.raw)
                if cmm and ins.opcode in ("fusion", "call", "conditional"):
                    subs = [s.lstrip("%") for s in
                            re.split(r",\s*", cmm.group(1))]
            for s in subs:
                if s in comps:
                    mult[s] = max(mult.get(s, 0.0), m * trips)
                    if s not in seen:
                        seen.add(s)
                        order.append(s)
        i += 1
    rows = []
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for ins in comp.instrs.values():
            if ins.opcode in _BYTE_OPS or ins.opcode in COLLECTIVES:
                rows.append((ins.out_bytes() * m, ins.out_bytes(), m,
                             cname, ins.name, ins.opcode,
                             ins.out_types[:1]))
    rows.sort(reverse=True)
    return rows[:k]


def analyze_hlo(text: str) -> Cost:
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        return Cost()
    cost = _comp_cost(entry, comps, {})
    # entry parameters are read from HBM once each (weights, caches, batch)
    for ins in entry.instrs.values():
        if ins.opcode == "parameter":
            cost.bytes += ins.out_bytes()
    return cost


def ideal_times(kind: str, model_flops_total: float, params_bytes: float,
                cache_bytes: float, io_bytes: float, n_chips: int):
    """Lower-bound step times: compute term = useful model flops at peak;
    memory term = unavoidable HBM traffic (params re-read per pass — 3x for
    train fwd/bwd, 1x otherwise — plus KV cache and batch IO)."""
    t_c = model_flops_total / n_chips / PEAK_FLOPS_BF16
    passes = 3.0 if kind == "train" else 1.0
    min_bytes = params_bytes * passes + cache_bytes + io_bytes
    t_m = min_bytes / n_chips / HBM_BW
    return t_c, t_m


# --------------------------------------------------------------- roofline
@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    wire_bytes: float
    coll_count: int
    coll_by_kind: dict
    raw_cost_flops: float
    raw_cost_bytes: float
    model_flops_total: float          # 6*N*D (active) whole-step, all chips
    n_chips: int

    @property
    def t_compute(self):
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self):
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self):
        return self.wire_bytes / ICI_BW

    @property
    def dominant(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self):
        # optimistic overlap model: terms hide behind the max
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self):
        """MODEL_FLOPS / HLO_FLOPs (per-chip)."""
        per_chip_model = self.model_flops_total / self.n_chips
        return per_chip_model / self.flops if self.flops else 0.0

    @property
    def roofline_frac(self):
        """Fraction of the compute roofline achieved: useful model flops per
        chip over (step_time * peak)."""
        per_chip_model = self.model_flops_total / self.n_chips
        denom = self.step_time * PEAK_FLOPS_BF16
        return per_chip_model / denom if denom else 0.0

    def to_dict(self):
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "wire_bytes": self.wire_bytes,
            "coll_count": self.coll_count,
            "coll_by_kind": dict(self.coll_by_kind),
            "raw_cost_flops": self.raw_cost_flops,
            "raw_cost_bytes": self.raw_cost_bytes,
            "model_flops_total": self.model_flops_total,
            "n_chips": self.n_chips,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "dominant": self.dominant,
            "step_time": self.step_time,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
        }


def model_flops(cfg, shape, n_active_params: int, n_embed_params: int) -> float:
    """6*N*D convention.  N = active non-embedding params + embedding matmul
    (unembed) treated as params once; D = tokens processed in the step."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 3  # fwd + bwd(2x)
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 1
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mult = 1
    n = n_active_params + n_embed_params
    return 2.0 * n * tokens * mult  # 2*N*D per fwd; x3 for train = 6*N*D


def build_roofline(compiled, cfg, shape, mesh, *, model_flops_total: float,
                   hlo_text: str | None = None) -> Roofline:
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = analyze_hlo(text)
    try:
        raw = compiled.cost_analysis()
        raw_f = float(raw.get("flops", 0.0))
        raw_b = float(raw.get("bytes accessed", 0.0))
    except Exception:
        raw_f = raw_b = 0.0
    n_chips = math.prod(mesh.shape.values())
    return Roofline(
        flops=cost.flops, hbm_bytes=cost.bytes, coll_bytes=cost.coll_bytes,
        wire_bytes=cost.wire_bytes, coll_count=cost.coll_count,
        coll_by_kind=dict(cost.coll_by_kind),
        raw_cost_flops=raw_f, raw_cost_bytes=raw_b,
        model_flops_total=model_flops_total, n_chips=n_chips)

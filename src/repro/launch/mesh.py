"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over whatever devices exist (tests / local runs)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis_of(mesh) -> str:
    return "model"

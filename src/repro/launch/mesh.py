"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  Every constructor validates the requested axis
sizes against ``jax.device_count()`` first — an undersized device pool
fails with an actionable message (how to simulate host devices on CPU)
instead of the XLA shape error ``jax.make_mesh`` would raise.
"""
from __future__ import annotations

import math

import jax

from repro.distributed.compat import require_device_count


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    require_device_count(
        math.prod(shape),
        what=f"production mesh {dict(zip(axes, shape))}")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over whatever devices exist (tests / local runs)."""
    for name, size in (("data", data), ("model", model)):
        if size < 1:
            raise ValueError(f"mesh axis {name!r} must be >= 1, got {size}")
    if pod < 0:
        raise ValueError(f"mesh axis 'pod' must be >= 0, got {pod}")
    shape = (pod, data, model) if pod else (data, model)
    axes = ("pod", "data", "model") if pod else ("data", "model")
    require_device_count(math.prod(shape),
                         what=f"host mesh {dict(zip(axes, shape))}")
    return jax.make_mesh(shape, axes)


def make_fleet_mesh(replicas: int, tp: int = 1):
    """``(data=replicas, model=tp)`` grid for a serving replica fleet.

    The data axis indexes replicas (each serves whole requests), the
    model axis is each replica's tensor-parallel degree — the same two
    axes training uses, so a deployment can flip between the two without
    re-slicing its device pool.  Validates the pool holds replicas*tp
    devices; on an undersized pool (CPU CI) the fleet runs unvalidated
    with replicas time-multiplexing the local devices instead.
    """
    if replicas < 1:
        raise ValueError(f"replicas must be >= 1, got {replicas}")
    return make_host_mesh(data=replicas, model=tp)


def dp_axes_of(mesh) -> tuple[str, ...]:
    """Mesh axis names that carry data parallelism."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis_of(mesh) -> str:
    """Mesh axis name that carries tensor parallelism."""
    return "model"

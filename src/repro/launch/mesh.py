"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  Every constructor validates the requested axis
sizes against ``jax.device_count()`` first — an undersized device pool
fails with an actionable message (how to simulate host devices on CPU)
instead of the XLA shape error ``jax.make_mesh`` would raise.
"""
from __future__ import annotations

import math

import jax

from repro.distributed.compat import require_device_count


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    require_device_count(
        math.prod(shape),
        what=f"production mesh {dict(zip(axes, shape))}")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over whatever devices exist (tests / local runs)."""
    for name, size in (("data", data), ("model", model)):
        if size < 1:
            raise ValueError(f"mesh axis {name!r} must be >= 1, got {size}")
    if pod < 0:
        raise ValueError(f"mesh axis 'pod' must be >= 0, got {pod}")
    shape = (pod, data, model) if pod else (data, model)
    axes = ("pod", "data", "model") if pod else ("data", "model")
    require_device_count(math.prod(shape),
                         what=f"host mesh {dict(zip(axes, shape))}")
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis_of(mesh) -> str:
    return "model"

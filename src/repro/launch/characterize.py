"""Measured serving characterization launcher: scenario x batch x plan sweep.

    PYTHONPATH=src python -m repro.launch.characterize --arch smollm-360m \
        --reduced --scenario chatbot --batches 1,2,4 --plan auto

Drives the live ServeEngine with a named traffic scenario (see
``repro.workload.list_scenarios``), records host telemetry, prints
per-batch measured launch tax + TTFT/ITL percentiles with a
CPU/GPU-bound classification, and writes to ``--out-dir``:

  workload_<scenario>.jsonl     replayable traffic trace (--replay loads one)
  trace_<scenario>_b<N>.json    merged host+modeled-device Chrome trace
                                (open in Perfetto / chrome://tracing)
  characterize.json             BENCH-style summary of the whole sweep
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from repro.configs import get_config, reduced
from repro.core.device_model import PLATFORMS
from repro.core.export import save_merged_trace
from repro.inference.engine import PLAN_STRATEGIES
from repro.models import init_params
from repro.telemetry.characterize import characterize
from repro.workload import list_scenarios, load_workload, save_workload


def write_artifacts(result, out_dir: str) -> dict:
    """Write workload JSONL, per-batch Chrome traces, and the summary."""
    os.makedirs(out_dir, exist_ok=True)
    paths = {}
    wl = os.path.join(out_dir, f"workload_{result.scenario}.jsonl")
    paths["workload"] = save_workload(result.workload, wl)
    for p in result.points:
        tr = os.path.join(out_dir,
                          f"trace_{result.scenario}_b{p.batch}.json")
        paths[f"trace_b{p.batch}"] = save_merged_trace(
            p.spans, result.platform, tr,
            device_events=p.modeled_events,
            device_anchors=p.decode_anchors,
            metadata={"arch": result.arch, "scenario": result.scenario,
                      "plan": result.plan, "batch": p.batch})
    summary = os.path.join(out_dir, "characterize.json")
    with open(summary, "w") as f:
        json.dump(result.summary(), f, indent=2)
    paths["summary"] = summary
    return paths


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--scenario", default="chatbot",
                    choices=list_scenarios())
    ap.add_argument("--batches", default="1,2,4",
                    help="comma-separated slot-pool sizes to sweep")
    ap.add_argument("--plan", default="auto", choices=PLAN_STRATEGIES)
    ap.add_argument("--platform", default="TPU-v5e",
                    choices=sorted(PLATFORMS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prompt-cap", type=int, default=24,
                    help="clip scenario prompt lengths (0 = no cap)")
    ap.add_argument("--output-cap", type=int, default=8,
                    help="clip scenario output lengths (0 = no cap)")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="compress the arrival timeline by this factor")
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the warmup pass (timings include compiles)")
    ap.add_argument("--replay", default=None,
                    help="replay a recorded workload JSONL instead of "
                         "generating from the scenario")
    ap.add_argument("--out-dir", default="characterize-out")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    workload = load_workload(args.replay) if args.replay else None
    batches = [int(b) for b in args.batches.split(",")]

    result = characterize(
        cfg, params, scenario=args.scenario, batches=batches,
        plan=args.plan, platform=args.platform, n_requests=args.requests,
        seed=args.seed, prompt_cap=args.prompt_cap or None,
        output_cap=args.output_cap or None, time_scale=args.time_scale,
        max_len=args.max_len, warmup=not args.no_warmup,
        workload=workload)

    for p in result.points:
        cls = result.boundedness.classify(p.batch)
        r = p.row()
        print(f"batch={p.batch:<3d} {cls:<9s} "
              f"launch_tax/step={r['decode_launch_tax_us']}us "
              f"step={r['mean_decode_step_us']}us "
              f"ttft_p50={r['ttft_p50_ms']}ms "
              f"ttft_p99={r['ttft_p99_ms']}ms "
              f"itl_p50={r['itl_p50_ms']}ms "
              f"itl_p99={r['itl_p99_ms']}ms "
              f"tok/s={r['tokens_per_s']}")
    infl = result.boundedness.inflection_batch
    print(f"inflection_batch={infl} "
          f"({'always CPU/dispatch-bound in range' if infl is None else 'GPU/compute-bound from here'})")

    paths = write_artifacts(result, args.out_dir)
    print(json.dumps({"summary": result.summary(), "artifacts": paths}))


if __name__ == "__main__":
    main()

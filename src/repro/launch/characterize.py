"""Measured serving characterization launcher: scenario x batch x plan sweep.

    PYTHONPATH=src python -m repro.launch.characterize --arch smollm-360m \
        --reduced --scenario chatbot --batches 1,2,4 --plan auto

Drives the live ServeEngine with a named traffic scenario (see
``repro.workload.list_scenarios``), records host telemetry, prints
per-batch measured launch tax + TTFT/ITL percentiles with a
CPU/GPU-bound classification, and writes to ``--out-dir``:

  workload_<scenario>.jsonl     replayable traffic trace (--replay loads one)
  trace_<scenario>_b<N>.json    merged host+modeled-device Chrome trace
                                (open in Perfetto / chrome://tracing)
  characterize.json             BENCH-style summary of the whole sweep

``--memory-sweep`` runs the paged-KV memory-pressure sweep instead:
the same seeded traffic is served with the block pool driven past
capacity on each ``--sweep-platforms`` device model (LC/PCIe vs
CC/NVLink-C2C), printing measured offload traffic and the link-priced
offload tax per architecture, and writing ``memory_sweep.json``.

``--tp-sweep`` models the tensor-parallel launch story instead: the
decode kernel stream is traced once per batch, then priced per
(platform, tp) with per-device dispatch streams (launch tax x tp),
1/tp device work, and per-layer psum payloads over each platform's
coupling link — printing how the CPU->GPU-bound inflection batch moves
with tp on LC vs CC parts, and writing ``tp_sweep.json``.

``--spec-sweep`` runs the speculative-decoding depth sweep: the live
engine measures acceptance and steps-per-emitted-token per (k, batch),
then the target/draft decode streams are priced per platform with the
draft's serialized dispatch stream and the (k+1)x verify work —
printing the LC-vs-CC winning batch regions (speculation pays where
decode is dispatch-bound; CC's region is wider) and writing
``spec_sweep.json``.
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from repro.configs import get_config, reduced
from repro.core.device_model import PLATFORMS
from repro.core.export import save_merged_trace
from repro.inference.engine import PLAN_STRATEGIES
from repro.models import init_params
from repro.telemetry.characterize import (characterize,
                                          memory_pressure_sweep, spec_sweep,
                                          tp_sweep)
from repro.workload import list_scenarios, load_workload, save_workload


def write_artifacts(result, out_dir: str) -> dict:
    """Write workload JSONL, per-batch Chrome traces, and the summary."""
    os.makedirs(out_dir, exist_ok=True)
    paths = {}
    wl = os.path.join(out_dir, f"workload_{result.scenario}.jsonl")
    paths["workload"] = save_workload(result.workload, wl)
    for p in result.points:
        tr = os.path.join(out_dir,
                          f"trace_{result.scenario}_b{p.batch}.json")
        paths[f"trace_b{p.batch}"] = save_merged_trace(
            p.spans, result.platform, tr,
            device_events=p.modeled_events,
            device_anchors=p.decode_anchors,
            metadata={"arch": result.arch, "scenario": result.scenario,
                      "plan": result.plan, "batch": p.batch})
    summary = os.path.join(out_dir, "characterize.json")
    with open(summary, "w") as f:
        json.dump(result.summary(), f, indent=2)
    paths["summary"] = summary
    return paths


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--scenario", default="chatbot",
                    choices=list_scenarios())
    ap.add_argument("--batches", default="1,2,4",
                    help="comma-separated slot-pool sizes to sweep")
    ap.add_argument("--plan", default="auto", choices=PLAN_STRATEGIES)
    ap.add_argument("--platform", default="TPU-v5e",
                    choices=sorted(PLATFORMS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prompt-cap", type=int, default=24,
                    help="clip scenario prompt lengths (0 = no cap)")
    ap.add_argument("--output-cap", type=int, default=8,
                    help="clip scenario output lengths (0 = no cap)")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="compress the arrival timeline by this factor")
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the warmup pass (timings include compiles)")
    ap.add_argument("--replay", default=None,
                    help="replay a recorded workload JSONL instead of "
                         "generating from the scenario")
    ap.add_argument("--out-dir", default="characterize-out")
    ap.add_argument("--memory-sweep", action="store_true",
                    help="run the paged-KV memory-pressure sweep (LC vs "
                         "CC offload tax) instead of the batch sweep")
    ap.add_argument("--sweep-platforms", default="Intel+H100,GH200",
                    help="comma-separated device models for "
                         "--memory-sweep / --tp-sweep")
    ap.add_argument("--pool-fracs", default="1.0,0.5,0.33",
                    help="pool sizes as fractions of the no-pressure pool")
    ap.add_argument("--block-size", type=int, default=4,
                    help="tokens per KV block for --memory-sweep")
    ap.add_argument("--kv-dtypes", default="bf16",
                    help="comma-separated paged-KV storage dtypes for "
                         "--memory-sweep (e.g. bf16,int8); each pool is "
                         "held at the first dtype's device byte budget, "
                         "so int8 cells fit proportionally more blocks")
    ap.add_argument("--sweep-max-batch", type=int, default=4)
    ap.add_argument("--tp-sweep", action="store_true",
                    help="model the tensor-parallel dispatch/collective "
                         "sweep (inflection batch vs tp on LC vs CC) "
                         "instead of the measured batch sweep")
    ap.add_argument("--tps", default="1,2,4,8",
                    help="comma-separated tensor-parallel degrees for "
                         "--tp-sweep")
    ap.add_argument("--spec-sweep", action="store_true",
                    help="run the speculative-decoding k x batch sweep "
                         "(measured acceptance + modeled LC-vs-CC draft "
                         "launch tax) instead of the measured batch sweep")
    ap.add_argument("--spec-ks", default="0,2,4,8",
                    help="comma-separated speculation depths for "
                         "--spec-sweep (0 = plain decode baseline)")
    ap.add_argument("--model-batches", default="",
                    help="extra batch sizes to price (not serve) in "
                         "--spec-sweep, e.g. 16,64,256")
    ap.add_argument("--attribution-report", action="store_true",
                    help="print the per-operator launch/queue/%%-of-TKLQT "
                         "table for each batch point (needs a launch-plan "
                         "mode, not --plan jit)")
    args = ap.parse_args()
    if args.attribution_report and args.plan == "jit":
        ap.error("--attribution-report needs a launch-plan mode (--plan "
                 "eager/chain/auto/whole_graph/fused): plan=jit has no "
                 "kernel-level provenance to attribute")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    if args.tp_sweep:
        # trace-only sweep: abstract weights — full-size archs price
        # without materializing (or randomly initializing) parameters
        from repro.launch.steps import params_sds
        sweep = tp_sweep(
            cfg, params_sds(cfg),
            batches=[int(b) for b in args.batches.split(",") if b],
            tps=[int(t) for t in args.tps.split(",") if t],
            platforms=[p for p in args.sweep_platforms.split(",") if p],
            max_len=args.max_len)
        for r in sweep["points"]:
            print(f"{r['platform']:<12s} {r['coupling']:<3s} "
                  f"tp={r['tp']:<2d} batch={r['batch']:<3d} "
                  f"tklqt={r['modeled_tklqt_us']}us "
                  f"step={r['modeled_step_us']}us "
                  f"launch={r['launch_tax_us']}us "
                  f"coll={r['collective_bytes']}B "
                  f"coll_tax={r['modeled_collective_tax_us']}us")
        for plat, by_tp in sweep["inflection_batch"].items():
            print(f"inflection[{plat}]: " + ", ".join(
                f"tp={t} -> {b}" for t, b in by_tp.items()))
        os.makedirs(args.out_dir, exist_ok=True)
        path = os.path.join(args.out_dir, "tp_sweep.json")
        with open(path, "w") as f:
            json.dump(sweep, f, indent=2)
        print(json.dumps({"summary": sweep, "artifacts": {"sweep": path}}))
        return

    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.spec_sweep:
        batches = [int(b) for b in args.batches.split(",") if b]
        mb = [int(b) for b in args.model_batches.split(",") if b]
        sweep = spec_sweep(
            cfg, params,
            ks=[int(k) for k in args.spec_ks.split(",") if k],
            batches=batches,
            platforms=[p for p in args.sweep_platforms.split(",") if p],
            scenario=args.scenario, n_requests=args.requests,
            seed=args.seed, prompt_cap=args.prompt_cap or None,
            output_cap=args.output_cap or None, max_len=args.max_len,
            model_batches=sorted(set(batches) | set(mb)) if mb else None)
        for r in sweep["measured"]:
            print(f"measured k={r['k']:<2d} batch={r['batch']:<3d} "
                  f"accept={r['accept_rate']:<5} "
                  f"steps/tok={r['steps_per_emitted_token']:<5} "
                  f"rounds={r['spec_rounds']:<4d} "
                  f"draft_disp={r['draft_dispatches']}")
        for r in sweep["modeled"]:
            print(f"{r['platform']:<12s} {r['coupling']:<3s} "
                  f"k={r['k']:<2d} batch={r['batch']:<5d} "
                  f"base/tok={r['modeled_baseline_per_token_us']}us "
                  f"spec/tok={r['modeled_spec_per_token_us']}us "
                  f"draft_tax={r['modeled_draft_launch_tax_per_round_us']}"
                  f"us win={r['win']}")
        for plat, by_k in sweep["win_batches"].items():
            print(f"win_batches[{plat}]: " + ", ".join(
                f"k={k} -> {bs}" for k, bs in by_k.items()))
        os.makedirs(args.out_dir, exist_ok=True)
        path = os.path.join(args.out_dir, "spec_sweep.json")
        with open(path, "w") as f:
            json.dump(sweep, f, indent=2)
        print(json.dumps({"summary": sweep, "artifacts": {"sweep": path}}))
        return
    if args.memory_sweep:
        sweep = memory_pressure_sweep(
            cfg, params, scenario=args.scenario,
            platforms=[p for p in args.sweep_platforms.split(",") if p],
            pool_fracs=[float(f) for f in args.pool_fracs.split(",") if f],
            kv_dtypes=[d for d in args.kv_dtypes.split(",") if d],
            max_batch=args.sweep_max_batch, max_len=args.max_len,
            block_size=args.block_size, n_requests=args.requests,
            seed=args.seed, prompt_cap=args.prompt_cap or None,
            output_cap=args.output_cap or None)
        for r in sweep["points"]:
            print(f"{r['platform']:<12s} {r['coupling']:<3s} "
                  f"link={r['link_gbps']}GB/s {r['kv_dtype']:<4s} "
                  f"pool={r['pool_frac']:<5} blocks={r['num_blocks']:<4d} "
                  f"preempt={r['preemptions']:<3d} "
                  f"offload={r['offload_bytes']}B "
                  f"tax={r['modeled_offload_tax_us']}us "
                  f"tax/tok={r['offload_tax_per_token_us']}us")
        for d in sweep["kv_dtype_deltas"]:
            print(f"delta[{d['platform']} pool={d['pool_frac']}] "
                  f"{d['baseline']}->{d['kv_dtype']}: "
                  f"capacity x{d['capacity_ratio']} "
                  f"preempt {d['preemptions'][d['baseline']]}->"
                  f"{d['preemptions'][d['kv_dtype']]} "
                  f"tax_delta={d['offload_tax_delta_us']}us")
        os.makedirs(args.out_dir, exist_ok=True)
        path = os.path.join(args.out_dir, "memory_sweep.json")
        with open(path, "w") as f:
            json.dump(sweep, f, indent=2)
        print(json.dumps({"summary": sweep, "artifacts": {"sweep": path}}))
        return
    workload = load_workload(args.replay) if args.replay else None
    batches = [int(b) for b in args.batches.split(",")]

    result = characterize(
        cfg, params, scenario=args.scenario, batches=batches,
        plan=args.plan, platform=args.platform, n_requests=args.requests,
        seed=args.seed, prompt_cap=args.prompt_cap or None,
        output_cap=args.output_cap or None, time_scale=args.time_scale,
        max_len=args.max_len, warmup=not args.no_warmup,
        workload=workload)

    for p in result.points:
        cls = result.boundedness.classify(p.batch)
        r = p.row()
        print(f"batch={p.batch:<3d} {cls:<9s} "
              f"launch_tax/step={r['decode_launch_tax_us']}us "
              f"step={r['mean_decode_step_us']}us "
              f"ttft_p50={r['ttft_p50_ms']}ms "
              f"ttft_p99={r['ttft_p99_ms']}ms "
              f"itl_p50={r['itl_p50_ms']}ms "
              f"itl_p99={r['itl_p99_ms']}ms "
              f"tok/s={r['tokens_per_s']}")
    infl = result.boundedness.inflection_batch
    print(f"inflection_batch={infl} "
          f"({'always CPU/dispatch-bound in range' if infl is None else 'GPU/compute-bound from here'})")

    if args.attribution_report:
        for p in result.points:
            rep = p.attribution
            if rep is None:
                print(f"attribution[batch={p.batch}]: unavailable "
                      "(no planned decode ran at this point)")
                continue
            print(f"attribution[batch={p.batch}] "
                  f"events={rep.total_events} complete={rep.complete} "
                  f"tklqt={rep.tklqt_s * 1e6:.1f}us")
            print(f"  {'operator':<12s} {'launches':>9s} {'launch_us':>10s} "
                  f"{'queue_us':>9s} {'exec_us':>9s} {'tklqt%':>7s}")
            for row in rep.as_dicts():
                print(f"  {row['operator']:<12s} {row['launches']:>9.1f} "
                      f"{row['launch_us']:>10.2f} {row['queue_us']:>9.2f} "
                      f"{row['exec_us']:>9.2f} {row['tklqt_pct']:>7.2f}")

    paths = write_artifacts(result, args.out_dir)
    print(json.dumps({"summary": result.summary(), "artifacts": paths}))


if __name__ == "__main__":
    main()

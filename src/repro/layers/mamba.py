"""Mamba (S6) selective-state-space mixer, used by the Jamba hybrid stack.

Forward over a segment runs a chunked time scan: `jax.checkpoint` on each
chunk body keeps backward memory at O(chunk-boundary states) instead of
O(T) full states.  Decode is a single-step state update.

State per layer: {"conv": (B, d_conv-1, d_inner), "h": (B, d_inner, d_state)}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.common import dense_init, split_keys

TIME_CHUNK = 256


def _dims(cfg: ModelConfig):
    m = cfg.mamba
    d_inner = m.expand * cfg.d_model
    dt_rank = m.dt_rank or -(-cfg.d_model // 16)
    return d_inner, m.d_state, m.d_conv, dt_rank


def mamba_init(key, cfg: ModelConfig):
    d = cfg.d_model
    d_inner, d_state, d_conv, dt_rank = _dims(cfg)
    ks = split_keys(key, 6)
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None],
                 (d_inner, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_inner), cfg.pdtype),
        "conv_w": dense_init(ks[1], (d_conv, d_inner), cfg.pdtype, scale=0.1),
        "conv_b": jnp.zeros((d_inner,), cfg.pdtype),
        "x_proj": dense_init(ks[2], (d_inner, dt_rank + 2 * d_state), cfg.pdtype),
        "dt_w": dense_init(ks[3], (dt_rank, d_inner), cfg.pdtype),
        "dt_b": jnp.full((d_inner,), -4.6, jnp.float32),   # softplus ~ 0.01
        "A_log": jnp.log(a),                               # (d_inner, d_state)
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[4], (d_inner, d), cfg.pdtype),
    }


def _conv_causal(x, w, b, prev):
    """Depthwise causal conv.  x: (B,S,di); w: (K,di); prev: (B,K-1,di)."""
    k = w.shape[0]
    xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)       # (B,S+K-1,di)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None] for i in range(k))
    return out + b[None, None].astype(x.dtype), xp[:, -(k - 1):, :]


def _ssm_params(params, xc, cfg: ModelConfig):
    """xc: (B,S,di) post-conv activations -> dt (B,S,di), Bm/Cm (B,S,ds)."""
    d_inner, d_state, _, dt_rank = _dims(cfg)
    proj = (xc @ params["x_proj"]).astype(jnp.float32)
    dt, bm, cm = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_w"].astype(jnp.float32)
                         + params["dt_b"])
    return dt, bm, cm


def _scan_chunk(h0, xs, a):
    """Per-step selective scan over one chunk.

    h0: (B,di,ds); xs = (xc, dt, bm, cm) each (B,C,...); a: (di,ds) = -A.
    """
    def step(h, inp):
        xc_t, dt_t, bm_t, cm_t = inp                    # (B,di),(B,di),(B,ds)x2
        da = jnp.exp(dt_t[..., None] * a[None])         # (B,di,ds)
        dbx = (dt_t * xc_t)[..., None] * bm_t[:, None, :]
        h = da * h + dbx
        y = jnp.einsum("bds,bs->bd", h, cm_t)
        return h, y

    xs_t = jax.tree.map(lambda v: v.swapaxes(0, 1), xs)  # (C,B,...)
    h, ys = jax.lax.scan(step, h0, xs_t)
    return h, ys.swapaxes(0, 1)                          # (B,C,di)


def mamba_fwd(params, x, cfg: ModelConfig, state=None):
    """x: (B,S,D) -> (out (B,S,D), new_state)."""
    b, s, d = x.shape
    d_inner, d_state, d_conv, _ = _dims(cfg)
    if state is None:
        state = {"conv": jnp.zeros((b, d_conv - 1, d_inner), x.dtype),
                 "h": jnp.zeros((b, d_inner, d_state), jnp.float32)}
    xz = x @ params["in_proj"]
    xr, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _conv_causal(xr, params["conv_w"], params["conv_b"],
                                  state["conv"])
    xc = jax.nn.silu(xc)
    dt, bm, cm = _ssm_params(params, xc, cfg)
    a = -jnp.exp(params["A_log"])                        # (di,ds), negative
    xcf = xc.astype(jnp.float32)

    if s == 1:
        h, ys = _scan_chunk(state["h"], (xcf, dt, bm, cm), a)
    else:
        chunk = min(TIME_CHUNK, s)
        pad = (-s) % chunk
        if pad:
            def pf(v):
                return jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
            xcf_, dt_, bm_, cm_ = pf(xcf), pf(dt), pf(bm), pf(cm)
        else:
            xcf_, dt_, bm_, cm_ = xcf, dt, bm, cm
        n = xcf_.shape[1] // chunk
        def resh(v):
            return v.reshape(b, n, chunk, v.shape[-1]).swapaxes(0, 1)
        xs = (resh(xcf_), resh(dt_), resh(bm_), resh(cm_))

        body = jax.checkpoint(functools.partial(_scan_chunk, a=a))
        h, ys = jax.lax.scan(lambda c, xx: body(c, xx), state["h"], xs)
        ys = ys.swapaxes(0, 1).reshape(b, n * chunk, d_inner)[:, :s]

    y = ys + params["D"][None, None] * xcf
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return out, {"conv": conv_state, "h": h}

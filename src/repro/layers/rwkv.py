"""RWKV6 (Finch) time-mix + channel-mix with data-dependent decay.

Recurrence semantics (per head, state S in R^{hd_k x hd_v}):

    o_t = r_t @ S_{t-1}  +  (r_t . (u (.) k_t)) v_t
    S_t = diag(w_t) S_{t-1} + k_t^T v_t

with w_t = exp(-exp(w0 + lora(x))) in (0,1) data-dependent per channel.

Two execution forms with identical math:
  * per-step recurrence (decode; also the oracle in kernels/rwkv6/ref.py)
  * chunked parallel form (train/prefill): within-chunk pairwise decays are
    computed in log space, exp() only of non-positive quantities -> no
    overflow for any decay magnitude.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.common import dense_init, split_keys

DECAY_LORA = 64
CHUNK = 16


def rwkv_time_init(key, cfg: ModelConfig):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = split_keys(key, 10)
    return {
        "mix_r": jnp.full((d,), 0.5, cfg.pdtype),
        "mix_k": jnp.full((d,), 0.5, cfg.pdtype),
        "mix_v": jnp.full((d,), 0.5, cfg.pdtype),
        "mix_w": jnp.full((d,), 0.5, cfg.pdtype),
        "mix_g": jnp.full((d,), 0.5, cfg.pdtype),
        "wr": dense_init(ks[0], (d, h * hd), cfg.pdtype),
        "wk": dense_init(ks[1], (d, h * hd), cfg.pdtype),
        "wv": dense_init(ks[2], (d, h * hd), cfg.pdtype),
        "wg": dense_init(ks[3], (d, h * hd), cfg.pdtype),
        "wo": dense_init(ks[4], (h * hd, d), cfg.pdtype),
        # data-dependent decay LoRA: w = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((h * hd,), -6.0, jnp.float32),
        "wA": dense_init(ks[5], (d, DECAY_LORA), cfg.pdtype),
        "wB": dense_init(ks[6], (DECAY_LORA, h * hd), cfg.pdtype),
        "u": dense_init(ks[7], (h, hd), jnp.float32, scale=0.5),
        "ln_scale": jnp.ones((h, hd), jnp.float32),
        "ln_bias": jnp.zeros((h, hd), jnp.float32),
    }


def rwkv_channel_init(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 2)
    return {
        "mix_k": jnp.full((d,), 0.5, cfg.pdtype),
        "wk": dense_init(ks[0], (d, f), cfg.pdtype),
        "wv": dense_init(ks[1], (f, d), cfg.pdtype),
    }


def _token_shift(x, prev, mix):
    """x: (B,S,D); prev: (B,D) last token of previous segment."""
    shifted = jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)
    return x + mix.astype(x.dtype) * (shifted - x)


def _head_ln(o, scale, bias, eps=1e-5):
    """Per-head layernorm (RWKV GroupNorm with groups == heads)."""
    of = o.astype(jnp.float32)
    mu = jnp.mean(of, axis=-1, keepdims=True)
    var = jnp.var(of, axis=-1, keepdims=True)
    return (of - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def wkv_chunked(r, k, v, logw, u, s0, chunk: int = CHUNK):
    """Chunked-parallel WKV6.

    r,k,v: (B,T,H,hd) f32; logw: (B,T,H,hd) f32 (log decay, <= 0)
    u: (H,hd) f32; s0: (B,H,hd,hd) f32 initial state.
    Returns o: (B,T,H,hd) f32, sT.
    """
    b, t, h, hd = r.shape
    assert t % chunk == 0, (t, chunk)
    n = t // chunk
    rs = r.reshape(b, n, chunk, h, hd).transpose(1, 0, 3, 2, 4)   # (n,B,H,C,hd)
    ks_ = k.reshape(b, n, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, n, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    lw = logw.reshape(b, n, chunk, h, hd).transpose(1, 0, 3, 2, 4)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool), -1)          # strict
    eye = jnp.eye(chunk, dtype=jnp.float32)

    def body(s, xs):
        rc, kc, vc, lwc = xs                                       # (B,H,C,hd)
        cum = jnp.cumsum(lwc, axis=2)                              # inclusive
        cum_exc = cum - lwc                                        # exclusive
        # pairwise decay exp(cum_exc[t] - cum[s]) for s < t  (always <= 0)
        pair = cum_exc[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,H,C,C,hd)
        pair = jnp.where(causal[None, None, :, :, None], pair, -jnp.inf)
        m = jnp.exp(pair)
        a = jnp.einsum("bhti,bhsi,bhtsi->bhts", rc, kc, m)
        diag_vals = jnp.einsum("bhti,hi,bhti->bht", rc, u, kc)     # (B,H,C)
        a = a + diag_vals[..., None] * eye[None, None]
        inter = jnp.einsum("bhts,bhsj->bhtj", a, vc)
        # cross-chunk: o += (r .* exp(cum_exc)) @ s
        dq = jnp.exp(cum_exc)
        cross = jnp.einsum("bhti,bhij->bhtj", rc * dq, s)
        oc = inter + cross
        # state update: s' = diag(exp(cum_T)) s + sum_s (k_s .* exp(cum_T-cum_s)) v_s
        tot = cum[:, :, -1:, :]                                    # (B,H,1,hd)
        dk = jnp.exp(tot - cum)                                    # (B,H,C,hd)
        s_new = jnp.exp(tot[:, :, 0, :])[..., None] * s + \
            jnp.einsum("bhsi,bhsj->bhij", kc * dk, vc)
        return s_new, oc

    # checkpoint: the (B,H,C,C,hd) pairwise-decay tensors are recomputed in
    # backward instead of being stacked into scan residuals (10s of GB/dev)
    sT, os_ = jax.lax.scan(jax.checkpoint(body), s0, (rs, ks_, vs, lw))
    o = os_.transpose(1, 0, 3, 2, 4).reshape(b, t, h, hd)
    return o, sT


def wkv_step(r, k, v, logw, u, s):
    """Single-token recurrence.  r,k,v,logw: (B,H,hd); s: (B,H,hd,hd)."""
    w = jnp.exp(logw)
    rkv = jnp.einsum("bhi,hi,bhi->bh", r, u, k)[..., None] * v
    o = jnp.einsum("bhi,bhij->bhj", r, s) + rkv
    s_new = w[..., None] * s + jnp.einsum("bhi,bhj->bhij", k, v)
    return o, s_new


def _rkvwg(params, x, cfg: ModelConfig, prev):
    """Project token-shifted activations to r,k,v,logw,g."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.hd
    xr = _token_shift(x, prev, params["mix_r"])
    xk = _token_shift(x, prev, params["mix_k"])
    xv = _token_shift(x, prev, params["mix_v"])
    xw = _token_shift(x, prev, params["mix_w"])
    xg = _token_shift(x, prev, params["mix_g"])
    r = (xr @ params["wr"]).astype(jnp.float32).reshape(b, s, h, hd)
    k = (xk @ params["wk"]).astype(jnp.float32).reshape(b, s, h, hd)
    v = (xv @ params["wv"]).astype(jnp.float32).reshape(b, s, h, hd)
    g = xg @ params["wg"]
    lora = jnp.tanh(xw @ params["wA"]) @ params["wB"]
    logw = -jnp.exp(params["w0"] + lora.astype(jnp.float32))       # <= 0
    logw = logw.reshape(b, s, h, hd)
    return r, k, v, logw, g


def rwkv_time_fwd(params, x, cfg: ModelConfig, state=None, shd=None):
    """Time-mix over a full segment.  state: {"shift": (B,D), "s": (B,H,hd,hd)}.

    shd: optional sharding hook — the WKV recurrence has no TP-shardable
    head count (40 heads vs 16-way model axis), so "wkv"-tagged tensors are
    batch-oversharded across data x model instead of replicated 16x.
    """
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.hd
    if state is None:
        state = {"shift": jnp.zeros((b, d), x.dtype),
                 "s": jnp.zeros((b, h, hd, hd), jnp.float32)}
    r, k, v, logw, g = _rkvwg(params, x, cfg, state["shift"])
    if shd is not None and s > 1:
        r, k, v, logw = (shd("wkv", t) for t in (r, k, v, logw))
    if s == 1:
        o, s_new = wkv_step(r[:, 0], k[:, 0], v[:, 0], logw[:, 0],
                            params["u"], state["s"])
        o = o[:, None]
    else:
        pad = (-s) % CHUNK
        if pad:
            def zf(a):
                return jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
            r, k, v = zf(r), zf(k), zf(v)
            logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
        o, s_new = wkv_chunked(r, k, v, logw, params["u"], state["s"])
        o = o[:, :s]
    o = _head_ln(o, params["ln_scale"], params["ln_bias"])
    o = o.reshape(b, s, h * hd).astype(x.dtype)
    o = o * jax.nn.silu(g)
    out = o @ params["wo"]
    new_state = {"shift": x[:, -1, :], "s": s_new}
    return out, new_state


def rwkv_channel_fwd(params, x, cfg: ModelConfig, state=None):
    """Channel-mix (squared-relu FFN with token shift). state: {"shift": (B,D)}."""
    b, s, d = x.shape
    if state is None:
        state = {"shift": jnp.zeros((b, d), x.dtype)}
    xk = _token_shift(x, state["shift"], params["mix_k"])
    k = jnp.square(jax.nn.relu(xk @ params["wk"]))
    out = k @ params["wv"]
    return out, {"shift": x[:, -1, :]}

"""Shared layer primitives: norms, rotary embeddings, MLPs, init helpers.

All forwards are pure functions ``f(params, x, cfg, ...)`` over pytree params
so they compose with jit/scan/pjit without a module framework.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# --------------------------------------------------------------------- init
def dense_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# --------------------------------------------------------------------- norms
def rmsnorm(x, weight, eps: float = 1e-5, plus_one: bool = False):
    """RMSNorm in fp32 with cast-back (gemma uses (1+w) parameterization)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:
        w = w + 1.0
    return (xf * w).astype(dtype)


def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


# --------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                        # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- mlp
def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = split_keys(key, 3)
    p = {"w_in": dense_init(ks[0], (d, f), cfg.pdtype),
         "w_out": dense_init(ks[1], (f, d), cfg.pdtype)}
    if cfg.glu:
        p["w_gate"] = dense_init(ks[2], (d, f), cfg.pdtype)
    return p


def activation(x, act: str):
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(act)


def mlp_fwd(params, x, cfg: ModelConfig, reduce=None):
    """Gated/plain MLP.  ``reduce`` is the tensor-parallel output hook:
    with ``w_in``/``w_gate`` column-sharded and ``w_out`` row-sharded over
    a model axis (Megatron layout), ``h @ w_out`` is a partial sum per
    device and ``reduce("mlp_out", y)`` psums it inside shard_map; None
    (single device / GSPMD paths) is identity."""
    h = x @ params["w_in"]
    if cfg.glu:
        h = activation(x @ params["w_gate"], cfg.act) * h
    else:
        h = activation(h, cfg.act)
    y = h @ params["w_out"]
    return reduce("mlp_out", y) if reduce is not None else y


# --------------------------------------------------------------------- misc
def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap) if cap else x


def embed_tokens(embedding, tokens, cfg: ModelConfig):
    x = jnp.take(embedding, tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def unembed(x, embedding, head, cfg: ModelConfig):
    w = embedding.T if cfg.tie_embeddings else head
    logits = x @ w.astype(x.dtype)
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)

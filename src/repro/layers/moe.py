"""Mixture-of-Experts: router, capacity dispatch, expert-parallel execution.

Three execution paths sharing the same math:

* ``moe_dense_fwd``   — naive all-experts reference (tiny tests only).
* ``moe_local_fwd``   — sort-based capacity dispatch, all experts local
                        (single-device smoke tests; also the per-shard body
                        of the EP paths).
* ``moe_ep_fwd``      — expert parallelism over the ``model`` mesh axis via
                        shard_map.  Two modes:
                          - "seq": tokens sequence-sharded over the EP axis,
                            all_to_all dispatch/return (train & prefill).
                          - "rep": tokens replicated over the EP axis, each
                            shard computes only its local experts, psum
                            combine (decode, where seq is unshardable).

Capacity dropping: per-shard capacity C = ceil(T*k/E * capacity_factor)
rounded up to a multiple of 8; tokens beyond capacity are dropped (standard
Switch-style semantics).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import axis_size, shard_map

from repro.configs.base import ModelConfig
from repro.layers.common import activation, dense_init, split_keys


class MeshContext(NamedTuple):
    """Distribution context threaded through model forwards."""
    mesh: object                   # jax.sharding.Mesh
    dp_axes: Tuple[str, ...]       # batch-sharding axes, e.g. ("pod","data")
    tp_axis: str                   # tensor/expert-parallel axis, e.g. "model"
    fsdp_axis: Optional[str] = None  # ZeRO-3 axis for expert weights (kimi/jamba)

    @property
    def ep_size(self) -> int:
        return self.mesh.shape[self.tp_axis]

    @property
    def fsdp_size(self) -> int:
        return self.mesh.shape[self.fsdp_axis] if self.fsdp_axis else 1


# ------------------------------------------------------------------ init
def moe_init(key, cfg: ModelConfig):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_expert, m.n_experts
    ks = split_keys(key, 6)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32, scale=0.006),
        "w_in": dense_init(ks[1], (e, d, f), cfg.pdtype),
        "w_gate": dense_init(ks[2], (e, d, f), cfg.pdtype),
        "w_out": dense_init(ks[3], (e, f, d), cfg.pdtype),
    }
    if m.n_shared_experts:
        fs = f * m.n_shared_experts
        p["shared_in"] = dense_init(ks[4], (d, fs), cfg.pdtype)
        p["shared_gate"] = dense_init(ks[5], (d, fs), cfg.pdtype)
        p["shared_out"] = dense_init(ks[4], (fs, d), cfg.pdtype)
    return p


# ------------------------------------------------------------------ router
def route(x2d, router_w, cfg: ModelConfig):
    """x2d: (T, D) -> gates (T,k) f32, eids (T,k) i32, aux-loss scalar."""
    m = cfg.moe
    logits = x2d.astype(jnp.float32) @ router_w.astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)           # renorm
    # load-balancing aux (Switch): E * sum_e f_e * P_e
    onehot = jax.nn.one_hot(eids, m.n_experts, dtype=jnp.float32)    # (T,k,E)
    f_e = jnp.mean(jnp.sum(onehot, axis=1), axis=0)                  # (E,)
    p_e = jnp.mean(probs, axis=0)                                    # (E,)
    aux = m.n_experts * jnp.sum(f_e * p_e) / m.top_k
    return gates, eids.astype(jnp.int32), aux


def capacity(t_local: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(-(-t_local * m.top_k * m.capacity_factor // m.n_experts))
    return max(8, -(-c // 8) * 8)


def dispatch_slots(eids, n_experts: int, cap: int):
    """Sort-based position-in-expert.  eids: (T,k) -> slots (T*k,), keep (T*k,).

    slot = expert_id * cap + position_within_expert for kept assignments;
    dropped assignments get slot = n_experts*cap (a dump row).
    """
    tk = eids.size
    flat_e = eids.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    pos_sorted = jnp.arange(tk, dtype=jnp.int32) - seg_start[sorted_e].astype(jnp.int32)
    pos = jnp.zeros((tk,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, n_experts * cap)
    return slot, keep


def expert_ffn(w_in, w_gate, w_out, xb, act: str):
    """xb: (E, N, D) batched per-expert FFN."""
    h = jnp.einsum("end,edf->enf", xb, w_in)
    g = jnp.einsum("end,edf->enf", xb, w_gate)
    h = activation(g, act) * h
    return jnp.einsum("enf,efd->end", h, w_out)


def _shared(params, x2d, cfg: ModelConfig):
    if "shared_in" not in params:
        return 0.0
    h = x2d @ params["shared_in"]
    g = activation(x2d @ params["shared_gate"], cfg.act)
    return (g * h) @ params["shared_out"]


# ------------------------------------------------------------------ dense ref
def moe_dense_fwd(params, x, cfg: ModelConfig):
    """All experts on all tokens — O(E) flops, tiny-test reference only."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    gates, eids, aux = route(xt, params["router"], cfg)
    xb = jnp.broadcast_to(xt[None], (cfg.moe.n_experts,) + xt.shape)
    ys = expert_ffn(params["w_in"], params["w_gate"], params["w_out"], xb, cfg.act)
    # combine: sum_k gate_k * y[eid_k]
    yk = jnp.take_along_axis(
        ys.transpose(1, 0, 2), eids[..., None].astype(jnp.int32), axis=1)  # (T,k,D)
    out = jnp.sum(gates[..., None].astype(yk.dtype) * yk, axis=1)
    out = out + _shared(params, xt, cfg)
    return out.reshape(b, s, d).astype(x.dtype), aux


# ------------------------------------------------------------------ local
def _dispatch_combine(params, xt, cfg: ModelConfig, w_in, w_gate, w_out,
                      expert_mask=None, local_offset=None):
    """Shared body: route/dispatch xt (T,D) against given expert weights.

    expert_mask: optional (E,) bool — only dispatch to these experts (rep-EP).
    local_offset: first expert id owned by this shard (rep-EP).
    Returns (combined (T,D), aux).
    """
    t, d = xt.shape
    e_global = cfg.moe.n_experts
    cap = capacity(t, cfg)
    gates, eids, aux = route(xt, params["router"], cfg)
    slot, keep = dispatch_slots(eids, e_global, cap)
    if expert_mask is not None:
        keep = keep & expert_mask[eids.reshape(-1)]
        slot = jnp.where(keep, slot, e_global * cap)
    # gather token vectors per assignment and scatter into the expert buffer
    tok_idx = jnp.arange(t * cfg.moe.top_k, dtype=jnp.int32) // cfg.moe.top_k
    buf = jnp.zeros((e_global * cap + 1, d), xt.dtype)
    buf = buf.at[slot].set(xt[tok_idx], mode="drop")
    buf = buf[:-1].reshape(e_global, cap, d)

    e_local = w_in.shape[0]
    if e_local != e_global:
        # rep-EP: this shard owns experts [lo, lo+e_local); slice its rows
        lo = local_offset
        buf = jax.lax.dynamic_slice_in_dim(buf, lo, e_local, axis=0)
    ys = expert_ffn(w_in, w_gate, w_out, buf, cfg.act)                # (El,C,D)
    if e_local != e_global:
        full = jnp.zeros((e_global, cap, d), ys.dtype)
        full = jax.lax.dynamic_update_slice_in_dim(full, ys, lo, axis=0)
        ys = full
    # combine
    ys_flat = jnp.concatenate(
        [ys.reshape(e_global * cap, d), jnp.zeros((1, d), ys.dtype)], axis=0)
    yk = ys_flat[slot].reshape(t, cfg.moe.top_k, d)
    gk = jnp.where(keep.reshape(t, cfg.moe.top_k), gates, 0.0)
    out = jnp.sum(gk[..., None].astype(jnp.float32) * yk.astype(jnp.float32), axis=1)
    return out.astype(xt.dtype), aux


def moe_local_fwd(params, x, cfg: ModelConfig):
    """Single-device capacity-dispatch MoE (no collectives)."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    out, aux = _dispatch_combine(params, xt, cfg, params["w_in"],
                                 params["w_gate"], params["w_out"])
    out = out + _shared(params, xt, cfg)
    return out.reshape(b, s, d).astype(x.dtype), aux


# ------------------------------------------------------------------ EP
def _gather_experts(params, fsdp_axis):
    """ZeRO-3: expert weights arrive d_ff-sharded over fsdp_axis; all-gather
    them just-in-time (storage stays sharded, compute sees full experts)."""
    if not fsdp_axis:
        return params
    p = dict(params)
    p["w_in"] = jax.lax.all_gather(params["w_in"], fsdp_axis, axis=2,
                                   tiled=True)
    p["w_gate"] = jax.lax.all_gather(params["w_gate"], fsdp_axis, axis=2,
                                     tiled=True)
    p["w_out"] = jax.lax.all_gather(params["w_out"], fsdp_axis, axis=1,
                                    tiled=True)
    return p


def _ep_seq_body(params, x, cfg: ModelConfig, dp_axes, tp_axis,
                 fsdp_axis=None):
    """Per-shard body, tokens seq-sharded over tp_axis.  x: (Bl, Sl, D)."""
    params = _gather_experts(params, fsdp_axis)
    bl, sl, d = x.shape
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    m = cfg.moe
    e_global, e_local = m.n_experts, m.n_experts // axis_size(tp_axis)
    cap = capacity(t, cfg)
    gates, eids, aux = route(xt, params["router"], cfg)
    slot, keep = dispatch_slots(eids, e_global, cap)
    tok_idx = jnp.arange(t * m.top_k, dtype=jnp.int32) // m.top_k
    buf = jnp.zeros((e_global * cap + 1, d), xt.dtype)
    buf = buf.at[slot].set(xt[tok_idx], mode="drop")[:-1]
    buf = buf.reshape(e_global, cap, d)
    # exchange: (E, C, D) -> rows regrouped so this shard holds its experts'
    # tokens from every peer: (ep*E_local, C, D) with blocks [peer, local_e]
    buf = jax.lax.all_to_all(buf, tp_axis, split_axis=0, concat_axis=0,
                             tiled=True)
    ep = axis_size(tp_axis)
    xb = buf.reshape(ep, e_local, cap, d).transpose(1, 0, 2, 3)
    xb = xb.reshape(e_local, ep * cap, d)
    ys = expert_ffn(params["w_in"], params["w_gate"], params["w_out"], xb, cfg.act)
    ys = ys.reshape(e_local, ep, cap, d).transpose(1, 0, 2, 3)
    ys = ys.reshape(e_global, cap, d)
    ys = jax.lax.all_to_all(ys, tp_axis, split_axis=0, concat_axis=0,
                            tiled=True)
    ys_flat = jnp.concatenate(
        [ys.reshape(e_global * cap, d), jnp.zeros((1, d), ys.dtype)], axis=0)
    yk = ys_flat[slot].reshape(t, m.top_k, d)
    gk = jnp.where(keep.reshape(t, m.top_k), gates, 0.0)
    out = jnp.sum(gk[..., None].astype(jnp.float32) * yk.astype(jnp.float32),
                  axis=1).astype(xt.dtype)
    out = out + _shared(params, xt, cfg)
    aux = jax.lax.pmean(aux, dp_axes + (tp_axis,)) if dp_axes else \
        jax.lax.pmean(aux, tp_axis)
    return out.reshape(bl, sl, d), aux


def _ep_rep_body(params, x, cfg: ModelConfig, dp_axes, tp_axis,
                 fsdp_axis=None):
    """Per-shard body, tokens replicated over tp_axis.  x: (Bl, S, D)."""
    params = _gather_experts(params, fsdp_axis)
    bl, s, d = x.shape
    xt = x.reshape(-1, d)
    ep = axis_size(tp_axis)
    e_local = cfg.moe.n_experts // ep
    my = jax.lax.axis_index(tp_axis)
    expert_mask = (jnp.arange(cfg.moe.n_experts) // e_local) == my
    out, aux = _dispatch_combine(
        params, xt, cfg,
        params["w_in"], params["w_gate"], params["w_out"],
        expert_mask=expert_mask, local_offset=my * e_local)
    out = jax.lax.psum(out, tp_axis)
    # shared experts once (identical on every shard — do NOT psum)
    out = out + _shared(params, xt, cfg)
    if dp_axes:
        aux = jax.lax.pmean(aux, dp_axes)
    return out.reshape(bl, s, d), aux


def moe_ep_fwd(params, x, cfg: ModelConfig, dist: MeshContext,
               mode: str = "auto"):
    """Expert-parallel MoE.  x: (B, S, D) global."""
    if mode == "auto":
        mode = "seq" if x.shape[1] % dist.ep_size == 0 else "rep"
    tp = dist.tp_axis
    # effective dp axes: longest prefix whose product divides the batch
    # (decode at batch=1 runs fully replicated over dp)
    dp, prod = [], 1
    for a in dist.dp_axes:
        if x.shape[0] % (prod * dist.mesh.shape[a]) == 0:
            dp.append(a)
            prod *= dist.mesh.shape[a]
    dp = tuple(dp)
    fsdp = dist.fsdp_axis
    if fsdp and (cfg.moe.d_expert % dist.fsdp_size or
                 cfg.moe.n_experts % dist.ep_size):
        fsdp = None
    wspec = {"router": P(),
             "w_in": P(tp, None, fsdp),
             "w_gate": P(tp, None, fsdp),
             "w_out": P(tp, fsdp, None)}
    for k in ("shared_in", "shared_gate", "shared_out"):
        if k in params:
            wspec[k] = P()
    wspec = {k: wspec[k] for k in params}
    if mode == "seq":
        body = functools.partial(_ep_seq_body, cfg=cfg, dp_axes=dp,
                                 tp_axis=tp, fsdp_axis=fsdp)
        xspec = P(dp, tp, None)
    else:
        body = functools.partial(_ep_rep_body, cfg=cfg, dp_axes=dp,
                                 tp_axis=tp, fsdp_axis=fsdp)
        xspec = P(dp, None, None)
    fn = shard_map(
        lambda p_, x_: body(p_, x_),
        mesh=dist.mesh,
        in_specs=(wspec, xspec),
        out_specs=(xspec, P()),
        check_vma=False,
    )
    return fn(params, x)


def moe_fwd(params, x, cfg: ModelConfig, dist: Optional[MeshContext] = None,
            mode: str = "auto"):
    """Entry point: EP when a mesh context is given, local otherwise."""
    if dist is None:
        return moe_local_fwd(params, x, cfg)
    return moe_ep_fwd(params, x, cfg, dist, mode=mode)

"""Attention: GQA, sliding-window, logit softcap, cross-attention, KV cache.

This is the pure-XLA reference path used for distribution lowering and smoke
tests; the Pallas flash/decode kernels in ``repro/kernels`` implement the
same math as the TPU-target hot-spot (see kernels/*/ref.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.inference.kv_quant import dequantize_kv, quantize_kv
from repro.layers.common import apply_rope, dense_init, softcap, split_keys

NEG_INF = -2.3819763e38  # large negative, bf16-safe


def attention_init(key, cfg: ModelConfig, cross: bool = False):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq * hd), cfg.pdtype),
        "wk": dense_init(ks[1], (d, hkv * hd), cfg.pdtype),
        "wv": dense_init(ks[2], (d, hkv * hd), cfg.pdtype),
        "wo": dense_init(ks[3], (hq * hd, d), cfg.pdtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((hq * hd,), cfg.pdtype)
        p["bk"] = jnp.zeros((hkv * hd,), cfg.pdtype)
        p["bv"] = jnp.zeros((hkv * hd,), cfg.pdtype)
    return p


def _project(params, x, cfg, name, heads):
    y = x @ params[f"w{name}"]
    if f"b{name}" in params:
        y = y + params[f"b{name}"].astype(y.dtype)
    b, s = x.shape[0], x.shape[1]
    return y.reshape(b, s, heads, cfg.hd)


def _expand_kv(k, g):
    """(B,T,HKV,hd) -> (B,T,HQ,hd).  The repeat keeps the head axis a single
    contiguous dim so GSPMD shards it cleanly on the model axis (a (HKV,G)
    split would not be expressible with one mesh axis)."""
    return jnp.repeat(k, g, axis=2) if g > 1 else k


def _mask(q_positions, kv_positions, causal, window, kv_valid):
    m = jnp.ones(q_positions.shape[:1] + (q_positions.shape[1],
                                          kv_positions.shape[1]), bool)
    if causal:
        m &= q_positions[:, :, None] >= kv_positions[:, None, :]
    if window:
        m &= (q_positions[:, :, None] - kv_positions[:, None, :]) < window
    if kv_valid is not None:
        m &= kv_valid[:, None, :]
    return m                                             # (B,S,T)


def mha(q, k, v, *, scale, causal, window, cap,
        q_positions, kv_positions, kv_valid=None):
    """Dense attention core (small sequences / decode).

    q: (B,S,HQ,hd)  k/v: (B,T,HKV,hd)
    q_positions: (B,S) | kv_positions: (B,T) | kv_valid: (B,T) bool or None
    """
    b, s, hq, hd = q.shape
    g = hq // k.shape[2]
    k, v = _expand_kv(k, g), _expand_kv(v, g)
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    scores = softcap(scores, cap)
    mask = _mask(q_positions, kv_positions, causal, window, kv_valid)
    scores = jnp.where(mask[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v)
    return out


def flash_mha(q, k, v, *, scale, causal, window, cap,
              q_positions, kv_positions, kv_valid=None, block_kv: int = 512):
    """Flash-style attention: online softmax over KV blocks inside a scan —
    the (S,T) score matrix never materializes (this is the XLA analogue of
    the Pallas kernel in repro/kernels/flash_attention).

    Each block body is checkpointed so backward re-computes block scores
    instead of saving them.
    """
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    nk = -(-t // block_kv)
    pad = nk * block_kv - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)))
        valid = jnp.ones((b, t), bool) if kv_valid is None else kv_valid
        kv_valid = jnp.pad(valid, ((0, 0), (0, pad)))
    qf = q.astype(jnp.float32)

    kb = k.reshape(b, nk, block_kv, hkv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, block_kv, hkv, hd).transpose(1, 0, 2, 3, 4)
    pb = kv_positions.reshape(b, nk, block_kv).transpose(1, 0, 2)
    if kv_valid is not None:
        valb = kv_valid.reshape(b, nk, block_kv).transpose(1, 0, 2)
    else:
        valb = jnp.ones((nk, b, block_kv), bool)

    def body(carry, blk):
        m, lsum, acc = carry
        kj, vj, posj, valj = blk
        kj = _expand_kv(kj, g).astype(jnp.float32)
        vj = _expand_kv(vj, g).astype(jnp.float32)
        sc = jnp.einsum("bshd,bthd->bhst", qf, kj) * scale   # (B,H,S,Bk)
        sc = softcap(sc, cap)
        msk = _mask(q_positions, posj, causal, window, valj)
        sc = jnp.where(msk[:, None], sc, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        lsum = lsum * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhst,bthd->bhsd", p, vj)
        return (m_new, lsum, acc), None

    m0 = jnp.full((b, hq, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, s), jnp.float32)
    a0 = jnp.zeros((b, hq, s, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                  (kb, vb, pb, valb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)     # (B,S,H,hd)


FLASH_MIN_SEQ = 1024


def attention_core(q, k, v, **kw):
    s, t = q.shape[1], k.shape[1]
    if s >= FLASH_MIN_SEQ and t >= FLASH_MIN_SEQ:
        return flash_mha(q, k, v, **kw)
    return mha(q, k, v, **kw)


def _paged_attention_fwd(q, k, v, cache, block_tables, positions, lengths,
                         cache_index, cfg: ModelConfig, *,
                         causal, window, scale):
    """Self-attention over the block-table paged KV cache.

    Pages are pool-global — k_pages/v_pages: (P, bs, HKV, hd) — and
    ``block_tables`` (B, NB) maps a row's logical token position ``t`` to
    page ``bt[b, t // bs]``.  New K/V rows are scattered at their positions
    (out-of-range table entries — the pool's pad sentinel — drop the
    write), then each row's logical view is gathered back for the masked
    attention core: the pure-XLA analogue of
    ``repro.kernels.decode_attention.paged_decode_attention``.
    """
    assert block_tables is not None, "paged KV cache needs block_tables"
    b, s = q.shape[0], q.shape[1]
    kp, vp = cache["k_pages"], cache["v_pages"]
    n_pages, bs_blk = kp.shape[0], kp.shape[1]
    quantized = "k_scale" in cache
    blk = positions // bs_blk
    nb = block_tables.shape[1]
    pages = jnp.take_along_axis(block_tables, jnp.minimum(blk, nb - 1), axis=1)
    # positions past the slot's table (a verify window crossing max_len)
    # must DROP, not clamp onto the last real page
    pages = jnp.where(blk < nb, pages, n_pages)
    offs = positions % bs_blk
    if quantized:
        # quantize-on-write: only the int8 payload + per-(token,head) f32
        # scale ever live in the pool; the bf16 intermediate is transient
        qk, sk = quantize_kv(k)
        qv, sv = quantize_kv(v)
        kp = kp.at[pages, offs].set(qk, mode="drop")
        vp = vp.at[pages, offs].set(qv, mode="drop")
        ksp = cache["k_scale"].at[pages, offs].set(sk, mode="drop")
        vsp = cache["v_scale"].at[pages, offs].set(sv, mode="drop")
        new_cache = {"k_pages": kp, "v_pages": vp,
                     "k_scale": ksp, "v_scale": vsp}
    else:
        kp = kp.at[pages, offs].set(k.astype(kp.dtype), mode="drop")
        vp = vp.at[pages, offs].set(v.astype(vp.dtype), mode="drop")
        new_cache = {"k_pages": kp, "v_pages": vp}
    safe = jnp.clip(block_tables, 0, n_pages - 1)
    t = block_tables.shape[1] * bs_blk
    if quantized:
        # dequantize-at-load: gather int8 pages + scales, widen to the
        # compute dtype only in the transient logical view
        kg = dequantize_kv(kp[safe], ksp[safe], k.dtype)
        vg = dequantize_kv(vp[safe], vsp[safe], v.dtype)
        kg = kg.reshape(b, t, kp.shape[2], kp.shape[3])
        vg = vg.reshape(b, t, vp.shape[2], vp.shape[3])
    else:
        kg = kp[safe].reshape(b, t, kp.shape[2], kp.shape[3])
        vg = vp[safe].reshape(b, t, vp.shape[2], vp.shape[3])
    kv_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    if lengths is not None:
        # continuous-batching decode / speculative verify: row b just wrote
        # S tokens at lengths[b] .. lengths[b]+S-1 (the causal mask over
        # q_positions orders the in-window tokens)
        kv_valid = kv_pos < lengths[:, None] + s
    else:
        # (chunked) prefill: tokens [cache_index, cache_index + s) written
        kv_valid = kv_pos < cache_index + s
    out = attention_core(q, kg, vg, scale=scale, causal=causal,
                         window=window, cap=cfg.attn_softcap,
                         q_positions=positions, kv_positions=kv_pos,
                         kv_valid=kv_valid)
    return out, new_cache


def attention_fwd(
    params,
    x,
    cfg: ModelConfig,
    *,
    positions,                      # (B,S) int32 positions of x tokens
    causal: bool = True,
    window: int = 0,
    is_cross: bool = False,
    cross_kv: Optional[jax.Array] = None,   # (B,T,d) encoder/image states
    cache: Optional[dict] = None,           # {"k","v"}: (B,Tmax,HKV,hd)
    cache_index: Optional[jax.Array] = None,  # scalar int32 write offset
    lengths: Optional[jax.Array] = None,    # (B,) per-row lengths (cont. batching)
    shd=None,                               # sharding hook (head-parallel attn)
    block_tables: Optional[jax.Array] = None,  # (B,NB) page ids (paged cache)
    reduce=None,                            # TP output hook (psum in shard_map)
):
    """Returns (out (B,S,d), new_cache|None).

    Cross attention: if ``cross_kv`` is given, K/V are (re)computed from it
    (and written into ``cache`` when one is passed — prefill).  If
    ``cross_kv`` is None but ``is_cross``, K/V come from the cache (decode).

    ``reduce``: with wq/wk/wv column-sharded by head and wo row-sharded
    over a model axis (Megatron layout), the post-``wo`` output is a
    partial sum per device; ``reduce("attn_out", y)`` psums it inside a
    shard_map body.  None (single device / GSPMD) is identity.  ``cfg``
    must then carry the LOCAL head counts (the sharded backend passes a
    per-device config).
    """
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    scale = cfg.attn_scale or cfg.hd ** -0.5
    b, s = x.shape[0], x.shape[1]

    def finish(o):
        y = o.reshape(b, s, hq * cfg.hd) @ params["wo"]
        return reduce("attn_out", y) if reduce is not None else y

    q = _project(params, x, cfg, "q", hq)
    new_cache = None

    if is_cross:
        if cross_kv is not None:
            src = cross_kv.astype(x.dtype)
            k = _project(params, src, cfg, "k", hkv)
            v = _project(params, src, cfg, "v", hkv)
            if cache is not None:
                new_cache = {"k": k.astype(cache["k"].dtype),
                             "v": v.astype(cache["v"].dtype)}
        else:
            assert cache is not None, "cross-attn decode needs a cross cache"
            k, v = cache["k"], cache["v"]
            new_cache = cache
        t = k.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
        out = attention_core(q, k, v, scale=scale, causal=False, window=0,
                             cap=cfg.attn_softcap, q_positions=positions,
                             kv_positions=kv_pos)
    else:
        k = _project(params, x, cfg, "k", hkv)
        v = _project(params, x, cfg, "v", hkv)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        if cache is not None and "k_pages" in cache:
            out, new_cache = _paged_attention_fwd(
                q, k, v, cache, block_tables, positions, lengths,
                cache_index, cfg, causal=causal, window=window, scale=scale)
            return finish(out), new_cache
        if shd is not None:
            if s == 1 and cache is not None:
                # decode: the q row is tiny — replicate it over tp and keep
                # the KV cache in place (T- or head-sharded per its spec).
                # Forcing head-sharded q here makes GSPMD all-gather the
                # ENTIRE cache per layer per token (~GBs/step).
                q = shd("q_decode", q)
            else:
                q = shd("q_heads", q)
                k = shd("kv_heads", k)
                v = shd("kv_heads", v)
        if cache is not None:
            if lengths is not None:
                # continuous-batching decode (S == 1) or speculative verify
                # (S == k+1): row b writes its S tokens at positions[b]
                # (lengths[b] + 0..S-1 by default) and sees only its own
                # prefix; the causal mask over q_positions orders the
                # in-window tokens.  Out-of-range positions (padding past
                # max_len) drop the write.
                rows = jnp.arange(b)[:, None]
                ck = cache["k"].at[rows, positions].set(k, mode="drop")
                cv = cache["v"].at[rows, positions].set(v, mode="drop")
                new_cache = {"k": ck, "v": cv}
                tmax = ck.shape[1]
                kv_pos = jnp.broadcast_to(jnp.arange(tmax, dtype=jnp.int32),
                                          (b, tmax))
                kv_valid = kv_pos <= positions[:, -1:]
                out = attention_core(q, ck, cv, scale=scale, causal=causal,
                                     window=window, cap=cfg.attn_softcap,
                                     q_positions=positions,
                                     kv_positions=kv_pos, kv_valid=kv_valid)
                return finish(out), new_cache
            # append k/v at cache_index, attend over the full cache
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_index, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_index, 1)
            new_cache = {"k": ck, "v": cv}
            tmax = ck.shape[1]
            kv_pos = jnp.broadcast_to(jnp.arange(tmax, dtype=jnp.int32), (b, tmax))
            kv_valid = kv_pos < (cache_index + s)
            out = attention_core(q, ck, cv, scale=scale, causal=causal,
                                 window=window, cap=cfg.attn_softcap,
                                 q_positions=positions, kv_positions=kv_pos,
                                 kv_valid=kv_valid)
        else:
            out = attention_core(q, k, v, scale=scale, causal=causal,
                                 window=window, cap=cfg.attn_softcap,
                                 q_positions=positions, kv_positions=positions)

    if shd is not None and s == 1 and cache is not None and not is_cross:
        # keep the whole decode attention replicated-q / sharded-KV; only
        # the tiny (B,1,D) activation reshards before the wo matmul
        out = shd("q_decode", out)
    return finish(out), new_cache


def make_self_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    shape = (batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def make_paged_self_cache(cfg: ModelConfig, num_pages: int, block_size: int,
                          dtype, quantized: bool = False):
    """Pool-global paged KV: pages are shared by all slots via block tables
    (``repro.kvcache``) rather than pre-carved per batch row.

    ``quantized``: int8 payload pages plus per-(token, head) f32 scale
    pages (``inference.kv_quant`` layout) — hd bytes + 4 scale bytes per
    (token, head) instead of 2*hd, so the same pool bytes hold
    ~2*hd/(hd+4) more tokens.
    """
    shape = (num_pages, block_size, cfg.n_kv_heads, cfg.hd)
    if quantized:
        return {"k_pages": jnp.zeros(shape, jnp.int8),
                "v_pages": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1], jnp.float32),
                "v_scale": jnp.zeros(shape[:-1], jnp.float32)}
    return {"k_pages": jnp.zeros(shape, dtype),
            "v_pages": jnp.zeros(shape, dtype)}


def init_cross_cache(params, cfg: ModelConfig, cross_kv):
    """Precompute cross-attention K/V from encoder/image states."""
    k = _project(params, cross_kv, cfg, "k", cfg.n_kv_heads)
    v = _project(params, cross_kv, cfg, "v", cfg.n_kv_heads)
    return {"k": k, "v": v}

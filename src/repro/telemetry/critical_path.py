"""Critical-path analysis: decompose each request's latency into blame.

``analyze`` walks every ``RequestTrace`` a ``RequestTracer`` collected
and partitions the request's measured end-to-end interval
``[arrival, done]`` into exhaustive, non-overlapping segments:

  router_queue_wait    arrival → router dispatch (fleet ingress queue)
  admission_wait       dispatch (or arrival, engine-only runs) → slot
  prefill_exec         measured (chunked) prefill compute on the clock
  decode_exec          measured decode/verify steps the request rode
  launch_tax           host dispatch time carved out of exec intervals
                       (PR 7's measured per-call launch tax)
  interleave_wait      admitted but idle between steps (other replicas'
                       turns, other requests' prefill chunks)
  preemption_stall     evicted, waiting to be re-admitted
  offload_restore_tax  modeled KV offload/restore transfer time carved
                       out of the enclosing preemption stall

The partition is exact *by construction*: the walk keeps a monotone
cursor from ``arrival`` to ``done``, charges every gap between events to
the wait bucket of the request's current lifecycle state, and clamps
event timestamps to the cursor (router and replica clocks can disagree
by a dispatch — clamping folds the skew into the neighbouring wait
instead of double-counting).  The **conservation invariant** — segments
sum to the measured E2E within float tolerance — is therefore a
structural guarantee the tests assert per request, the request-level
analogue of the attribution layer's rational 100%-of-dispatches sum.

Offload/restore transfer is *modeled* tax (it never advances the
engine's virtual clock), so it cannot be its own clock interval without
breaking conservation; instead ``min(modeled tax, stall window)`` is
carved out of the preemption stall it hides inside.

On top of the decomposition: per-scenario ``SLO`` thresholds, a
``slo_report`` classifying every completed request (goodput = fraction
meeting both TTFT and ITL), ``record_goodput`` publishing first-class
goodput/blame families into a metrics registry, and ``triage`` — the
JSON report ``--trace-out`` ships, with a per-request waterfall and an
aggregate + p99-tail blame table ("p99 TTFT violators: 71%
router_queue_wait").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.telemetry.metrics import percentile

SEGMENTS = ("router_queue_wait", "admission_wait", "prefill_exec",
            "launch_tax", "decode_exec", "interleave_wait",
            "preemption_stall", "offload_restore_tax")

# wait bucket charged for a gap, by lifecycle state
_WAIT_BUCKET = {
    "queued": "admission_wait",        # engine-only runs: no router leg
    "routed": "router_queue_wait",     # queued behind the router
    "dispatched": "admission_wait",
    "admitted": "interleave_wait",
    "preempted": "preemption_stall",
}


@dataclass
class RequestBreakdown:
    """One request's measured latency, fully partitioned into segments.

    ``segments`` covers ``[arrival, done]``; ``ttft_segments`` is the
    same walk truncated at first token (intervals clipped, launch tax
    pro-rated).  ``pieces`` is the ordered ``(segment, t0, t1)`` timeline
    the Perfetto request track renders.
    """

    rid: int
    replica: Optional[int]
    arrival_s: float
    first_token_s: Optional[float]
    done_s: Optional[float]
    n_tokens: int = 0
    preemptions: int = 0
    segments: dict = field(default_factory=dict)
    ttft_segments: dict = field(default_factory=dict)
    pieces: list = field(default_factory=list)

    @property
    def e2e_s(self) -> float:
        """Measured end-to-end latency (arrival → final token)."""
        if self.done_s is None:
            return 0.0
        return self.done_s - self.arrival_s

    @property
    def ttft_s(self) -> float:
        """Measured time-to-first-token (arrival → first emission)."""
        if self.first_token_s is None:
            return 0.0
        return self.first_token_s - self.arrival_s

    @property
    def mean_itl_s(self) -> float:
        """Mean inter-token latency over the decode tail.  The engine's
        final token lands exactly at ``done``, so the mean is derived
        exactly from the anchors — no per-token events needed."""
        if (self.done_s is None or self.first_token_s is None
                or self.n_tokens < 2):
            return 0.0
        return (self.done_s - self.first_token_s) / (self.n_tokens - 1)

    @property
    def conservation_error(self) -> float:
        """|sum(segments) - measured E2E| in seconds."""
        return abs(sum(self.segments.values()) - self.e2e_s)

    @property
    def conserved(self) -> bool:
        """Conservation invariant: segments partition the measured E2E
        (tolerance scales with magnitude for float summation)."""
        return self.conservation_error <= 1e-9 + 1e-6 * abs(self.e2e_s)

    @property
    def dominant(self) -> str:
        """Segment holding the largest share of E2E."""
        return max(SEGMENTS, key=lambda s: self.segments.get(s, 0.0))

    @property
    def ttft_dominant(self) -> str:
        """Segment holding the largest share of TTFT."""
        return max(SEGMENTS, key=lambda s: self.ttft_segments.get(s, 0.0))


def _decompose(trace, until: Optional[float] = None):
    """Partition ``[arrival, end]`` of one trace into segments.

    Returns ``(segments, pieces)``.  ``until`` truncates the walk (the
    TTFT decomposition); exec intervals straddling the cut are clipped
    with their launch tax pro-rated by the surviving fraction.
    """
    done = trace.first("done")
    end = done.t0 if done is not None else max(
        (ev.t1 for ev in trace.events), default=trace.arrival_s)
    if until is not None:
        end = min(end, until)
    segments = {s: 0.0 for s in SEGMENTS}
    pieces: list = []
    has_dispatch = trace.first("dispatch") is not None

    def charge(seg, t0, t1):
        if t1 > t0:
            segments[seg] += t1 - t0
            if pieces and pieces[-1][0] == seg and pieces[-1][2] == t0:
                pieces[-1] = (seg, pieces[-1][1], t1)
            else:
                pieces.append((seg, t0, t1))

    t = trace.arrival_s
    state = "routed" if has_dispatch else "queued"
    pending_tax = 0.0  # modeled offload/restore tax awaiting its stall

    def charge_gap(t0, t1):
        nonlocal pending_tax
        if t1 <= t0:
            return
        if state == "preempted" and pending_tax > 0:
            carve = min(pending_tax, t1 - t0)
            charge("offload_restore_tax", t0, t0 + carve)
            pending_tax -= carve
            t0 += carve
        charge(_WAIT_BUCKET[state], t0, t1)

    for ev in trace.sorted_events():
        t0 = min(max(ev.t0, t), end)
        # restore tax is modeled transfer hiding in the stall that this
        # admit terminates — make it carvable before charging the gap
        if ev.kind == "admit" and state == "preempted":
            pending_tax += ev.meta.get("restore_tax_s", 0.0)
        charge_gap(t, t0)
        t = t0
        if ev.kind in ("prefill", "decode"):
            t1 = min(max(ev.t1, t), end)
            full = ev.t1 - ev.t0
            frac = (t1 - t0) / full if full > 0 else 0.0
            tax = min(t1 - t0, ev.meta.get("tax_s", 0.0) * frac)
            charge("launch_tax", t0, t0 + tax)
            exec_seg = ("prefill_exec" if ev.kind == "prefill"
                        else "decode_exec")
            charge(exec_seg, t0 + tax, t1)
            t = t1
        elif ev.kind == "dispatch":
            if state in ("queued", "routed"):
                state = "dispatched"
        elif ev.kind == "admit":
            state = "admitted"
        elif ev.kind == "preempt":
            state = "preempted"
            pending_tax += ev.meta.get("offload_tax_s", 0.0)
        if t >= end:
            break
    charge_gap(t, end)
    return segments, pieces


def breakdown(trace) -> RequestBreakdown:
    """Decompose one completed trace into a ``RequestBreakdown``."""
    done = trace.first("done")
    ft = trace.first("first_token")
    disp = trace.last("dispatch")
    segments, pieces = _decompose(trace)
    ttft_segments, _ = _decompose(
        trace, until=ft.t0 if ft is not None else None)
    return RequestBreakdown(
        rid=trace.rid,
        replica=(disp.meta.get("replica") if disp is not None else None),
        arrival_s=trace.arrival_s,
        first_token_s=(ft.t0 if ft is not None else None),
        done_s=(done.t0 if done is not None else None),
        n_tokens=(done.meta.get("n_tokens", 0) if done is not None else 0),
        preemptions=trace.count("preempt"),
        segments=segments,
        ttft_segments=ttft_segments,
        pieces=pieces,
    )


@dataclass
class CriticalPathAnalysis:
    """Fleet-wide view over every completed request's decomposition."""

    breakdowns: list
    rejected: list

    @property
    def conservation_ok(self) -> bool:
        """True when every request's partition conserves its E2E."""
        return all(b.conserved for b in self.breakdowns)

    def aggregate(self) -> dict:
        """Total seconds and share per segment across all requests."""
        totals = {s: 0.0 for s in SEGMENTS}
        for b in self.breakdowns:
            for s, v in b.segments.items():
                totals[s] += v
        whole = sum(totals.values())
        return {
            "total_s": totals,
            "share": {s: (v / whole if whole > 0 else 0.0)
                      for s, v in totals.items()},
        }

    def tail_blame(self, q: float = 99.0) -> dict:
        """Blame shares over the TTFT tail: requests at or above the
        ``q``-th TTFT percentile, decomposed by TTFT segment."""
        if not self.breakdowns:
            return {"quantile": q, "threshold_s": 0.0, "n": 0,
                    "share": {}, "dominant": None}
        ttfts = [b.ttft_s for b in self.breakdowns]
        thresh = percentile(ttfts, q)
        tail = [b for b in self.breakdowns if b.ttft_s >= thresh]
        totals = {s: 0.0 for s in SEGMENTS}
        for b in tail:
            for s, v in b.ttft_segments.items():
                totals[s] += v
        whole = sum(totals.values())
        share = {s: (v / whole if whole > 0 else 0.0)
                 for s, v in totals.items()}
        dominant = max(SEGMENTS, key=lambda s: share.get(s, 0.0))
        return {"quantile": q, "threshold_s": thresh, "n": len(tail),
                "share": share, "dominant": dominant}


def analyze(tracer) -> CriticalPathAnalysis:
    """Decompose every completed trace the tracer collected."""
    completed, rejected = [], []
    for rid, tr in sorted(tracer.traces.items()):
        if tr.first("reject") is not None:
            rejected.append(rid)
        elif tr.first("done") is not None:
            completed.append(breakdown(tr))
    return CriticalPathAnalysis(breakdowns=completed, rejected=rejected)


# ---------------------------------------------------------------- SLOs
@dataclass(frozen=True)
class SLO:
    """Per-request latency objectives (None = unconstrained)."""

    ttft_s: Optional[float] = None
    itl_s: Optional[float] = None

    @classmethod
    def from_scenario(cls, scenario) -> "SLO":
        """Adopt the scenario's registered default thresholds."""
        return cls(ttft_s=scenario.slo_ttft_s, itl_s=scenario.slo_itl_s)

    @classmethod
    def resolve(cls, scenario=None, ttft_ms=None, itl_ms=None) -> "SLO":
        """CLI-flag resolution: explicit ``--slo-*-ms`` values override
        the scenario's registered defaults; 0 (or negative) disables
        that bound entirely."""
        ttft = scenario.slo_ttft_s if scenario is not None else None
        itl = scenario.slo_itl_s if scenario is not None else None
        if ttft_ms is not None:
            ttft = ttft_ms / 1e3 if ttft_ms > 0 else None
        if itl_ms is not None:
            itl = itl_ms / 1e3 if itl_ms > 0 else None
        return cls(ttft_s=ttft, itl_s=itl)

    def verdict(self, b: RequestBreakdown) -> str:
        """``met`` / ``ttft`` / ``itl`` / ``both`` for one request."""
        miss_ttft = self.ttft_s is not None and b.ttft_s > self.ttft_s
        miss_itl = self.itl_s is not None and b.mean_itl_s > self.itl_s
        if miss_ttft and miss_itl:
            return "both"
        if miss_ttft:
            return "ttft"
        if miss_itl:
            return "itl"
        return "met"


def _post_ttft_dominant(b: RequestBreakdown) -> str:
    """Dominant segment of the decode tail (E2E minus the TTFT leg) —
    the blame target for ITL-only violators."""
    post = {s: max(0.0, b.segments.get(s, 0.0) - b.ttft_segments.get(s, 0.0))
            for s in SEGMENTS}
    return max(SEGMENTS, key=lambda s: post.get(s, 0.0))


def slo_report(analysis: CriticalPathAnalysis, slo: SLO) -> dict:
    """Classify every completed request against ``slo``.

    Returns verdict counts, the goodput ratio, and a per-segment blame
    table: TTFT violators blame their dominant TTFT segment, ITL-only
    violators the dominant segment of their decode tail.
    """
    verdicts = {"met": 0, "ttft": 0, "itl": 0, "both": 0}
    blame = {s: 0 for s in SEGMENTS}
    per_request = []
    for b in analysis.breakdowns:
        v = slo.verdict(b)
        verdicts[v] += 1
        if v in ("ttft", "both"):
            blame[b.ttft_dominant] += 1
        elif v == "itl":
            blame[_post_ttft_dominant(b)] += 1
        per_request.append({"rid": b.rid, "verdict": v})
    n = len(analysis.breakdowns)
    return {
        "slo": {"ttft_s": slo.ttft_s, "itl_s": slo.itl_s},
        "n_requests": n,
        "verdicts": verdicts,
        "goodput_ratio": (verdicts["met"] / n if n else 0.0),
        "blame": blame,
        "per_request": per_request,
    }


def record_goodput(registry, report: dict) -> None:
    """Publish the SLO report as first-class registry families, ready
    for the future SLO-aware scheduler to consume live:

      goodput_requests_total{verdict}   completed requests per verdict
      goodput_blame_total{segment}      violators per dominant segment
      goodput_ratio                     fraction of requests meeting SLO
      slo_ttft_seconds / slo_itl_seconds   active thresholds (gauges)
    """
    req = registry.counter(
        "goodput_requests_total",
        help="completed requests by SLO verdict (met/ttft/itl/both)",
        labels=("verdict",))
    for verdict, n in report["verdicts"].items():
        if n:
            req.inc(n, verdict=verdict)
    blame = registry.counter(
        "goodput_blame_total",
        help="SLO violators by dominant critical-path blame segment",
        labels=("segment",))
    for seg, n in report["blame"].items():
        if n:
            blame.inc(n, segment=seg)
    registry.gauge(
        "goodput_ratio",
        help="fraction of completed requests meeting their SLO",
    ).set(report["goodput_ratio"])
    slo = report["slo"]
    if slo.get("ttft_s") is not None:
        registry.gauge("slo_ttft_seconds",
                       help="active TTFT SLO threshold").set(slo["ttft_s"])
    if slo.get("itl_s") is not None:
        registry.gauge("slo_itl_seconds",
                       help="active ITL SLO threshold").set(slo["itl_s"])


def triage(analysis: CriticalPathAnalysis, slo: Optional[SLO] = None,
           tail_q: float = 99.0) -> dict:
    """The ``--trace-out`` report: conservation status, aggregate blame,
    per-request waterfalls, SLO/goodput verdicts, and the TTFT-tail
    blame table."""
    waterfall = []
    for b in analysis.breakdowns:
        waterfall.append({
            "rid": b.rid,
            "replica": b.replica,
            "arrival_s": b.arrival_s,
            "ttft_s": b.ttft_s,
            "mean_itl_s": b.mean_itl_s,
            "e2e_s": b.e2e_s,
            "n_tokens": b.n_tokens,
            "preemptions": b.preemptions,
            "segments": dict(b.segments),
            "ttft_segments": dict(b.ttft_segments),
            "dominant": b.dominant,
            "ttft_dominant": b.ttft_dominant,
            "conservation_error_s": b.conservation_error,
            "conserved": b.conserved,
        })
    out = {
        "n_requests": len(analysis.breakdowns),
        "n_rejected": len(analysis.rejected),
        "conservation": {
            "ok": analysis.conservation_ok,
            "max_error_s": max(
                (b.conservation_error for b in analysis.breakdowns),
                default=0.0),
        },
        "aggregate": analysis.aggregate(),
        "tail": analysis.tail_blame(tail_q),
        "waterfall": waterfall,
    }
    if slo is not None and (slo.ttft_s is not None
                            or slo.itl_s is not None):
        out["slo_report"] = slo_report(analysis, slo)
    return out

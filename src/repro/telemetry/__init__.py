"""Telemetry subsystem: span recording, latency metrics, measured sweeps.

Only the dependency-light pieces (spans, metrics) import eagerly — the
serving engine imports ``repro.telemetry.metrics``, so this package init
must not import the engine back (``characterize`` does).  The heavy
driver is re-exported lazily.
"""
from repro.telemetry.attribution import (  # noqa: F401
    AttributionReport, OperatorRow, OpTag, attribute_events, merge_report,
    parse_operator, segment_ops,
)
from repro.telemetry.critical_path import (  # noqa: F401
    SEGMENTS, SLO, CriticalPathAnalysis, RequestBreakdown, analyze,
    record_goodput, slo_report, triage,
)
from repro.telemetry.metrics import (  # noqa: F401
    LatencySummary, RequestTiming, percentile, percentiles, summarize,
)
from repro.telemetry.registry import (  # noqa: F401
    Counter, Gauge, Histogram, MetricsRegistry, exponential_buckets,
)
from repro.telemetry.spans import Span, SpanRecorder  # noqa: F401
from repro.telemetry.tracing import (  # noqa: F401
    RequestTrace, RequestTracer, TraceEvent,
)

_LAZY = ("CharacterizationResult", "MeasuredPoint", "TPSweepPoint",
         "characterize", "classify_measured_sweep", "memory_pressure_sweep",
         "run_point", "tp_sweep",
         # monitor imports characterize (which imports the engine), so it
         # must stay lazy for the same reason characterize does
         "BoundednessMonitor")


def __getattr__(name):
    if name == "BoundednessMonitor":
        from repro.telemetry.monitor import BoundednessMonitor
        return BoundednessMonitor
    if name in _LAZY:
        from repro.telemetry import characterize as _c
        return getattr(_c, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

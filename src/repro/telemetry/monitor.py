"""Online CPU/GPU-boundedness monitor over the live dispatch stream.

``launch.characterize`` classifies boundedness *offline* by sweeping
batch sizes; serving can't do that — the batch it runs at is whatever
continuous batching produced this step.  The monitor instead buckets
every decode step by its live batch size, keeps a sliding window of
(step time, launch tax) per bucket, and reruns the same inflection rule
(``core.boundedness`` via ``classify_measured_sweep``) over the bucket
means, so the CPU-bound/GPU-bound verdict — and the transition batch —
updates continuously during ``ServeEngine.run()``.

Per-operator TKLQT totals (fed from the attribution layer once per
planned decode call) ride along, so the verdict comes with a ranked
"who is paying the launch tax" answer — the hook the ROADMAP's
SLO-aware router consumes.
"""
from __future__ import annotations

from collections import deque

from repro.core.boundedness import INFLECTION_FACTOR, BoundednessResult
from repro.telemetry.characterize import classify_measured_sweep


class BoundednessMonitor:
    """Sliding-window boundedness estimator keyed by live batch size."""

    def __init__(self, window: int = 64,
                 factor: float = INFLECTION_FACTOR,
                 refresh_stride: int = 16):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if refresh_stride < 1:
            raise ValueError(
                f"refresh_stride must be >= 1, got {refresh_stride}")
        self.window = window
        self.factor = factor
        # bound gauges republish every Nth observation (scrape-time
        # views tolerate a few steps of lag; reclassifying the whole
        # sweep per decode step would eat the <5% telemetry budget) —
        # any result()/verdict()/summary() call republishes immediately
        self.refresh_stride = refresh_stride
        self._pending = 0
        self._buckets: dict = {}          # batch -> deque[(step_s, tax_s)]
        self._op_totals: dict = {}        # operator -> [launches, tklqt_s]
        self._registry = None
        self._g_inflection = None
        self._g_bound = None
        self._g_step = None
        self._c_op_tklqt = None
        self._c_op_launch = None

    # ------------------------------------------------------------ wiring
    def bind_metrics(self, registry) -> None:
        self._registry = registry
        self._g_inflection = registry.gauge(
            "monitor_inflection_batch",
            "live CPU->GPU-bound transition batch (-1 = none observed)")
        self._g_bound = registry.gauge(
            "monitor_gpu_bound",
            "1 = this batch bucket classifies GPU-bound, 0 = CPU-bound",
            labels=("batch",))
        self._g_step = registry.gauge(
            "monitor_window_step_seconds",
            "sliding-window mean decode-step time per batch bucket",
            labels=("batch",))
        self._c_op_tklqt = registry.counter(
            "monitor_operator_tklqt_seconds_total",
            "attributed launch+queue time per model operator",
            labels=("operator",))
        self._c_op_launch = registry.counter(
            "monitor_operator_launches_total",
            "attributed kernel launches per model operator",
            labels=("operator",))

    # ------------------------------------------------------------ feeding
    def observe(self, batch: int, step_s: float, tax_s: float = 0.0) -> None:
        """One decode step at live ``batch`` took ``step_s`` of which
        ``tax_s`` was host-side dispatch."""
        if batch < 1:
            return
        dq = self._buckets.get(batch)
        if dq is None:
            dq = self._buckets[batch] = deque(maxlen=self.window)
        dq.append((step_s, tax_s))
        if self._registry is not None:
            self._pending += 1
            if self._pending >= self.refresh_stride:
                self._refresh_gauges()

    def observe_operators(self, rows, calls: int = 1) -> None:
        """Accumulate per-operator attribution rows (OperatorRow-like:
        .operator/.launches/.tklqt_s) for ``calls`` identical calls."""
        for r in rows:
            acc = self._op_totals.get(r.operator)
            if acc is None:
                acc = self._op_totals[r.operator] = [0.0, 0.0]
            launches = float(r.launches) * calls
            tklqt = r.tklqt_s * calls
            acc[0] += launches
            acc[1] += tklqt
            if self._c_op_tklqt is not None:
                self._c_op_tklqt.inc(tklqt, operator=r.operator)
                self._c_op_launch.inc(launches, operator=r.operator)

    # ------------------------------------------------------------ verdicts
    def result(self) -> BoundednessResult:
        """Classify the current windows with the offline sweep rule."""
        batches = sorted(self._buckets)
        steps, taxes = [], []
        for b in batches:
            dq = self._buckets[b]
            steps.append(sum(s for s, _ in dq) / len(dq))
            taxes.append(sum(t for _, t in dq) / len(dq))
        res = classify_measured_sweep(batches, steps, taxes)
        if self._g_inflection is not None:
            self._publish(res)
        return res

    def verdict(self, batch: int = None) -> str:
        res = self.result()
        if not res.batches:
            return "unknown"
        if batch is None:
            batch = res.batches[-1]
        return res.classify(batch)

    def top_operators(self, k: int = 5) -> list:
        """[(operator, launches, tklqt_s)] ranked by attributed TKLQT."""
        ranked = sorted(self._op_totals.items(), key=lambda kv: -kv[1][1])
        return [(op, v[0], v[1]) for op, v in ranked[:k]]

    def summary(self) -> dict:
        res = self.result()
        return {
            "batches": res.batches,
            "window_mean_step_s": res.tklqt,
            "queue_share": res.queue_share,
            "inflection_batch": res.inflection_batch,
            "classification": {str(b): res.classify(b)
                               for b in res.batches},
            "top_operators": [
                {"operator": op, "launches": launches,
                 "tklqt_us": tklqt * 1e6}
                for op, launches, tklqt in self.top_operators()
            ],
        }

    def clear(self) -> None:
        self._buckets.clear()
        self._op_totals.clear()

    # ------------------------------------------------------------ internals
    def _refresh_gauges(self) -> None:
        self.result()                      # result() publishes when bound

    def _publish(self, res: BoundednessResult) -> None:
        self._pending = 0
        self._g_inflection.set(
            -1 if res.inflection_batch is None else res.inflection_batch)
        for b, t in zip(res.batches, res.tklqt):
            self._g_step.set(t, batch=b)
            self._g_bound.set(
                1.0 if res.classify(b) == "GPU-bound" else 0.0, batch=b)

"""Request-scoped distributed tracing: typed lifecycle events per request.

A ``RequestTracer`` is minted once per serving run and threaded through
every layer a request crosses — router ingress, policy dispatch, engine
admission, (chunked) prefill, batched decode steps, preemption /
offload / restore, completion — each of which stamps a typed
``TraceEvent`` on the virtual clocks the serving tier already keeps
(engine ``now`` / router ``clock``).  The tracer is deliberately dumb:
recording is one small-object append per event, a ``None`` tracer costs
one attribute check at every hook, and nothing is aggregated until
``repro.telemetry.critical_path.analyze`` walks the per-request
timelines.

Events are request-scoped, not step-scoped: a batched decode step that
served four slots appends one event to each of the four request traces
(per-request latency decomposition charges the full step duration to
every participant — each of them was waiting on that step).  The same
tracer instance is shared across all replicas of a fleet, so one trace
follows a request across dispatch, re-queue, and re-dispatch.

Event kinds (``EVENT_KINDS``):

  ingress       request released into the serving tier (t = arrival)
  dispatch      router picked a replica (meta: ``replica``)
  admit         engine bound the request to a slot (meta: ``resume``,
                ``restore_bytes``/``restore_tax_s`` when KV came back
                from the host offload tier)
  prefill       one (chunked) prefill interval (meta: ``tax_s`` measured
                launch tax, ``replay`` for preemption recompute)
  decode        one batched decode/verify interval the request took part
                in (meta: ``tax_s``, ``batch``, ``modeled_tklqt_s``)
  first_token   first emission (TTFT anchor)
  preempt       evicted from its slot (meta: ``mode``,
                ``offload_bytes``/``offload_tax_s`` when KV was staged)
  done          final token emitted (meta: ``n_tokens``)
  reject        admission refused (prompt + budget > max_len)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

EVENT_KINDS = ("ingress", "dispatch", "admit", "prefill", "decode",
               "first_token", "preempt", "done", "reject")

# sort tiebreak for events sharing a timestamp: lifecycle order, so a
# preempt and the re-admit that follows at the same clock value replay
# in the order they actually happened
_KIND_ORDER = {k: i for i, k in enumerate(EVENT_KINDS)}


@dataclass
class TraceEvent:
    """One typed lifecycle event on a request's timeline.

    Point events have ``t1 == t0``; ``prefill``/``decode`` are intervals.
    ``meta`` carries kind-specific payload (see module docstring).
    """

    kind: str
    t0: float
    t1: float
    meta: dict = field(default_factory=dict)

    @property
    def dur(self) -> float:
        """Interval length in seconds (0 for point events)."""
        return self.t1 - self.t0


@dataclass
class RequestTrace:
    """The full event timeline of one request."""

    rid: int
    arrival_s: float
    events: list = field(default_factory=list)

    def first(self, kind: str) -> Optional[TraceEvent]:
        """Earliest event of ``kind`` (None when absent)."""
        best = None
        for ev in self.events:
            if ev.kind == kind and (best is None or ev.t0 < best.t0):
                best = ev
        return best

    def last(self, kind: str) -> Optional[TraceEvent]:
        """Latest event of ``kind`` (None when absent)."""
        best = None
        for ev in self.events:
            if ev.kind == kind and (best is None or ev.t0 >= best.t0):
                best = ev
        return best

    def count(self, kind: str) -> int:
        """Number of events of ``kind``."""
        return sum(1 for ev in self.events if ev.kind == kind)

    def sorted_events(self) -> list:
        """Events in timeline order (kind order breaks timestamp ties)."""
        return sorted(self.events,
                      key=lambda e: (e.t0, _KIND_ORDER.get(e.kind, 99)))


class RequestTracer:
    """Collects ``RequestTrace``s across router, fleet, and engines.

    One instance per serving run; every layer that sees the request
    stamps events through the typed helpers below.  ``ingress`` is
    idempotent (first call wins) so a router-fed replica's ``submit``
    never doubles the mint.  Disabled hooks are a single ``is None``
    check at each call site — the tracer itself is never consulted when
    tracing is off.
    """

    def __init__(self):
        self.traces: dict[int, RequestTrace] = {}

    # ------------------------------------------------------------ mint
    def ingress(self, rid: int, t: float) -> RequestTrace:
        """Mint (or return) the trace for ``rid``; first call wins."""
        tr = self.traces.get(rid)
        if tr is None:
            tr = self.traces[rid] = RequestTrace(rid=rid, arrival_s=t)
            tr.events.append(TraceEvent("ingress", t, t))
        return tr

    def _event(self, rid: int, kind: str, t0: float, t1: float,
               **meta) -> None:
        """Append one event, minting the trace if the layer that should
        have (router/submit) was bypassed (direct ``admit`` calls)."""
        tr = self.traces.get(rid)
        if tr is None:
            tr = self.ingress(rid, t0)
        tr.events.append(TraceEvent(kind, t0, t1, meta))

    # ------------------------------------------------------------ router
    def dispatch(self, rid: int, t: float, *, replica: int) -> None:
        """Router routed ``rid`` to ``replica`` at router clock ``t``."""
        self._event(rid, "dispatch", t, t, replica=replica)

    # ------------------------------------------------------------ engine
    def admit(self, rid: int, t: float, *, resume: bool = False,
              restore_bytes: int = 0, restore_tax_s: float = 0.0) -> None:
        """Engine bound ``rid`` to a slot (``resume`` = re-admission)."""
        self._event(rid, "admit", t, t, resume=resume,
                    restore_bytes=restore_bytes,
                    restore_tax_s=restore_tax_s)

    def reject(self, rid: int, t: float) -> None:
        """Admission refused: prompt + budget exceed the KV region."""
        self._event(rid, "reject", t, t)

    def prefill(self, rid: int, t0: float, t1: float, *,
                tax_s: float = 0.0, replay: bool = False,
                chunk: int = 0) -> None:
        """One (chunked) prefill interval executed for ``rid``."""
        self._event(rid, "prefill", t0, t1, tax_s=tax_s, replay=replay,
                    chunk=chunk)

    def decode(self, rids, t0: float, t1: float, *, tax_s: float = 0.0,
               batch: int = 0, modeled_tklqt_s: float = 0.0) -> None:
        """One batched decode/verify interval; charged to every
        participating request (each was waiting on this very step)."""
        for rid in rids:
            self._event(rid, "decode", t0, t1, tax_s=tax_s, batch=batch,
                        modeled_tklqt_s=modeled_tklqt_s)

    def first_token(self, rid: int, t: float) -> None:
        """First emission for ``rid`` (the TTFT anchor)."""
        self._event(rid, "first_token", t, t)

    def preempt(self, rid: int, t: float, *, mode: str = "recompute",
                offload_bytes: int = 0, offload_tax_s: float = 0.0) -> None:
        """``rid`` evicted from its slot under pool pressure."""
        self._event(rid, "preempt", t, t, mode=mode,
                    offload_bytes=offload_bytes,
                    offload_tax_s=offload_tax_s)

    def done(self, rid: int, t: float, *, n_tokens: int = 0) -> None:
        """``rid`` emitted its final token."""
        self._event(rid, "done", t, t, n_tokens=n_tokens)

    # ------------------------------------------------------------ queries
    def __len__(self) -> int:
        return len(self.traces)

    def completed(self) -> list:
        """Traces that reached ``done``, in rid order."""
        return [tr for _, tr in sorted(self.traces.items())
                if tr.first("done") is not None]

    def clear(self) -> None:
        """Drop every trace (fresh measured run after a warmup)."""
        self.traces.clear()

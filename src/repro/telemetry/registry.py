"""Metrics registry: labeled Counter/Gauge/Histogram + Prometheus export.

The registry is the single live store serving telemetry writes into —
``EngineStats`` scalars delegate here, backends/pools/monitors register
their own families — and reads come out two ways: ``snapshot()`` (a
plain-JSON dict for artifacts and tests) and ``to_prometheus()`` (the
text exposition format, so ``serve --metrics-out metrics.prom`` drops a
scrape-ready file).

Design constraints, in order: recording must be allocation-light (one
dict lookup + float add per observation — it sits on the decode hot
path, gated by the <5% bench budget), label handling must be strict
(every call names the full label set its family declared, so snapshots
never grow surprise series), and histograms use fixed exponential
buckets (latency spans decades; ITL/TTFT/step-time families share the
same default grid so their distributions compare bucket-for-bucket).
"""
from __future__ import annotations

import math
from collections import OrderedDict
from typing import Optional, Sequence


def exponential_buckets(start: float, factor: float,
                        count: int) -> tuple:
    """``count`` bucket upper bounds: start, start*factor, ... (the
    +Inf bucket is implicit in every histogram)."""
    if start <= 0 or factor <= 1 or count < 1:
        raise ValueError(
            f"need start > 0, factor > 1, count >= 1; got "
            f"({start}, {factor}, {count})")
    return tuple(start * factor ** i for i in range(count))


# 1us .. ~67s in doublings: wide enough for per-segment dispatch times at
# the bottom and cold-compile TTFTs at the top
DEFAULT_TIME_BUCKETS = exponential_buckets(1e-6, 2.0, 27)


class _Family:
    """Shared label plumbing for one named metric family."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.label_names = tuple(labels)

    def _key(self, labels: dict) -> tuple:
        """Series key from kwargs; the FULL declared label set is
        required — partial or extra labels are registration bugs."""
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"metric {self.name!r} declared labels "
                f"{self.label_names}, got {tuple(sorted(labels))}")
        return tuple(str(labels[k]) for k in self.label_names)


class Counter(_Family):
    """Monotonic accumulator (counts, bytes, seconds-of-tax)."""

    kind = "counter"

    def __init__(self, name, help="", labels=()):
        super().__init__(name, help, labels)
        self._values: dict = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        """Add ``amount`` (>= 0) to the labeled series."""
        if amount < 0:
            raise ValueError(
                f"counter {self.name!r} cannot decrease (inc {amount})")
        key = self._key(labels)
        self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        """Current accumulated value of the labeled series (0 if unseen)."""
        return self._values.get(self._key(labels), 0.0)

    def series(self) -> dict:
        """All series as {label-value tuple: value}."""
        return dict(self._values)


class Gauge(_Family):
    """Set-to-current-value metric (utilization, verdicts, levels)."""

    kind = "gauge"

    def __init__(self, name, help="", labels=()):
        super().__init__(name, help, labels)
        self._values: dict = {}

    def set(self, value: float, **labels) -> None:
        """Overwrite the labeled series with ``value``."""
        self._values[self._key(labels)] = float(value)

    def add(self, amount: float, **labels) -> None:
        """Shift the labeled series by ``amount`` (either sign)."""
        key = self._key(labels)
        self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        """Current value of the labeled series (0 if never set)."""
        return self._values.get(self._key(labels), 0.0)

    def series(self) -> dict:
        """All series as {label-value tuple: value}."""
        return dict(self._values)


class Histogram(_Family):
    """Fixed-bucket distribution (cumulative counts, Prometheus-style)."""

    kind = "histogram"

    def __init__(self, name, help="", labels=(),
                 buckets: Optional[Sequence[float]] = None):
        super().__init__(name, help, labels)
        bounds = tuple(buckets if buckets is not None
                       else DEFAULT_TIME_BUCKETS)
        if list(bounds) != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError(
                f"histogram {self.name!r} buckets must be strictly "
                f"increasing: {bounds}")
        self.bounds = bounds
        self._counts: dict = {}    # key -> [per-bucket counts] + overflow
        self._sums: dict = {}
        self._totals: dict = {}

    def observe(self, value: float, **labels) -> None:
        """Record one observation into its bucket (linear scan)."""
        key = self._key(labels)
        counts = self._counts.get(key)
        if counts is None:
            counts = self._counts[key] = [0] * (len(self.bounds) + 1)
            self._sums[key] = 0.0
            self._totals[key] = 0
        # linear scan is fine: bucket lists are ~27 long and most
        # observations land in the first few buckets (µs-scale times)
        for i, b in enumerate(self.bounds):
            if value <= b:
                counts[i] += 1
                break
        else:
            counts[-1] += 1
        self._sums[key] += value
        self._totals[key] += 1

    def count(self, **labels) -> int:
        """Total observations recorded for the labeled series."""
        return self._totals.get(self._key(labels), 0)

    def sum(self, **labels) -> float:
        """Sum of all observed values for the labeled series."""
        return self._sums.get(self._key(labels), 0.0)

    def quantile(self, q: float, **labels) -> float:
        """Bucket-resolution quantile estimate (upper bound of the
        bucket holding the q-th observation; math.inf when it landed
        in the overflow bucket, 0.0 when empty)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        key = self._key(labels)
        total = self._totals.get(key, 0)
        if not total:
            return 0.0
        rank = q * total
        seen = 0
        for i, c in enumerate(self._counts[key]):
            seen += c
            if seen >= rank and c:
                return (self.bounds[i] if i < len(self.bounds)
                        else math.inf)
        return math.inf

    def merge_series(self, count: int, sum: float, buckets,
                     **labels) -> None:
        """Fold an already-bucketed series (another registry's snapshot
        of a same-bounds family) into the labeled series — the fleet
        aggregation path, where re-observing raw values is impossible."""
        if len(buckets) != len(self.bounds) + 1:
            raise ValueError(
                f"histogram {self.name!r} has {len(self.bounds) + 1} "
                f"buckets (incl. overflow), got {len(buckets)}")
        key = self._key(labels)
        counts = self._counts.get(key)
        if counts is None:
            counts = self._counts[key] = [0] * (len(self.bounds) + 1)
            self._sums[key] = 0.0
            self._totals[key] = 0
        for i, c in enumerate(buckets):
            counts[i] += c
        self._sums[key] += sum
        self._totals[key] += count

    def series(self) -> dict:
        """All series as {key: {count, sum, buckets}}."""
        out = {}
        for key, counts in self._counts.items():
            out[key] = {
                "count": self._totals[key],
                "sum": self._sums[key],
                "buckets": list(counts),
            }
        return out


class MetricsRegistry:
    """Ordered name -> family store with get-or-create accessors."""

    def __init__(self):
        self._families: OrderedDict = OrderedDict()

    def _get_or_create(self, cls, name, help, labels, **kw):
        """Return the named family, creating it on first registration;
        re-registering under a different kind is a TypeError."""
        fam = self._families.get(name)
        if fam is not None:
            if not isinstance(fam, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{fam.kind}, requested {cls.kind}")
            return fam
        fam = cls(name, help=help, labels=labels, **kw)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        """Get-or-create a Counter family."""
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        """Get-or-create a Gauge family."""
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        """Get-or-create a Histogram family (default time buckets)."""
        return self._get_or_create(Histogram, name, help, labels,
                                   buckets=buckets)

    def get(self, name: str):
        """The named family, or None."""
        return self._families.get(name)

    def names(self) -> list:
        """Family names in registration order."""
        return list(self._families)

    # ------------------------------------------------------------ export
    def snapshot(self) -> dict:
        """Plain-JSON view: family -> {type, help, labels, series}."""
        out = {}
        for name, fam in self._families.items():
            series = []
            for key, val in fam.series().items():
                series.append({
                    "labels": dict(zip(fam.label_names, key)),
                    "value": val,
                })
            out[name] = {
                "type": fam.kind,
                "help": fam.help,
                "label_names": list(fam.label_names),
                "series": series,
            }
            if fam.kind == "histogram":
                out[name]["buckets"] = list(fam.bounds)
        return out

    def to_prometheus(self) -> str:
        """Text exposition format (counters get a _total suffix only if
        the family name already carries one — names here are explicit)."""
        lines = []
        for name, fam in self._families.items():
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            if fam.kind == "histogram":
                for key, s in fam.series().items():
                    base = _label_str(fam.label_names, key)
                    cum = 0
                    for b, c in zip(fam.bounds, s["buckets"]):
                        cum += c
                        lines.append(
                            f"{name}_bucket{_merge(base, _fmt(b))} "
                            f"{cum}")
                    cum += s["buckets"][-1]
                    lines.append(
                        f"{name}_bucket{_merge(base, '+Inf')}"
                        f" {cum}")
                    lines.append(f"{name}_sum{_wrap(base)} {_fmt(s['sum'])}")
                    lines.append(f"{name}_count{_wrap(base)} {s['count']}")
            else:
                for key, val in fam.series().items():
                    base = _label_str(fam.label_names, key)
                    lines.append(f"{name}{_wrap(base)} {_fmt(val)}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    """Prometheus-safe number formatting (ints bare, +/-Inf named)."""
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    f = float(v)
    return repr(int(f)) if f.is_integer() else repr(f)


def _escape_label_value(v: str) -> str:
    """Escape a label value per the Prometheus text-exposition spec:
    backslash, double-quote, and newline must be backslash-escaped
    (order matters — backslash first, or the others double-escape)."""
    return (v.replace("\\", "\\\\")
             .replace('"', '\\"')
             .replace("\n", "\\n"))


def _label_str(names, key) -> str:
    """Render a label set as name="value" pairs (values escaped)."""
    return ",".join(f'{n}="{_escape_label_value(v)}"'
                    for n, v in zip(names, key))


def _wrap(base: str) -> str:
    """Brace a label string, or nothing when unlabeled."""
    return f"{{{base}}}" if base else ""


def _merge(base: str, le_value: str) -> str:
    """Brace a label string with the histogram ``le=`` pair appended."""
    extra = f'le="{_escape_label_value(le_value)}"'
    return f"{{{base},{extra}}}" if base else f"{{{extra}}}"

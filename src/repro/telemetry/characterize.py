"""Measured SKIP characterization of the serving engine under a scenario.

This is the repo's counterpart of the paper's real-trace side: instead of
simulating a kernel stream against ``core.device_model``, it drives the
live ``ServeEngine`` with a named traffic scenario, records host-side
telemetry (per-step dispatch spans, per-request TTFT/ITL/E2E), sweeps the
slot-pool size, and classifies the CPU/GPU-bound inflection from the
MEASURED per-step latency curve via ``core.boundedness`` — flat step time
in batch = dispatch-bound (more slots are free), growing step time =
compute-bound (the paper's transition, observed rather than modeled).

Each run per batch point is warmup-then-measure: the warmup pass pays
tracing/planning/jit once so measured timings are steady-state serving.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.core.boundedness import BoundednessResult, classify_sweep
from repro.inference.engine import Request, ServeEngine
from repro.kvcache.paged import PagedKVCache
from repro.telemetry.metrics import LatencySummary, summarize
from repro.telemetry.spans import SpanRecorder
from repro.workload.generator import Workload, sample_requests

MAX_DEVICE_ANCHORS = 64     # cap modeled-lane replication in exported traces


@dataclass
class _MeasuredReport:
    """Measured stand-in for SkipReport in classify_sweep: tklqt is the
    measured mean decode-step latency, queue_share the non-dispatch part."""
    tklqt: float
    queue_share: float


def classify_measured_sweep(batches: Sequence[int],
                            step_times_s: Sequence[float],
                            launch_tax_s: Optional[Sequence[float]] = None
                            ) -> BoundednessResult:
    """Boundedness from a measured batch sweep, via classify_sweep."""
    if launch_tax_s is None:
        launch_tax_s = [0.0] * len(step_times_s)
    reports = [
        _MeasuredReport(t, max(0.0, 1.0 - (tax / t)) if t > 0 else 0.0)
        for t, tax in zip(step_times_s, launch_tax_s)
    ]
    return classify_sweep(batches, reports)


@dataclass
class MeasuredPoint:
    """One batch point of a measured serving sweep."""
    batch: int
    latency: LatencySummary
    mean_decode_step_s: float
    launch_tax_per_step_s: float          # prefill+decode, per engine step
    decode_launch_tax_s: float            # decode only, per decode step
    dispatches_per_decode_step: float
    modeled_tklqt_s: float
    tokens_per_s: float
    mean_occupancy: float
    tokens_out: int
    decode_steps: int
    fused_dispatches_per_decode_step: float = 0.0  # rule-backed fused kernels
    rule_hits: dict = field(default_factory=dict)  # fusion-rule launch counts
    # paged KV cache counters (zero under cache="contiguous")
    preemptions: int = 0
    offload_bytes: int = 0
    restore_bytes: int = 0
    modeled_offload_tax_s: float = 0.0
    mean_pool_utilization: float = 0.0
    peak_pool_utilization: float = 0.0
    spans: list = field(default_factory=list)           # telemetry Spans
    modeled_events: list = field(default_factory=list)  # one decode step
    decode_anchors: list = field(default_factory=list)  # decode span starts
    attribution: object = None     # AttributionReport of one decode step

    def row(self) -> dict:
        out = {
            "batch": self.batch,
            "mean_decode_step_us": round(self.mean_decode_step_s * 1e6, 1),
            "launch_tax_per_step_us":
                round(self.launch_tax_per_step_s * 1e6, 1),
            "decode_launch_tax_us": round(self.decode_launch_tax_s * 1e6, 1),
            "dispatches_per_decode_step":
                round(self.dispatches_per_decode_step, 2),
            "fused_dispatches_per_decode_step":
                round(self.fused_dispatches_per_decode_step, 2),
            "rule_hits": dict(self.rule_hits),
            "modeled_tklqt_us": round(self.modeled_tklqt_s * 1e6, 1),
            "tokens_per_s": round(self.tokens_per_s, 1),
            "mean_occupancy": round(self.mean_occupancy, 2),
            "tokens_out": self.tokens_out,
            "decode_steps": self.decode_steps,
            "preemptions": self.preemptions,
            "offload_bytes": self.offload_bytes,
            "restore_bytes": self.restore_bytes,
            "modeled_offload_tax_us":
                round(self.modeled_offload_tax_s * 1e6, 1),
            "mean_pool_utilization": round(self.mean_pool_utilization, 3),
            "peak_pool_utilization": round(self.peak_pool_utilization, 3),
        }
        if self.attribution is not None:
            out["attribution"] = self.attribution.as_dicts()
        out.update(self.latency.row())
        return out


@dataclass
class CharacterizationResult:
    arch: str
    scenario: str
    plan: str
    platform: str
    workload: Workload
    points: list                     # list[MeasuredPoint], one per batch
    boundedness: BoundednessResult

    def summary(self) -> dict:
        return {
            "arch": self.arch, "scenario": self.scenario,
            "plan": self.plan, "platform": self.platform,
            "seed": self.workload.seed,
            "n_requests": self.workload.n,
            "batches": [p.batch for p in self.points],
            "inflection_batch": self.boundedness.inflection_batch,
            "classification": {
                str(p.batch): self.boundedness.classify(p.batch)
                for p in self.points
            },
            "points": [p.row() for p in self.points],
        }


def _requests(workload: Workload) -> list:
    # engine Requests are mutable run state; mint fresh ones per run
    return [Request(r.rid, prompt=list(r.prompt),
                    max_new_tokens=r.max_new_tokens,
                    arrival_s=r.arrival_s)
            for r in workload.requests]


def run_point(cfg, params, workload: Workload, *, batch: int,
              plan: str = "auto", platform: str = "TPU-v5e",
              max_len: int = 256, warmup: bool = True,
              cache: str = "contiguous", block_size: int = 16,
              num_blocks=None, offload: str = "none",
              prefill_chunk=None) -> MeasuredPoint:
    """Serve the workload with ``batch`` slots and measure one sweep point."""
    rec = SpanRecorder()
    eng = ServeEngine(cfg, params, max_batch=batch, max_len=max_len,
                      plan=plan, platform=platform, telemetry=rec,
                      cache=cache, block_size=block_size,
                      num_blocks=num_blocks, offload=offload,
                      prefill_chunk=prefill_chunk)
    if warmup:
        eng.run(_requests(workload))
        eng.reset()
    eng.run(_requests(workload))
    st = eng.stats
    lat = summarize(list(eng.timings.values()))
    steps = st.step_times_s
    mean_step = sum(steps) / len(steps) if steps else 0.0
    planned = eng._planned_decode
    decode_spans = [s for s in rec.spans if s.cat == "decode"]
    return MeasuredPoint(
        batch=batch,
        latency=lat,
        mean_decode_step_s=mean_step,
        launch_tax_per_step_s=st.launch_tax_per_step_s,
        decode_launch_tax_s=st.launch_tax_per_decode_step_s,
        dispatches_per_decode_step=st.dispatches_per_decode_step,
        fused_dispatches_per_decode_step=st.fused_dispatches_per_decode_step,
        rule_hits=dict(st.rule_hits),
        preemptions=st.preemptions,
        offload_bytes=st.offload_bytes,
        restore_bytes=st.restore_bytes,
        modeled_offload_tax_s=st.modeled_offload_tax_s,
        mean_pool_utilization=st.mean_block_pool_utilization,
        peak_pool_utilization=st.peak_block_pool_utilization,
        modeled_tklqt_s=st.modeled_tklqt_s,
        tokens_per_s=st.tokens_out / eng.now if eng.now else 0.0,
        mean_occupancy=(sum(st.slot_occupancy) / len(st.slot_occupancy)
                        if st.slot_occupancy else 0.0),
        tokens_out=st.tokens_out,
        decode_steps=st.decode_steps,
        spans=list(rec.spans),
        modeled_events=(list(planned.modeled_events) if planned else []),
        decode_anchors=[s.t0 for s in decode_spans[:MAX_DEVICE_ANCHORS]],
        attribution=(planned.attribution if planned else None),
    )


def characterize(cfg, params, *, scenario: str = "chatbot",
                 batches: Sequence[int] = (1, 2, 4), plan: str = "auto",
                 platform: str = "TPU-v5e", n_requests: int = 6,
                 seed: int = 0, prompt_cap: Optional[int] = 24,
                 output_cap: Optional[int] = 8, time_scale: float = 1.0,
                 max_len: int = 256, warmup: bool = True,
                 workload: Optional[Workload] = None,
                 cache: str = "contiguous", block_size: int = 16,
                 num_blocks=None, offload: str = "none",
                 prefill_chunk=None) -> CharacterizationResult:
    """Scenario x batch sweep over the live engine -> measured boundedness.

    Pass ``workload`` (e.g. loaded from a recorded JSONL trace) to replay
    exact traffic instead of generating it from the scenario registry.
    """
    if workload is None:
        workload = sample_requests(scenario, n_requests, seed=seed,
                                   vocab_size=cfg.vocab_size,
                                   prompt_cap=prompt_cap,
                                   output_cap=output_cap,
                                   time_scale=time_scale)
    elif workload.vocab_size > cfg.vocab_size:
        # JAX clamps out-of-range gather indices silently — a replayed
        # trace from a bigger-vocab model would "run" but measure garbage
        raise ValueError(
            f"workload was recorded for vocab_size={workload.vocab_size} "
            f"but model {cfg.name} has vocab_size={cfg.vocab_size}; "
            "re-record the trace against this config")
    points = [run_point(cfg, params, workload, batch=b, plan=plan,
                        platform=platform, max_len=max_len, warmup=warmup,
                        cache=cache, block_size=block_size,
                        num_blocks=num_blocks, offload=offload,
                        prefill_chunk=prefill_chunk)
              for b in batches]
    bound = classify_measured_sweep(
        [p.batch for p in points],
        [p.mean_decode_step_s for p in points],
        [p.decode_launch_tax_s for p in points])
    return CharacterizationResult(
        arch=cfg.name, scenario=workload.scenario, plan=plan,
        platform=platform, workload=workload, points=points,
        boundedness=bound)


# ------------------------------------------------------------ memory pressure
@dataclass
class MemoryPressurePoint:
    """One (platform, kv dtype, pool size) cell of the pressure sweep."""
    platform: str
    coupling: str                  # LC (PCIe) | CC (C2C)
    link_gbps: float
    kv_dtype: str                  # bf16 | int8 page payloads
    block_bytes: int               # device bytes of ONE pool block
    pool_frac: float               # fraction of the no-pressure pool size
    num_blocks: int
    preemptions: int
    offload_bytes: int
    restore_bytes: int
    modeled_offload_tax_s: float
    peak_pool_utilization: float
    tokens_out: int
    decode_steps: int

    def row(self) -> dict:
        tax_us = self.modeled_offload_tax_s * 1e6
        return {
            "platform": self.platform, "coupling": self.coupling,
            "link_gbps": round(self.link_gbps, 1),
            "kv_dtype": self.kv_dtype,
            "block_bytes": self.block_bytes,
            "pool_frac": self.pool_frac, "num_blocks": self.num_blocks,
            "preemptions": self.preemptions,
            "offload_bytes": self.offload_bytes,
            "restore_bytes": self.restore_bytes,
            "modeled_offload_tax_us": round(tax_us, 1),
            "offload_tax_per_token_us":
                round(tax_us / self.tokens_out, 2) if self.tokens_out
                else 0.0,
            "peak_pool_utilization": round(self.peak_pool_utilization, 3),
            "tokens_out": self.tokens_out,
            "decode_steps": self.decode_steps,
        }


def memory_pressure_sweep(cfg, params, *, scenario: str = "chatbot",
                          platforms: Sequence[str] = ("Intel+H100", "GH200"),
                          pool_fracs: Sequence[float] = (1.0, 0.5, 0.33),
                          kv_dtypes: Sequence[str] = ("bf16",),
                          max_batch: int = 4, max_len: int = 64,
                          block_size: int = 4, prefill_chunk: Optional[int] = None,
                          n_requests: int = 8, seed: int = 0,
                          prompt_cap: Optional[int] = 16,
                          output_cap: Optional[int] = 8) -> dict:
    """Drive the paged engine's block pool past capacity on LC vs CC
    device models (the paper's coupling axis applied to KV offload).

    The eviction traffic is MEASURED — the same seeded workload drives
    near-identical preemptions and offload bytes on every platform
    (exactly identical for closed-loop scenarios; open-loop arrivals
    interact with measured step durations) — while the transfer time
    those bytes cost is MODELED through each platform's coupling link
    (``core.device_model.offload_cost_s``), so the sweep isolates how
    PCIe (LC) vs NVLink-C2C (CC) bandwidth changes the offload tax of
    serving under memory pressure.

    ``kv_dtypes`` adds the quantization axis: every (platform, frac)
    cell is re-served per dtype with the pool held at the SAME device
    BYTE budget — an int8 pool fits ``block_bytes(bf16)/block_bytes
    (int8)`` more blocks (~3.2x for an f32-payload CPU cache at hd=16),
    so the sweep measures how quantization converts a fixed byte budget
    into fewer preemptions and less offload traffic.
    """
    from repro.core.device_model import PLATFORMS
    workload = sample_requests(scenario, n_requests, seed=seed,
                               vocab_size=cfg.vocab_size,
                               prompt_cap=prompt_cap, output_cap=output_cap)
    # pool sized against the workload's own peak demand (longest possible
    # sequence on every slot at once) so pool_frac < 1 actually presses
    longest = max(len(r.prompt) + r.max_new_tokens
                  for r in workload.requests)
    per_seq = -(-longest // block_size)
    full_blocks = max_batch * per_seq
    min_blocks = per_seq + 1                     # one full request + growth
    # per-dtype bytes of one pool block, measured off a 1-block probe —
    # byte-budget equivalence below uses REAL leaf sizes, not entry math
    bb = {}
    for dt in kv_dtypes:
        probe = PagedKVCache(cfg, num_blocks=1, block_size=block_size,
                             max_len=block_size, kv_dtype=dt)
        probe.make_pages()
        bb[dt] = probe.pool.block_bytes
    points = []
    for plat in platforms:
        spec = PLATFORMS[plat]
        for frac in pool_fracs:
            nb_native = max(min_blocks, int(full_blocks * frac))
            byte_budget = nb_native * bb[kv_dtypes[0]]
            for dt in kv_dtypes:
                nb = max(min_blocks, byte_budget // bb[dt])
                eng = ServeEngine(cfg, params, max_batch=max_batch,
                                  max_len=max_len, platform=plat,
                                  cache="paged", block_size=block_size,
                                  num_blocks=nb, offload="host",
                                  prefill_chunk=prefill_chunk,
                                  kv_dtype=dt)
                eng.run(_requests(workload))
                st = eng.stats
                points.append(MemoryPressurePoint(
                    platform=plat, coupling=spec.coupling,
                    link_gbps=spec.link_bw / 1e9, kv_dtype=dt,
                    block_bytes=bb[dt], pool_frac=frac,
                    num_blocks=nb, preemptions=st.preemptions,
                    offload_bytes=st.offload_bytes,
                    restore_bytes=st.restore_bytes,
                    modeled_offload_tax_s=st.modeled_offload_tax_s,
                    peak_pool_utilization=st.peak_block_pool_utilization,
                    tokens_out=st.tokens_out,
                    decode_steps=st.decode_steps))
    return {
        "arch": cfg.name, "scenario": workload.scenario,
        "seed": workload.seed, "n_requests": workload.n,
        "max_batch": max_batch, "max_len": max_len,
        "block_size": block_size, "full_pool_blocks": full_blocks,
        "platforms": list(platforms), "pool_fracs": list(pool_fracs),
        "kv_dtypes": list(kv_dtypes),
        "block_bytes": dict(bb),
        "points": [p.row() for p in points],
        "kv_dtype_deltas": _kv_dtype_deltas(points, kv_dtypes),
    }


def _kv_dtype_deltas(points, kv_dtypes) -> list:
    """Matched (platform, pool_frac) comparisons of each quantized dtype
    against the native baseline at the same device byte budget: pool
    capacity in blocks, preemption count, and offload-tax deltas."""
    if len(kv_dtypes) < 2:
        return []
    base_dt = kv_dtypes[0]
    base = {(p.platform, p.pool_frac): p for p in points
            if p.kv_dtype == base_dt}
    rows = []
    for p in points:
        if p.kv_dtype == base_dt:
            continue
        b = base[(p.platform, p.pool_frac)]
        rows.append({
            "platform": p.platform, "pool_frac": p.pool_frac,
            "kv_dtype": p.kv_dtype, "baseline": base_dt,
            "capacity_ratio": round(p.num_blocks / b.num_blocks, 2),
            "preemptions": {base_dt: b.preemptions,
                            p.kv_dtype: p.preemptions},
            "offload_bytes": {base_dt: b.offload_bytes,
                              p.kv_dtype: p.offload_bytes},
            "offload_tax_delta_us": round(
                (p.modeled_offload_tax_s - b.modeled_offload_tax_s) * 1e6,
                1),
            "peak_pool_utilization": {
                base_dt: round(b.peak_pool_utilization, 3),
                p.kv_dtype: round(p.peak_pool_utilization, 3)},
        })
    return rows


# ------------------------------------------------------------ tp sweep
@dataclass
class TPSweepPoint:
    """One (platform, tp, batch) cell of the tensor-parallel sweep."""
    platform: str
    coupling: str                  # LC (PCIe) | CC (C2C)
    tp: int
    batch: int
    n_kernels: int                 # eager stream length (one decode step)
    per_device_dispatches: int     # launches issued per device stream
    modeled_tklqt_s: float
    modeled_step_s: float          # end of the simulated device timeline
    launch_tax_s: float            # host-side launch time of the step
    collective_bytes: int          # psum payload per step (all layers)
    modeled_collective_tax_s: float

    def row(self) -> dict:
        return {
            "platform": self.platform, "coupling": self.coupling,
            "tp": self.tp, "batch": self.batch,
            "n_kernels": self.n_kernels,
            "per_device_dispatches": self.per_device_dispatches,
            "modeled_tklqt_us": round(self.modeled_tklqt_s * 1e6, 1),
            "modeled_step_us": round(self.modeled_step_s * 1e6, 1),
            "launch_tax_us": round(self.launch_tax_s * 1e6, 1),
            "collective_bytes": self.collective_bytes,
            "modeled_collective_tax_us":
                round(self.modeled_collective_tax_s * 1e6, 1),
        }


def decode_collective_sites(cfg, batch: int, n_segments: int) -> list:
    """Per-segment psum payloads of ONE tensor-parallel decode step.

    Every layer reduces its attention output and its MLP output — two
    (B, 1, d_model) activations, the collectives the sharded backend
    captures at trace time.  The ``2 * n_layers`` sites are spread
    uniformly across the segment stream (the layer structure is
    periodic), so each psum pays its own ring-latency floor in the queue
    model instead of one smeared aggregate."""
    n_sites = 2 * cfg.n_layers
    per_site = batch * cfg.d_model * cfg.cdtype.itemsize
    coll = [0.0] * n_segments
    if not n_segments:
        return coll
    for s in range(n_sites):
        # last segment of each uniform span: the reduce closes a layer half
        idx = min(((s + 1) * n_segments) // n_sites, n_segments) - 1
        coll[max(idx, 0)] += per_site
    return coll


def tp_sweep(cfg, params, *, batches: Sequence[int] = (1, 2, 4, 8),
             tps: Sequence[int] = (1, 2, 4, 8),
             platforms: Sequence[str] = ("Intel+H100", "GH200"),
             max_len: int = 64) -> dict:
    """Model how tensor parallelism shifts the CPU->GPU-bound transition.

    The decode kernel stream is traced ONCE per batch (the real eager
    stream of this model's decode step), then priced per (platform, tp)
    through the extended queue model: the host issues every launch once
    per device stream (launch tax x tp — the multi-GPU widening of
    Chung et al.), each device runs 1/tp of the flops/bytes, and the
    per-layer psum payloads ride the platform's coupling link
    (``allreduce_cost_s``).  The per-(platform, tp) TKLQT-vs-batch curve
    is classified with the same inflection rule as the measured sweep, so
    the output shows the inflection batch MOVING RIGHT with tp: more
    devices widen the CPU-bound region — the paper's coupling story at
    multi-GPU scale.

    Nothing executes — tracing only — so ``params`` may be abstract
    (``launch.steps.params_sds(cfg)``): full-size models sweep without
    materializing weights.  On full smollm-360m this moves the LC
    (Intel+H100) inflection 16 -> 64 -> 256 -> beyond-range as tp goes
    1 -> 2 -> 4 -> 8.
    """
    import jax.numpy as jnp

    from repro.core.device_model import PLATFORMS, allreduce_cost_s
    from repro.core.metrics import report
    from repro.core.tracing import trace_fn
    from repro.models import forward, make_cache
    from repro.runtime.plan import LaunchPlan
    from repro.runtime.planner import simulate_plan

    traces = {}
    for b in batches:
        cache = make_cache(cfg, b, max_len, src_len=1, dtype=cfg.cdtype)
        toks = jnp.zeros((b, 1), jnp.int32)
        lengths = jnp.zeros((b,), jnp.int32)

        def decode_body(params, cache, tokens, lengths):
            logits, _, cache2 = forward(params, tokens, cfg, cache=cache,
                                        lengths=lengths, unroll=True)
            return logits[:, 0], cache2

        traces[b] = trace_fn(decode_body, params, cache, toks, lengths)

    points: list[TPSweepPoint] = []
    inflection: dict = {}
    for plat in platforms:
        spec = PLATFORMS[plat]
        inflection[plat] = {}
        for tp in tps:
            reports = []
            for b in batches:
                tr = traces[b]
                n = len(tr.kernels)
                plan = LaunchPlan.eager(n)
                coll = (decode_collective_sites(cfg, b, n)
                        if tp > 1 else None)
                # one queue-model walk per cell: the SkipReport is
                # derived from the same event list the point exposes
                ev = simulate_plan(tr.kernels, plan, spec, tp=tp,
                                   collective_bytes=coll)
                rep = report(ev, spec.name,
                             spec.launch_overhead_ns * 1e-9)
                reports.append(rep)
                coll_b = int(sum(coll)) if coll else 0
                points.append(TPSweepPoint(
                    platform=plat, coupling=spec.coupling, tp=tp, batch=b,
                    n_kernels=n,
                    per_device_dispatches=n,
                    modeled_tklqt_s=rep.tklqt,
                    modeled_step_s=ev[-1].kernel_end if ev else 0.0,
                    launch_tax_s=sum(e.t_launch for e in ev),
                    collective_bytes=coll_b,
                    modeled_collective_tax_s=sum(
                        allreduce_cost_s(spec, c, tp)
                        for c in (coll or []) if c)))
            bound = classify_sweep(batches, reports)
            inflection[plat][str(tp)] = bound.inflection_batch
    return {
        "arch": cfg.name, "max_len": max_len,
        "batches": list(batches), "tps": list(tps),
        "platforms": list(platforms),
        "inflection_batch": inflection,
        "points": [p.row() for p in points],
    }


# ------------------------------------------------------------ spec sweep
@dataclass
class SpecSweepPoint:
    """One (k, batch) cell of the speculative-decoding sweep (measured)."""
    k: int
    batch: int
    accept_rate: float
    steps_per_emitted_token: float
    spec_rounds: int
    proposed: int
    accepted: int
    corrections: int
    draft_dispatches: int
    tokens_out: int
    decode_steps: int

    def row(self) -> dict:
        return {
            "k": self.k, "batch": self.batch,
            "accept_rate": round(self.accept_rate, 3),
            "steps_per_emitted_token":
                round(self.steps_per_emitted_token, 3),
            "spec_rounds": self.spec_rounds,
            "proposed": self.proposed, "accepted": self.accepted,
            "corrections": self.corrections,
            "draft_dispatches": self.draft_dispatches,
            "tokens_out": self.tokens_out,
            "decode_steps": self.decode_steps,
        }


def spec_sweep(cfg, params, *, draft_cfg=None, draft_params=None,
               ks: Sequence[int] = (2, 4, 8),
               batches: Sequence[int] = (1, 2, 4),
               platforms: Sequence[str] = ("Intel+H100", "GH200"),
               scenario: str = "chatbot", n_requests: int = 6,
               seed: int = 0, prompt_cap: Optional[int] = 16,
               output_cap: Optional[int] = 12, max_len: int = 128,
               cache: str = "contiguous", block_size: int = 16,
               num_blocks=None, warmup: bool = False,
               model_batches: Optional[Sequence[int]] = None) -> dict:
    """Sweep speculation depth x batch: measured acceptance, modeled tax.

    The trade speculation makes is the paper's launch-tax axis run in
    reverse: the draft ADDS k small dispatches per round (pure host-side
    serialization — its kernels are tiny) to REMOVE sequential target
    steps (the batched verify scores k+1 positions per launch stream).
    So it pays off exactly where decode is CPU/dispatch-bound — low
    batch — and keeps paying on coupled (CC) parts out to larger batches
    because their higher per-launch host cost makes each SAVED launch
    worth more while their inflection sits further right.

    Measured side: the live engine serves the same seeded workload at
    every (k, batch) with a fixed depth (``spec_inflection=None`` pins
    ``pick_spec_k`` at k); acceptance and steps-per-emitted-token are
    real properties of the draft/target pair, independent of platform.
    Modeled side: the target's decode kernel stream is traced per batch
    (``model_batches`` extends past the measured range so the sweep
    reaches the compute-bound flip) and priced per platform through
    ``simulate_plan``: the baseline is one decode step per emitted token
    per sequence; the speculative round scales the stream by
    ``batch_scale=k+1`` (verify work) and prepends ``k x
    n_draft_kernels`` serialized draft dispatches, then divides by the
    MEASURED emitted-tokens-per-sequence-per-round (a per-sequence
    property, carried to the extended batches).  A cell "wins" when
    modeled spec time per token beats the baseline — in the CPU-bound
    region the (k+1)x verify work is free (kernels stay under the launch
    cost) so amortizing launches wins; past the inflection the verify
    pays full compute and speculation loses.  CC parts, with their
    costlier per-launch host path and further-right inflection, keep a
    WIDER winning batch range than LC — the opposite-region check."""
    import jax.numpy as jnp

    from repro.core.device_model import PLATFORMS, dispatch_fanout_s
    from repro.core.tracing import trace_fn
    from repro.inference.speculative import (default_draft_config,
                                             draft_params_from_target)
    from repro.models import forward, make_cache
    from repro.runtime.plan import LaunchPlan
    from repro.runtime.planner import simulate_plan

    if draft_cfg is None:
        draft_cfg = default_draft_config(cfg)
    if draft_params is None:
        draft_params = draft_params_from_target(params, draft_cfg)
    workload = sample_requests(scenario, n_requests, seed=seed,
                               vocab_size=cfg.vocab_size,
                               prompt_cap=prompt_cap, output_cap=output_cap)

    # ---- measured: acceptance + steps/token per (k, batch)
    points: list[SpecSweepPoint] = []
    for b in batches:
        for k in ks:
            eng = ServeEngine(cfg, params, max_batch=b, max_len=max_len,
                              cache=cache, block_size=block_size,
                              num_blocks=num_blocks,
                              speculative=k > 0, spec_k=max(k, 1),
                              draft_config=draft_cfg if k > 0 else None,
                              draft_params=draft_params if k > 0 else None)
            if warmup:
                eng.run(_requests(workload))
                eng.reset()
            eng.run(_requests(workload))
            st = eng.stats
            points.append(SpecSweepPoint(
                k=k, batch=b, accept_rate=st.accept_rate,
                steps_per_emitted_token=st.steps_per_emitted_token,
                spec_rounds=st.spec_rounds, proposed=st.proposed,
                accepted=st.accepted, corrections=st.corrections,
                draft_dispatches=st.draft_dispatches,
                tokens_out=st.tokens_out, decode_steps=st.decode_steps))
    measured = {(p.k, p.batch): p for p in points}

    # emitted tokens per sequence per round is a per-sequence property of
    # the draft/target pair (bounded by accept rate), so the value from
    # the largest measured batch carries to the extended model batches
    emit_per_seq: dict = {}
    for k in ks:
        if k == 0:
            continue
        bmax = max(batches)
        p = measured[(k, bmax)]
        emitted = p.accepted + p.corrections
        emit_per_seq[k] = (emitted / (p.spec_rounds * bmax)
                          if p.spec_rounds else 1.0)

    if model_batches is None:
        model_batches = sorted(set(batches) | {16, 64, 256})

    # ---- modeled: price the launch trade per platform over the traced
    # target/draft decode streams
    def decode_body_for(body_cfg):
        def decode_body(params_, cache, tokens, lengths):
            logits, _, cache2 = forward(params_, tokens, body_cfg,
                                        cache=cache, lengths=lengths,
                                        unroll=True)
            return logits[:, 0], cache2
        return decode_body

    traces = {}
    for b in model_batches:
        tcache = make_cache(cfg, b, max_len, src_len=1, dtype=cfg.cdtype)
        traces[b] = trace_fn(decode_body_for(cfg), params, tcache,
                             jnp.zeros((b, 1), jnp.int32),
                             jnp.zeros((b,), jnp.int32))
    dcache = make_cache(draft_cfg, 1, max_len, src_len=1,
                        dtype=draft_cfg.cdtype)
    n_draft_kernels = len(trace_fn(
        decode_body_for(draft_cfg), draft_params, dcache,
        jnp.zeros((1, 1), jnp.int32),
        jnp.zeros((1,), jnp.int32)).kernels)

    modeled = []
    win_region: dict = {}
    for plat in platforms:
        spec = PLATFORMS[plat]
        win_region[plat] = {}
        for b in model_batches:
            tr = traces[b]
            plan = LaunchPlan.eager(len(tr.kernels))
            # one decode step emits one token per sequence
            base_ev = simulate_plan(tr.kernels, plan, spec)
            base_per_tok = base_ev[-1].kernel_end if base_ev else 0.0
            for k in ks:
                if k == 0:
                    continue
                meas = measured.get((k, b))
                ev = simulate_plan(tr.kernels, plan, spec,
                                   batch_scale=float(k + 1),
                                   draft_launches=k * n_draft_kernels)
                round_s = ev[-1].kernel_end if ev else 0.0
                spec_per_tok = round_s / emit_per_seq[k]
                tax = k * n_draft_kernels * dispatch_fanout_s(spec, 1)
                win = bool(spec_per_tok < base_per_tok)
                modeled.append({
                    "platform": plat, "coupling": spec.coupling,
                    "k": k, "batch": b, "measured": meas is not None,
                    "accept_rate": round(
                        (meas or measured[(k, max(batches))]).accept_rate,
                        3),
                    "emitted_per_seq_per_round":
                        round(emit_per_seq[k], 3),
                    "modeled_baseline_per_token_us":
                        round(base_per_tok * 1e6, 1),
                    "modeled_spec_per_token_us":
                        round(spec_per_tok * 1e6, 1),
                    "modeled_draft_launch_tax_per_round_us":
                        round(tax * 1e6, 1),
                    "win": win,
                })
                if win:
                    win_region[plat].setdefault(str(k), []).append(b)
    return {
        "arch": cfg.name, "draft": draft_cfg.name,
        "scenario": workload.scenario, "seed": workload.seed,
        "n_requests": workload.n, "max_len": max_len, "cache": cache,
        "ks": list(ks), "batches": list(batches),
        "model_batches": list(model_batches),
        "platforms": list(platforms),
        "n_draft_kernels": n_draft_kernels,
        "measured": [p.row() for p in points],
        "modeled": modeled,
        "win_batches": win_region,
    }

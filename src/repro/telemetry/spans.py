"""Low-overhead span recorder for host-side dispatch telemetry.

A ``SpanRecorder`` collects completed spans — (name, category, thread,
begin, end, args) — from the serving engine and the plan executor.  It is
deliberately dumb and allocation-light: recording is an ``append`` of one
small object, a disabled recorder costs one attribute check, and nothing
is aggregated until a report or export asks for it.  Timestamps are
whatever clock the caller stamps with (the engine uses its virtual
serving clock so idle fast-forwards don't appear as giant gaps).
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional

# chrome-trace thread ids for the merged timeline
TID_HOST = 0          # engine-level host work (prefill/decode dispatch)
TID_SEGMENTS = 1      # per-segment launches inside PlanExecutor
TID_DEVICE = 2        # modeled device lane (simulated kernels)


@dataclass
class Span:
    name: str
    cat: str
    t0: float                     # seconds, caller's clock
    t1: float
    tid: int = TID_HOST
    args: Optional[dict] = None

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


@dataclass
class SpanRecorder:
    """``max_spans=None`` (the default) keeps every span — right for
    short characterize runs that export full traces.  Long serving runs
    pass a cap: the recorder then keeps only the NEWEST ``max_spans``
    spans (ring-buffer semantics) and counts evictions in ``dropped``,
    also published as the ``telemetry_spans_dropped_total`` counter when
    a registry is bound."""

    enabled: bool = True
    spans: list = field(default_factory=list)
    max_spans: Optional[int] = None
    dropped: int = 0

    def __post_init__(self):
        if self.max_spans is not None and self.max_spans < 1:
            raise ValueError(f"max_spans must be >= 1, got {self.max_spans}")
        self._dropped_total = None

    def bind_metrics(self, registry) -> None:
        self._dropped_total = registry.counter(
            "telemetry_spans_dropped_total",
            "spans evicted from the SpanRecorder ring buffer")
        if self.dropped:
            self._dropped_total.inc(self.dropped)

    def add(self, name: str, cat: str, t0: float, t1: float, *,
            tid: int = TID_HOST, **args) -> None:
        if not self.enabled:
            return
        self.spans.append(Span(name, cat, t0, t1, tid=tid,
                               args=args or None))
        if self.max_spans is not None and len(self.spans) > self.max_spans:
            # one add() can overflow by at most one span, so a single
            # pop-from-front keeps the newest max_spans entries
            self.spans.pop(0)
            self.dropped += 1
            if self._dropped_total is not None:
                self._dropped_total.inc()

    @contextmanager
    def span(self, name: str, cat: str = "host", *, tid: int = TID_HOST,
             **args):
        """Wall-clock convenience wrapper (perf_counter timestamps)."""
        if not self.enabled:
            yield
            return
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add(name, cat, t0, time.perf_counter(), tid=tid, **args)

    def clear(self) -> None:
        self.spans.clear()

    # ------------------------------------------------------------ queries
    def by_cat(self, cat: str) -> list:
        return [s for s in self.spans if s.cat == cat]

    def total_s(self, cat: str) -> float:
        return sum(s.dur for s in self.by_cat(cat))

"""Streaming-latency metrics: per-request timings -> TTFT/ITL/E2E percentiles.

Percentiles use linear interpolation between closest ranks — the same
definition as ``numpy.percentile``'s default — implemented directly so
the telemetry path has no array-library dependency and the equivalence
is testable rather than assumed.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable, Sequence

PCTS = (50, 95, 99)


def percentile(values: Sequence[float], q: float) -> float:
    """q-th percentile (0..100), linear interpolation (numpy default)."""
    if not values:
        return float("nan")
    s = sorted(values)
    if len(s) == 1:
        return float(s[0])
    pos = (len(s) - 1) * (q / 100.0)
    lo = math.floor(pos)
    hi = math.ceil(pos)
    if lo == hi:
        return float(s[lo])
    return float(s[lo] + (s[hi] - s[lo]) * (pos - lo))


def percentiles(values: Sequence[float],
                qs: Iterable[int] = PCTS) -> dict:
    return {f"p{q}": percentile(values, q) for q in qs}


@dataclass
class RequestTiming:
    """Lifecycle timestamps of one request, all on the engine clock."""
    rid: int
    arrival_s: float
    first_token_s: float = float("nan")
    done_s: float = float("nan")
    token_times_s: list = field(default_factory=list)  # incl. first token

    @property
    def ttft_s(self) -> float:
        return self.first_token_s - self.arrival_s

    @property
    def e2e_s(self) -> float:
        return self.done_s - self.arrival_s

    @property
    def itl_s(self) -> list:
        """Inter-token latencies (gaps between consecutive tokens)."""
        ts = self.token_times_s
        return [b - a for a, b in zip(ts, ts[1:])]

    @property
    def mean_itl_s(self) -> float:
        itl = self.itl_s
        return sum(itl) / len(itl) if itl else float("nan")


@dataclass
class LatencySummary:
    n_requests: int
    ttft: dict                     # {"p50": s, "p95": s, "p99": s}
    itl: dict
    e2e: dict
    mean_ttft_s: float
    mean_itl_s: float

    def row(self, unit: float = 1e3) -> dict:
        """Flat dict in milliseconds (unit=1e3) for JSON output."""
        out = {"n_requests": self.n_requests}
        for metric, pcts in (("ttft", self.ttft), ("itl", self.itl),
                             ("e2e", self.e2e)):
            for k, v in pcts.items():
                out[f"{metric}_{k}_ms"] = round(v * unit, 3)
        out["mean_ttft_ms"] = round(self.mean_ttft_s * unit, 3)
        out["mean_itl_ms"] = round(self.mean_itl_s * unit, 3)
        return out


def summarize(timings: Sequence[RequestTiming],
              qs: Iterable[int] = PCTS) -> LatencySummary:
    ttfts = [t.ttft_s for t in timings if not math.isnan(t.ttft_s)]
    e2es = [t.e2e_s for t in timings if not math.isnan(t.e2e_s)]
    itls = [g for t in timings for g in t.itl_s]
    return LatencySummary(
        n_requests=len(timings),
        ttft=percentiles(ttfts, qs),
        itl=percentiles(itls, qs),
        e2e=percentiles(e2es, qs),
        mean_ttft_s=sum(ttfts) / len(ttfts) if ttfts else float("nan"),
        mean_itl_s=sum(itls) / len(itls) if itls else float("nan"),
    )

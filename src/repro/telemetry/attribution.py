"""Operator→kernel attribution: resolve every launch to the model
operator that issued it.

Provenance flows in three hops: ``jax.named_scope`` tags in
``models/transformer.py`` land on traced eqns' name stacks, which
``core.tracing`` copies onto ``Kernel.operator`` (re-prepending scopes
lost when call-like primitives are inlined); launch-plan segments group
kernels, so a segment's single dispatch is split across its members'
operators by kernel count (a fused-rule segment attributes fractionally
to its constituent ops); and ``simulate_plan``'s per-segment
``KernelEvent`` timeline supplies the launch/queue/exec decomposition
each fraction prices against.

Launch counts accumulate as ``fractions.Fraction`` so the acceptance
invariant — attribution accounts for 100% of dispatches — is exact
arithmetic, not a float tolerance.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from fractions import Fraction
from typing import Optional, Sequence

# canonical op kinds, in display order (ISSUE taxonomy: attention / mlp /
# norm / collective / draft + the stack's edge ops)
OP_KINDS = ("attention", "mlp", "norm", "embed", "unembed", "residual",
            "mamba", "rwkv", "moe", "collective", "draft", "other")

# scope-path component -> canonical op kind (first match along the path,
# innermost component first, wins)
_COMPONENT_OP = {
    "attn": "attention", "attn_local": "attention", "xattn": "attention",
    "mlp": "mlp", "rwkv_channel": "mlp",
    "moe": "moe",
    "norm1": "norm", "norm2": "norm", "norm": "norm",
    "final_norm": "norm", "norm_x": "norm",
    "embed": "embed", "unembed": "unembed",
    "resid": "residual",
    "mamba": "mamba", "rwkv": "rwkv",
}

# primitive names that are collectives regardless of scope
_COLLECTIVE_PRIMS = {"psum", "all_reduce", "all_gather", "ppermute",
                     "all_to_all", "reduce_scatter", "psum_scatter"}

_LAYER_RE = re.compile(r"^layer(\d+)$")


@dataclass(frozen=True)
class OpTag:
    """Parsed provenance of one kernel."""
    op: str                        # canonical kind from OP_KINDS
    layer: Optional[int]           # layer index, when the scope names one
    raw: str                       # the full named_scope path

    def key(self, by_layer: bool = False) -> str:
        if by_layer and self.layer is not None:
            return f"layer{self.layer}/{self.op}"
        return self.op


def parse_operator(raw: str, kernel_name: str = "") -> OpTag:
    """Map a named_scope path (+ primitive name) to its canonical tag."""
    if kernel_name in _COLLECTIVE_PRIMS:
        return OpTag("collective", _scope_layer(raw), raw)
    if raw.startswith("draft"):
        return OpTag("draft", None, raw)
    layer = _scope_layer(raw)
    # innermost component wins: "layer0/slot0/attn" -> attention even
    # though einsum sub-scopes may trail it
    for comp in reversed(raw.split("/")):
        op = _COMPONENT_OP.get(comp)
        if op is not None:
            return OpTag(op, layer, raw)
    return OpTag("other", layer, raw)


def _scope_layer(raw: str) -> Optional[int]:
    for comp in raw.split("/"):
        m = _LAYER_RE.match(comp)
        if m:
            return int(m.group(1))
    return None


def segment_ops(kernels: Sequence, seg: Sequence,
                by_layer: bool = False) -> dict:
    """Kernel count per canonical op for one plan segment."""
    counts: dict = {}
    for i in seg:
        k = kernels[i]
        tag = parse_operator(getattr(k, "operator", ""), k.name)
        key = tag.key(by_layer)
        counts[key] = counts.get(key, 0) + 1
    return counts


@dataclass
class OperatorRow:
    """Attributed totals for one operator across a dispatch timeline."""
    operator: str
    launches: Fraction = Fraction(0)
    kernels: int = 0
    launch_s: float = 0.0
    queue_s: float = 0.0
    exec_s: float = 0.0

    @property
    def tklqt_s(self) -> float:
        return self.launch_s + self.queue_s

    def as_dict(self, total_tklqt_s: float = 0.0) -> dict:
        return {
            "operator": self.operator,
            "launches": float(self.launches),
            "kernels": self.kernels,
            "launch_us": self.launch_s * 1e6,
            "queue_us": self.queue_s * 1e6,
            "exec_us": self.exec_s * 1e6,
            "tklqt_us": self.tklqt_s * 1e6,
            "tklqt_pct": (100.0 * self.tklqt_s / total_tklqt_s
                          if total_tklqt_s > 0 else 0.0),
        }


@dataclass
class AttributionReport:
    """Per-operator decomposition of one simulated dispatch timeline."""
    rows: list = field(default_factory=list)   # [OperatorRow], tklqt desc
    total_events: int = 0

    @property
    def accounted_launches(self) -> Fraction:
        return sum((r.launches for r in self.rows), Fraction(0))

    @property
    def complete(self) -> bool:
        """Exact (rational-arithmetic) 100%-of-dispatches check."""
        return self.accounted_launches == self.total_events

    @property
    def tklqt_s(self) -> float:
        return sum(r.tklqt_s for r in self.rows)

    def top_k(self, k: int) -> list:
        return self.rows[:k]

    def as_dicts(self) -> list:
        total = self.tklqt_s
        return [r.as_dict(total) for r in self.rows]


def attribute_events(kernels: Sequence, plan, events: Sequence,
                     by_layer: bool = False) -> AttributionReport:
    """Attribute a ``simulate_plan`` timeline to model operators.

    ``events`` is the planner's modeled timeline: optional host-only
    ``draft_launch[i]`` events first, then exactly one ``KernelEvent``
    per plan segment, in plan order.  Each segment's launch/queue/exec
    time splits across its member kernels' operators proportionally to
    kernel count, so fused segments attribute to their constituent ops
    and Σ launches over rows equals len(events) exactly.
    """
    rows: dict = {}

    def row(key: str) -> OperatorRow:
        r = rows.get(key)
        if r is None:
            r = rows[key] = OperatorRow(key)
        return r

    si = 0
    segments = plan.segments
    for e in events:
        if e.name.startswith("draft_launch["):
            r = row("draft")
            r.launches += 1
            r.launch_s += e.t_launch
            r.queue_s += e.t_queue
            r.exec_s += e.duration
            continue
        if si >= len(segments):
            raise ValueError(
                f"timeline has more segment events than plan segments "
                f"({len(segments)}); extra event {e.name!r}")
        seg = segments[si]
        si += 1
        counts = segment_ops(kernels, seg, by_layer)
        n = len(seg)
        for key, c in counts.items():
            frac = Fraction(c, n)
            r = row(key)
            r.launches += frac
            r.kernels += c
            w = float(frac)
            r.launch_s += e.t_launch * w
            r.queue_s += e.t_queue * w
            r.exec_s += e.duration * w
    if si != len(segments):
        raise ValueError(
            f"timeline covered {si} of {len(segments)} plan segments")
    ordered = sorted(rows.values(), key=lambda r: -r.tklqt_s)
    return AttributionReport(rows=ordered, total_events=len(events))


def merge_report(dst: dict, report: AttributionReport,
                 calls: int = 1) -> dict:
    """Accumulate a per-call report into a running per-operator dict
    (used by the engine to aggregate over every decode call)."""
    for r in report.rows:
        acc = dst.get(r.operator)
        if acc is None:
            acc = dst[r.operator] = OperatorRow(r.operator)
        acc.launches += r.launches * calls
        acc.kernels += r.kernels * calls
        acc.launch_s += r.launch_s * calls
        acc.queue_s += r.queue_s * calls
        acc.exec_s += r.exec_s * calls
    return dst

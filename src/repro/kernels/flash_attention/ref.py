"""Pure-jnp oracle for the flash-attention kernel (BHSD layout, GQA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def attention_ref(q, k, v, *, scale: float, causal: bool = True,
                  window: int = 0, softcap: float = 0.0,
                  kv_len=None):
    """q: (B,HQ,S,hd); k/v: (B,HKV,T,hd); kv_len: scalar valid-KV bound.

    Dense reference with fp32 softmax — the oracle the Pallas kernel (and
    the XLA flash path) must match.
    """
    b, hq, s, hd = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = hq // hkv
    kf = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    sc = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32), kf) * scale
    if softcap:
        sc = softcap * jnp.tanh(sc / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        # rows/cols aligned at the end: q token i sits at position T-S+i
        mask &= (qpos + (t - s)) >= kpos
    if window:
        mask &= (qpos + (t - s) - kpos) < window
    if kv_len is not None:
        mask &= kpos < kv_len
    sc = jnp.where(mask[None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhst,bhtd->bhsd", p, vf)
    return o.astype(q.dtype)

"""Jitted public wrapper: padding/alignment + layout around the kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=(
    "scale", "causal", "window", "softcap", "block_q", "block_kv",
    "interpret"))
def flash_attention(q, k, v, kv_len=None, *, scale: float, causal=True,
                    window=0, softcap=0.0, block_q=128, block_kv=128,
                    interpret=True):
    """q: (B,HQ,S,hd); k/v: (B,HKV,T,hd); kv_len: scalar int (None -> T)."""
    b, hq, s, hd = q.shape
    t = k.shape[2]
    if kv_len is None:
        kv_len = t
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(1)

    bq = min(block_q, max(8, 1 << (s - 1).bit_length()))
    bkv = min(block_kv, max(8, 1 << (t - 1).bit_length()))
    q_, pad_s = _pad_to(q, bq, 2)
    k_, pad_t = _pad_to(k, bkv, 2)
    v_, _ = _pad_to(v, bkv, 2)
    # pad head dim to the 128 lane width (zeros are exact: they add nothing
    # to q.k and produce zero output columns, sliced off below)
    q_, pad_h = _pad_to(q_, 128, 3)
    k_, _ = _pad_to(k_, 128, 3)
    v_, _ = _pad_to(v_, 128, 3)
    # padded queries sit at the causal tail: they attend to everything valid
    # but are discarded; padded KV masked via kv_len
    kv_len_eff = jnp.minimum(kv_len, t)

    o = flash_attention_kernel(
        q_, k_, v_, kv_len_eff, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=bq, block_kv=bkv, q_offset=t - s,
        interpret=interpret)
    return o[:, :, :s, :hd]

"""Pallas TPU flash attention: online-softmax tiles in VMEM, MXU matmuls.

Grid (B, HQ, nQ, nKV) — the KV dim innermost so the (m, l, acc) scratch
accumulators carry across KV tiles of one Q tile.  GQA is handled in the
K/V index_map (h -> h // group) so KV is never expanded in HBM.  Causal and
sliding-window masking use global position iota; tiles are f32 in VMEM,
matmuls hit the MXU at (block_q x hd) x (hd x block_kv).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _fa_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
               *, scale, causal, window, softcap, block_q, block_kv,
               n_kv, q_offset):
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bkv, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    iq = pl.program_id(2)
    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 0)
    qpos = qpos + q_offset                 # right-aligned query positions
    kpos = ikv * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                     (block_q, block_kv), 1)
    mask = jnp.ones((block_q, block_kv), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    mask &= kpos < kvlen_ref[0]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ikv == n_kv - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, kv_len, *, scale, causal=True, window=0,
                           softcap=0.0, block_q=128, block_kv=128,
                           q_offset=0, interpret=True):
    """q: (B,HQ,S,hd) | k/v: (B,HKV,T,hd) | kv_len: (1,) int32 valid bound.

    S, T must be multiples of the block sizes and hd 128-aligned on real
    TPUs — ops.py pads.  q_offset: global position of q row 0 (right-aligned
    decode/prefill windows).  Returns (B,HQ,S,hd).
    """
    b, hq, s_len, hd = q.shape
    hkv, t_len = k.shape[1], k.shape[2]
    g = hq // hkv
    nq = s_len // block_q
    nkv = t_len // block_kv
    grid = (b, hq, nq, nkv)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_kv=block_kv,
        n_kv=nkv, q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bb, h, iq, ikv: (bb, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda bb, h, iq, ikv: (bb, h // g, ikv, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda bb, h, iq, ikv: (bb, h // g, ikv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bb, h, iq, ikv: (bb, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, q, k, v)

"""Fused Pallas kernels for the decode hot path.

Each subpackage mirrors the top-level kernel layout — ``kernel.py`` is the
hand-tiled Pallas TPU kernel, ``ops.py`` the jitted shape-polymorphic
wrapper, ``ref.py`` the pure-jnp oracle — and every kernel runs in
interpret mode on CPU so CI exercises the exact code path the rule
registry substitutes into launch plans (``repro.runtime.rules``).

residual_rmsnorm  — residual add + RMSNorm (+ optional plain-norm form):
                    the 9/10-eqn window at every decoder block boundary
rmsnorm_matmul    — RMSNorm + projection matmul: the norm that feeds the
                    qkv/MLP dot_general, one VMEM round trip for both
"""

from repro.kernels.fused.residual_rmsnorm.ops import (  # noqa: F401
    residual_rmsnorm,
)
from repro.kernels.fused.rmsnorm_matmul.ops import rmsnorm_matmul  # noqa: F401

"""Oracle for fused residual-add + RMSNorm (decode block boundary)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def residual_rmsnorm_ref(x, weight, residual=None, eps: float = 1e-5):
    """x: (N, D); weight: (D,); optional residual added before the norm.

    Returns ``(normed, pre_norm_sum)`` — both live in the decode trace:
    the normed value feeds the next projection, the sum is the residual
    stream consumed by the following block.
    """
    s = x if residual is None else x + residual
    sf = s.astype(jnp.float32)
    var = jnp.mean(jnp.square(sf), axis=-1, keepdims=True)
    out = sf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype), s

"""Jitted wrapper for the fused residual-add + RMSNorm kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fused.residual_rmsnorm.kernel import residual_rmsnorm_kernel


@functools.partial(jax.jit, static_argnames=("eps", "block_n", "interpret"))
def residual_rmsnorm(
    x, weight, residual=None, *, eps=1e-5, block_n=256, interpret=True
):
    """x: (..., D) -> (normed, pre-norm sum), leading dims flattened.

    Without a residual the pre-norm sum is the input itself, so ``x`` is
    returned directly and the kernel emits only the normed output.
    """
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    r2 = residual.reshape(-1, d) if residual is not None else None
    n = x2.shape[0]
    bn = min(block_n, max(8, 1 << (n - 1).bit_length()))
    pad = (-n) % bn
    if pad:
        x2 = jnp.pad(x2, [(0, pad), (0, 0)])
        if r2 is not None:
            r2 = jnp.pad(r2, [(0, pad), (0, 0)])
    outs = residual_rmsnorm_kernel(
        x2, weight, r2, eps=eps, block_n=bn, interpret=interpret
    )
    y = outs[0][:n].reshape(shape)
    s = outs[1][:n].reshape(shape) if residual is not None else x
    return y, s

"""Pallas TPU fused residual-add + RMSNorm for the decode hot path.

The eager decode trace spends 10 eqns per block boundary on
``add -> square -> reduce_sum -> broadcast -> div -> add -> rsqrt -> mul
-> broadcast -> mul``; this kernel is that window as ONE launch: row
blocks of (block_n, D) in VMEM, fp32 statistics, one HBM round trip for
both live outputs (the normed rows and the residual stream).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _res_rms_kernel(x_ref, r_ref, w_ref, o_ref, *s_ref, eps, has_residual):
    s = x_ref[...].astype(jnp.float32)
    if has_residual:
        s = s + r_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(s), axis=-1, keepdims=True)
    y = s * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)[None]
    o_ref[...] = y.astype(o_ref.dtype)
    if s_ref:
        # the residual-stream output only exists when a residual was
        # actually added; the bare-norm form skips the dead (N, D) write
        s_ref[0][...] = s.astype(s_ref[0].dtype)


def residual_rmsnorm_kernel(
    x,
    weight,
    residual=None,
    *,
    eps=1e-5,
    block_n=256,
    interpret=True,
):
    """x: (N, D) -> [normed (N, D)] or [normed, pre-norm sum (N, D)].

    The pre-norm-sum output is emitted only when ``residual`` is given —
    without one the sum IS the input, so materializing it would be a
    dead full-width HBM write in the decode hot path.
    """
    n, d = x.shape
    has_res = residual is not None
    out_spec = pl.BlockSpec((block_n, d), lambda i: (i, 0))
    out_specs = [out_spec, out_spec] if has_res else [out_spec]
    sds = jax.ShapeDtypeStruct((n, d), x.dtype)
    out_shape = [sds, sds] if has_res else [sds]
    if has_res:
        r_spec = pl.BlockSpec((block_n, d), lambda i: (i, 0))
    else:
        residual = jnp.zeros((1, d), x.dtype)  # dummy, never read
        r_spec = pl.BlockSpec((1, d), lambda i: (0, 0))
    kernel = functools.partial(_res_rms_kernel, eps=eps, has_residual=has_res)
    return pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            r_spec,
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, residual, weight)

"""Pallas TPU fused RMSNorm + projection matmul.

The decode trace norms each row then immediately contracts it with a
projection weight (qkv / MLP in / unembed).  Eager pays one launch per
eqn plus an HBM round trip for the normed intermediate; here the norm
runs on the VPU while the row block is already in VMEM for the MXU dot,
so the window is one launch and the intermediate never leaves VMEM.

Grid: (row blocks, F blocks).  The norm is recomputed per F block — VPU
work that is negligible next to the MXU dot and cheaper than a second
HBM pass.  fp32 statistics and accumulation throughout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_mm_kernel(x_ref, w_ref, p_ref, y_ref, n_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    scale = w_ref[...].astype(jnp.float32)[None]
    normed = (x * jax.lax.rsqrt(var + eps) * scale).astype(n_ref.dtype)
    y = jax.lax.dot_general(
        normed,
        p_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y_ref[...] = y.astype(y_ref.dtype)
    n_ref[...] = normed


def rmsnorm_matmul_kernel(
    x,
    weight,
    w_proj,
    *,
    eps=1e-5,
    block_n=256,
    block_f=512,
    interpret=True,
):
    """x: (N, D), weight: (D,), w_proj: (D, F) -> ((N, F), normed (N, D))."""
    n, d = x.shape
    f = w_proj.shape[1]
    block_f = min(block_f, f)
    kernel = functools.partial(_rms_mm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n // block_n, f // block_f),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
            pl.BlockSpec((d, block_f), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, block_f), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, f), w_proj.dtype),
            jax.ShapeDtypeStruct((n, d), x.dtype),
        ],
        interpret=interpret,
    )(x, weight, w_proj)

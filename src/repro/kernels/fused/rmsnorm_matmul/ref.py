"""Oracle for fused RMSNorm + projection matmul."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_matmul_ref(x, weight, w_proj, eps: float = 1e-5):
    """x: (N, D); weight: (D,); w_proj: (D, F).

    Returns ``(x_normed @ w_proj, x_normed)`` — the projection feeds one
    dot_general consumer, the normed rows stay live because q/k/v (or
    gate/up) projections share one norm in the decode trace.
    """
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    normed = normed.astype(x.dtype)
    return normed @ w_proj.astype(normed.dtype), normed

"""Jitted wrapper for the fused RMSNorm + projection matmul kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fused.rmsnorm_matmul.kernel import rmsnorm_matmul_kernel


@functools.partial(
    jax.jit,
    static_argnames=("eps", "block_n", "block_f", "interpret"),
)
def rmsnorm_matmul(
    x,
    weight,
    w_proj,
    *,
    eps=1e-5,
    block_n=256,
    block_f=512,
    interpret=True,
):
    """x: (..., D), w_proj: (D, F) -> (proj (..., F), normed (..., D))."""
    shape = x.shape
    d = shape[-1]
    f = w_proj.shape[1]
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    bn = min(block_n, max(8, 1 << (n - 1).bit_length()))
    pad = (-n) % bn
    if pad:
        x2 = jnp.pad(x2, [(0, pad), (0, 0)])
    bf = min(block_f, f)
    pad_f = (-f) % bf
    w2 = jnp.pad(w_proj, [(0, 0), (0, pad_f)]) if pad_f else w_proj
    y, normed = rmsnorm_matmul_kernel(
        x2, weight, w2, eps=eps, block_n=bn, block_f=bf, interpret=interpret
    )
    return y[:n, :f].reshape(shape[:-1] + (f,)), normed[:n].reshape(shape)

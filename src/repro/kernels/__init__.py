"""Pallas TPU kernels for the compute hot-spots, each with a jitted wrapper
(ops.py) and a pure-jnp oracle (ref.py), validated in interpret mode.

flash_attention  — online-softmax VMEM tiles, GQA via K/V index_map,
                   causal/sliding-window/softcap (the paper's
                   domain-specific-fusion exemplar, TPU-native)
decode_attention — flash-decoding over a long KV cache (memory-bound)
rmsnorm          — fused residual+RMSNorm (a PS=1 chain, hand-fused)
rwkv6            — chunked WKV6 with data-dependent decay (log-space,
                   overflow-safe; MXU cumsum via triangular matmul)
"""
from repro.kernels.flash_attention.ops import flash_attention  # noqa: F401
from repro.kernels.decode_attention.ops import decode_attention  # noqa: F401
from repro.kernels.rmsnorm.ops import rmsnorm as fused_rmsnorm  # noqa: F401
from repro.kernels.rwkv6.ops import wkv6  # noqa: F401

"""Jitted wrapper for the decode-attention kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_kernel


@functools.partial(jax.jit, static_argnames=("scale", "block_kv", "interpret"))
def decode_attention(q, k, v, kv_len=None, *, scale: float, block_kv=512,
                     interpret=True):
    """q: (B,HQ,hd); k/v: (B,HKV,T,hd); kv_len scalar (None -> T)."""
    b, hq, hd = q.shape
    t = k.shape[2]
    if kv_len is None:
        kv_len = t
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(1)

    bkv = min(block_kv, max(8, 1 << (t - 1).bit_length()))
    pad_t = (-t) % bkv
    if pad_t:
        widths = [(0, 0), (0, 0), (0, pad_t), (0, 0)]
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    pad_h = (-hd) % 128
    if pad_h:
        q = jnp.pad(q, [(0, 0), (0, 0), (0, pad_h)])
        k = jnp.pad(k, [(0, 0), (0, 0), (0, 0), (0, pad_h)])
        v = jnp.pad(v, [(0, 0), (0, 0), (0, 0), (0, pad_h)])

    kv_len = jnp.minimum(kv_len, t)
    o = decode_attention_kernel(q[:, :, None, :], k, v, kv_len, scale=scale,
                                block_kv=bkv, interpret=interpret)
    return o[:, :, 0, :hd]

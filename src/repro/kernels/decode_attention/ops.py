"""Jitted wrappers for the decode-attention kernels (contiguous + paged)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import (
    decode_attention_kernel, paged_decode_attention_kernel,
    paged_decode_attention_quant_kernel)


@functools.partial(jax.jit, static_argnames=("scale", "block_kv", "interpret"))
def decode_attention(q, k, v, kv_len=None, *, scale: float, block_kv=512,
                     interpret=True):
    """q: (B,HQ,hd); k/v: (B,HKV,T,hd); kv_len scalar (None -> T)."""
    b, hq, hd = q.shape
    t = k.shape[2]
    if kv_len is None:
        kv_len = t
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(1)

    bkv = min(block_kv, max(8, 1 << (t - 1).bit_length()))
    pad_t = (-t) % bkv
    if pad_t:
        widths = [(0, 0), (0, 0), (0, pad_t), (0, 0)]
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    pad_h = (-hd) % 128
    if pad_h:
        q = jnp.pad(q, [(0, 0), (0, 0), (0, pad_h)])
        k = jnp.pad(k, [(0, 0), (0, 0), (0, 0), (0, pad_h)])
        v = jnp.pad(v, [(0, 0), (0, 0), (0, 0), (0, pad_h)])

    kv_len = jnp.minimum(kv_len, t)
    o = decode_attention_kernel(q[:, :, None, :], k, v, kv_len, scale=scale,
                                block_kv=bkv, interpret=interpret)
    return o[:, :, 0, :hd]


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, block_tables, kv_lens, *,
                           scale: float, interpret=True,
                           k_scale=None, v_scale=None):
    """Decode attention through a block-table paged KV cache.

    q: (B,HQ,hd); k_pages/v_pages: (P,bs,HKV,hd) pooled token pages (the
    ``repro.kvcache`` layout); block_tables: (B,NB) int32 page ids (entries
    past a row's length may be any value); kv_lens: (B,) valid tokens.

    With ``k_scale``/``v_scale`` — (P,bs,HKV) f32, the quantized-pool
    layout — the pages are int8 payloads and the quantized kernel
    dequantizes each page tile after the DMA.

    The wrapper re-lays pages head-major — (HKV,P,bs,hd) — so each grid
    step of the kernel streams one (bs,hd) page tile picked by the
    scalar-prefetched block table; on a real TPU this transpose would be
    kept resident rather than re-done per step.
    """
    b, hq, hd = q.shape
    n_pages, bs, hkv, _ = k_pages.shape
    kp = jnp.transpose(k_pages, (2, 0, 1, 3))
    vp = jnp.transpose(v_pages, (2, 0, 1, 3))
    pad_h = (-hd) % 128
    if pad_h:
        q = jnp.pad(q, [(0, 0), (0, 0), (0, pad_h)])
        kp = jnp.pad(kp, [(0, 0), (0, 0), (0, 0), (0, pad_h)])
        vp = jnp.pad(vp, [(0, 0), (0, 0), (0, 0), (0, pad_h)])
    # out-of-range table entries (pool sentinels) must not steer a DMA
    bt = jnp.clip(block_tables.astype(jnp.int32), 0, n_pages - 1)
    kv_lens = jnp.minimum(kv_lens.astype(jnp.int32),
                          block_tables.shape[1] * bs)
    if k_scale is not None:
        ks = jnp.transpose(k_scale, (2, 0, 1)).astype(jnp.float32)
        vs = jnp.transpose(v_scale, (2, 0, 1)).astype(jnp.float32)
        o = paged_decode_attention_quant_kernel(
            q[:, :, None, :], kp, vp, ks, vs, bt, kv_lens,
            scale=scale, interpret=interpret)
        return o[:, :, 0, :hd]
    o = paged_decode_attention_kernel(q[:, :, None, :], kp, vp, bt, kv_lens,
                                      scale=scale, interpret=interpret)
    return o[:, :, 0, :hd]

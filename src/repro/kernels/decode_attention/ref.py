"""Oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def decode_attention_ref(q, k, v, kv_len, *, scale: float):
    """q: (B,HQ,hd); k/v: (B,HKV,T,hd); kv_len: scalar — positions < kv_len
    are valid.  Returns (B,HQ,hd)."""
    b, hq, hd = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = hq // hkv
    kf = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhtd->bht", q.astype(jnp.float32), kf) * scale
    mask = jnp.arange(t)[None, None, :] < kv_len
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bht,bhtd->bhd", p, vf)
    return o.astype(q.dtype)

"""Oracles for single-token decode attention over a KV cache.

``decode_attention_ref`` reads a contiguous per-sequence cache;
``paged_decode_attention_ref`` reads the same logical KV through a
block table over a pool of fixed-size token pages (the paged KV cache
layout of ``repro.kvcache``): position ``t`` of row ``b`` lives at
``pages[tables[b, t // bs], t % bs]``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def decode_attention_ref(q, k, v, kv_len, *, scale: float):
    """q: (B,HQ,hd); k/v: (B,HKV,T,hd); kv_len: scalar — positions < kv_len
    are valid.  Returns (B,HQ,hd)."""
    b, hq, hd = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = hq // hkv
    kf = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhtd->bht", q.astype(jnp.float32), kf) * scale
    mask = jnp.arange(t)[None, None, :] < kv_len
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bht,bhtd->bhd", p, vf)
    return o.astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, kv_lens, *,
                               scale: float):
    """q: (B,HQ,hd); k_pages/v_pages: (P,bs,HKV,hd) pooled token pages;
    block_tables: (B,NB) int32 page ids (entries past a row's length may be
    any value — they are masked); kv_lens: (B,) valid tokens per row.
    Returns (B,HQ,hd)."""
    b, hq, hd = q.shape
    n_pages, bs, hkv, _ = k_pages.shape
    nb = block_tables.shape[1]
    g = hq // hkv
    safe = jnp.clip(block_tables, 0, n_pages - 1)
    # gather each row's logical view: (B,NB,bs,HKV,hd) -> (B,HKV,T,hd)
    kg = k_pages[safe].reshape(b, nb * bs, hkv, hd).transpose(0, 2, 1, 3)
    vg = v_pages[safe].reshape(b, nb * bs, hkv, hd).transpose(0, 2, 1, 3)
    kf = jnp.repeat(kg, g, axis=1).astype(jnp.float32)
    vf = jnp.repeat(vg, g, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhtd->bht", q.astype(jnp.float32), kf) * scale
    mask = jnp.arange(nb * bs)[None, None, :] < kv_lens[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bht,bhtd->bhd", p, vf)
    return o.astype(q.dtype)


def paged_decode_attention_quant_ref(q, k_pages, v_pages, k_scale, v_scale,
                                     block_tables, kv_lens, *, scale: float):
    """Quantized-pool oracle: k_pages/v_pages are (P,bs,HKV,hd) int8 with
    per-(token, head) f32 scales (P,bs,HKV); dequantize the whole pool in
    f32 and defer to ``paged_decode_attention_ref``."""
    kf = k_pages.astype(jnp.float32) * k_scale[..., None]
    vf = v_pages.astype(jnp.float32) * v_scale[..., None]
    return paged_decode_attention_ref(q, kf, vf, block_tables, kv_lens,
                                      scale=scale)

"""Pallas TPU decode attention (flash-decoding style).

One query token per sequence attends over a long KV cache.  Grid
(B, HQ, nKV) with the KV dim innermost; online-softmax accumulators live in
VMEM scratch.  This kernel is memory-bound by design — its job is streaming
the KV cache through VMEM at full HBM bandwidth; the q row is re-packed to
(8, hd) sublanes to keep the VPU busy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _dec_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                *, scale, block_kv, n_kv):
    ikv = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bkv, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = ikv * block_kv + jax.lax.broadcasted_iota(jnp.int32, (1, block_kv), 1)
    s = jnp.where(kpos < kvlen_ref[0], s, NEG_INF)         # (1, bkv)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ikv == n_kv - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _paged_dec_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                      m_scr, l_scr, acc_scr, *, scale, block_size, n_blocks):
    bb = pl.program_id(0)
    ikv = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bs, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # logical position of this page's tokens: page ikv of row bb holds
    # positions [ikv*bs, (ikv+1)*bs) — the block table only redirects
    # WHERE the page lives, not WHICH positions it holds
    kpos = ikv * block_size + \
        jax.lax.broadcasted_iota(jnp.int32, (1, block_size), 1)
    s = jnp.where(kpos < len_ref[bb], s, NEG_INF)          # (1, bs)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ikv == n_blocks - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _paged_dec_quant_kernel(bt_ref, len_ref, q_ref, k_ref, ks_ref, v_ref,
                            vs_ref, o_ref, m_scr, l_scr, acc_scr, *,
                            scale, block_size, n_blocks):
    """Quantized-page variant of ``_paged_dec_kernel``: the pool holds int8
    payload pages + per-(token, head) f32 scale pages, and this kernel
    dequantizes each page tile AFTER the DMA — HBM traffic is the int8
    bytes + scales, never the widened bf16."""
    bb = pl.program_id(0)
    ikv = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # (1, hd)
    # dequantize in-register: int8 payload (bs, hd) x f32 scale (bs, 1)
    k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]
    v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = ikv * block_size + \
        jax.lax.broadcasted_iota(jnp.int32, (1, block_size), 1)
    s = jnp.where(kpos < len_ref[bb], s, NEG_INF)          # (1, bs)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ikv == n_blocks - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention_quant_kernel(q, k_pages, v_pages, k_scale,
                                        v_scale, block_tables, kv_lens, *,
                                        scale, interpret=True):
    """q: (B,HQ,1,hd); k_pages/v_pages: (HKV,P,bs,hd) int8; k_scale/v_scale:
    (HKV,P,bs) f32 per-(token, head) scales, DMA'd per page tile by the
    same scalar-prefetched block table that steers the payload fetch."""
    b, hq, _, hd = q.shape
    hkv, n_pages, bs = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    nb = block_tables.shape[1]
    g = hq // hkv
    kernel = functools.partial(_paged_dec_quant_kernel, scale=scale,
                               block_size=bs, n_blocks=nb)
    page_spec = pl.BlockSpec((1, 1, bs, hd),
                             lambda bb, h, ikv, bt, kl: (h // g, bt[bb, ikv],
                                                         0, 0))
    scale_spec = pl.BlockSpec((1, 1, bs),
                              lambda bb, h, ikv, bt, kl: (h // g,
                                                          bt[bb, ikv], 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # block_tables, kv_lens
        grid=(b, hq, nb),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd),
                         lambda bb, h, ikv, bt, kl: (bb, h, 0, 0)),
            page_spec, scale_spec, page_spec, scale_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd),
                               lambda bb, h, ikv, bt, kl: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, hd), q.dtype),
        interpret=interpret,
    )(block_tables, kv_lens, q, k_pages, k_scale, v_pages, v_scale)


def paged_decode_attention_kernel(q, k_pages, v_pages, block_tables, kv_lens,
                                  *, scale, interpret=True):
    """q: (B,HQ,1,hd); k_pages/v_pages: (HKV,P,bs,hd) — note the head axis
    leads so each grid step DMAs one (bs,hd) page tile; block_tables:
    (B,NB) int32 page ids (scalar-prefetched: the index map reads them to
    steer each page fetch); kv_lens: (B,) int32 valid tokens per row."""
    b, hq, _, hd = q.shape
    hkv, n_pages, bs = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    nb = block_tables.shape[1]
    g = hq // hkv
    kernel = functools.partial(_paged_dec_kernel, scale=scale,
                               block_size=bs, n_blocks=nb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # block_tables, kv_lens
        grid=(b, hq, nb),
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd),
                         lambda bb, h, ikv, bt, kl: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd),
                         lambda bb, h, ikv, bt, kl: (h // g, bt[bb, ikv],
                                                     0, 0)),
            pl.BlockSpec((1, 1, bs, hd),
                         lambda bb, h, ikv, bt, kl: (h // g, bt[bb, ikv],
                                                     0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd),
                               lambda bb, h, ikv, bt, kl: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, hd), q.dtype),
        interpret=interpret,
    )(block_tables, kv_lens, q, k_pages, v_pages)


def decode_attention_kernel(q, k, v, kv_len, *, scale, block_kv=512,
                            interpret=True):
    """q: (B,HQ,1,hd); k/v: (B,HKV,T,hd); kv_len: (1,) int32."""
    b, hq, _, hd = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = hq // hkv
    nkv = t // block_kv
    kernel = functools.partial(_dec_kernel, scale=scale, block_kv=block_kv,
                               n_kv=nkv)
    return pl.pallas_call(
        kernel,
        grid=(b, hq, nkv),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 1, hd), lambda bb, h, ikv: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda bb, h, ikv: (bb, h // g, ikv, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda bb, h, ikv: (bb, h // g, ikv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda bb, h, ikv: (bb, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, q, k, v)

"""Jitted wrapper for fused residual+RMSNorm."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_kernel


@functools.partial(jax.jit, static_argnames=("eps", "block_n", "interpret"))
def rmsnorm(x, weight, residual=None, *, eps=1e-5, block_n=256,
            interpret=True):
    """x: (..., D) -> (normed, residual_out) with leading dims flattened."""
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    r2 = residual.reshape(-1, d) if residual is not None else None
    n = x2.shape[0]
    bn = min(block_n, max(8, 1 << (n - 1).bit_length()))
    pad = (-n) % bn
    if pad:
        x2 = jnp.pad(x2, [(0, pad), (0, 0)])
        if r2 is not None:
            r2 = jnp.pad(r2, [(0, pad), (0, 0)])
    y, res = rmsnorm_kernel(x2, weight, r2, eps=eps, block_n=bn,
                            interpret=interpret)
    return y[:n].reshape(shape), res[:n].reshape(shape)

"""Pallas TPU fused residual-add + RMSNorm.

The eager chain  add -> square -> mean -> rsqrt -> mul -> mul  is exactly
the kind of deterministic PS=1 chain the proximity miner recommends fusing
(launch tax: 6 kernels -> 1); this kernel is that fusion, hand-tiled:
row-blocks of (block_n, D) in VMEM, fp32 statistics, one HBM round trip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, w_ref, r_ref, o_ref, res_ref, *, eps, has_residual):
    x = x_ref[...].astype(jnp.float32)
    if has_residual:
        x = x + r_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)[None]
    o_ref[...] = y.astype(o_ref.dtype)
    res_ref[...] = x.astype(res_ref.dtype)


def rmsnorm_kernel(x, weight, residual=None, *, eps=1e-5, block_n=256,
                   interpret=True):
    """x: (N, D) -> (normed (N,D), new_residual (N,D))."""
    n, d = x.shape
    has_res = residual is not None
    if residual is None:
        residual = jnp.zeros((1, d), x.dtype)   # dummy, never read
    grid = (n // block_n,)
    kernel = functools.partial(_rms_kernel, eps=eps, has_residual=has_res)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)) if has_res
            else pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), x.dtype),
            jax.ShapeDtypeStruct((n, d), x.dtype),
        ],
        interpret=interpret,
    )(x, weight, residual)

"""Oracle for the fused RMSNorm(+residual) kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, weight, residual=None, eps: float = 1e-5):
    """x: (N, D); weight: (D,); optional residual added BEFORE the norm
    (the fused residual+norm pattern at every block boundary)."""
    if residual is not None:
        x = x + residual
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype), x

"""Oracle for WKV6: the per-step recurrence, executed literally.

    o_t = r_t @ (S_{t-1}) + (r_t . (u (.) k_t)) v_t
    S_t = diag(w_t) S_{t-1} + k_t^T v_t        with w_t = exp(logw_t)
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def wkv6_ref(r, k, v, logw, u, s0):
    """r,k,v,logw: (B,H,T,hd) f32; u: (H,hd); s0: (B,H,hd,hd).
    Returns (o: (B,H,T,hd), sT)."""
    r, k, v, logw = (np.asarray(x, np.float64) for x in (r, k, v, logw))
    u = np.asarray(u, np.float64)
    s = np.asarray(s0, np.float64).copy()
    b, h, t, hd = r.shape
    o = np.zeros((b, h, t, hd))
    for bi in range(b):
        for hi in range(h):
            st = s[bi, hi]
            for ti in range(t):
                rt, kt, vt = r[bi, hi, ti], k[bi, hi, ti], v[bi, hi, ti]
                wt = np.exp(logw[bi, hi, ti])
                bonus = (rt * u[hi] * kt).sum() * vt
                o[bi, hi, ti] = rt @ st + bonus
                st = wt[:, None] * st + np.outer(kt, vt)
            s[bi, hi] = st
    return jnp.asarray(o, jnp.float32), jnp.asarray(s, jnp.float32)

"""Pallas TPU chunked WKV6 (data-dependent-decay linear attention).

Grid (B, H, nC) with the chunk dim innermost: the (hd x hd) state carries
across chunks in VMEM scratch.  In-chunk cumulative decays are computed in
log space via a lower-triangular ones matmul (MXU-friendly cumsum); every
exp() argument is <= 0 so the kernel is overflow-safe for any decay.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, sT_ref,
                 s_scr, *, chunk, n_chunks):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    rc = r_ref[0, 0].astype(jnp.float32)                  # (C, hd)
    kc = k_ref[0, 0].astype(jnp.float32)
    vc = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)                 # (C, hd), <= 0
    u = u_ref[0].astype(jnp.float32)                      # (hd,)
    s = s_scr[...]

    c = rc.shape[0]
    tril_inc = jnp.tril(jnp.ones((c, c), jnp.float32))    # inclusive cumsum
    cum = jax.lax.dot_general(tril_inc, lw, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (C, hd)
    cum_exc = cum - lw

    # A[t,s] = sum_i r[t,i] k[s,i] exp(cum_exc[t,i] - cum[s,i])  for s < t
    pair = cum_exc[:, None, :] - cum[None, :, :]          # (C, C, hd)
    strict = jnp.tril(jnp.ones((c, c), jnp.bool_), -1)
    pair = jnp.where(strict[:, :, None], pair, NEG_INF)
    m = jnp.exp(pair)
    a = jnp.sum(rc[:, None, :] * kc[None, :, :] * m, axis=2)   # (C, C)
    diag = jnp.sum(rc * u[None, :] * kc, axis=1)          # (C,)
    a = a + diag[:, None] * jnp.eye(c, dtype=jnp.float32)

    inter = jax.lax.dot_general(a, vc, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    dq = jnp.exp(cum_exc)                                 # (C, hd)
    cross = jax.lax.dot_general(rc * dq, s, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0, 0] = (inter + cross).astype(o_ref.dtype)

    tot = cum[-1:, :]                                     # (1, hd)
    dk = jnp.exp(tot - cum)                               # (C, hd)
    s_new = jnp.exp(tot[0])[:, None] * s + jax.lax.dot_general(
        (kc * dk), vc, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_scr[...] = s_new

    @pl.when(ic == n_chunks - 1)
    def _done():
        sT_ref[0, 0] = s_new.astype(sT_ref.dtype)


def wkv6_kernel(r, k, v, logw, u, s0, *, chunk=16, interpret=True):
    """r,k,v,logw: (B,H,T,hd); u: (H,hd); s0: (B,H,hd,hd).
    T must be a multiple of chunk (ops.py pads).  Returns (o, sT)."""
    b, h, t, hd = r.shape
    nc = t // chunk
    kernel = functools.partial(_wkv6_kernel, chunk=chunk, n_chunks=nc)
    io_spec = pl.BlockSpec((1, 1, chunk, hd),
                           lambda bb, hh, ic: (bb, hh, ic, 0))
    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            io_spec, io_spec, io_spec, io_spec,
            pl.BlockSpec((1, hd), lambda bb, hh, ic: (hh, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda bb, hh, ic: (bb, hh, 0, 0)),
        ],
        out_specs=[
            io_spec,
            pl.BlockSpec((1, 1, hd, hd), lambda bb, hh, ic: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, s0)

"""Jitted wrapper for the chunked WKV6 kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import wkv6_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, logw, u, s0, *, chunk=16, interpret=True):
    """r,k,v,logw: (B,H,T,hd); u: (H,hd); s0: (B,H,hd,hd) -> (o, sT).

    Pads T to the chunk multiple with zero k/v and zero log-decay (w=1):
    padded steps add nothing to the state and their outputs are sliced off.
    """
    b, h, t, hd = r.shape
    pad = (-t) % chunk
    if pad:
        w4 = [(0, 0), (0, 0), (0, pad), (0, 0)]
        r = jnp.pad(r, w4)
        k = jnp.pad(k, w4)
        v = jnp.pad(v, w4)
        logw = jnp.pad(logw, w4)
    o, sT = wkv6_kernel(r, k, v, logw, u, s0, chunk=chunk,
                        interpret=interpret)
    return o[:, :, :t], sT

"""Fault-tolerant checkpointing: async writes, integrity manifest, elastic
restore onto any mesh.

Layout per step:  <dir>/step_<N>/
    manifest.msgpack   {step, leaf paths, shapes, dtypes, sha256 per leaf}
    <leaf>.npy         full (unsharded) arrays

Full arrays make restores mesh-shape-agnostic: a checkpoint written on a
16x16 mesh restores onto 2x16x16, 4 hosts, or 1 CPU — the restore path
re-shards via the target NamedShardings (elastic scaling).  A SHA-256 per
leaf catches torn writes from mid-save failures; incomplete checkpoints
(no COMMIT file) are ignored by `latest_step`.
"""
from __future__ import annotations

import hashlib
import os
import shutil
import threading
from typing import Optional

import jax
import msgpack
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                       for p in path)
        out[key] = leaf
    return out


def _sha(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------ save
    def save(self, step: int, tree) -> None:
        # device->host copy happens synchronously (values are immutable
        # afterwards); disk I/O goes to the background thread
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        if self.async_write:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def _write(self, step: int, host: dict) -> None:
        d = os.path.join(self.dir, f"step_{step:08d}")
        tmp = d + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": {}}
        for key, arr in host.items():
            fn = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"][key] = {
                "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "sha256": _sha(arr)}
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        shutil.rmtree(d, ignore_errors=True)
        os.replace(tmp, d)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------ restore
    def all_steps(self) -> list[int]:
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "COMMIT")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, shardings=None,
                verify: bool = True):
        """Restore into the structure of target_tree; optional per-leaf
        NamedShardings re-shard for the current mesh (elastic restore)."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
            manifest = msgpack.unpackb(f.read())
        flat_t = _flatten(target_tree)
        flat_s = _flatten(shardings) if shardings is not None else {}
        out = {}
        for key, leaf in flat_t.items():
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = np.load(os.path.join(d, meta["file"]))
            if str(arr.dtype) != meta["dtype"]:
                # ml_dtypes (bfloat16, fp8) round-trip through .npy as raw
                # void bytes — reinterpret to the logical dtype
                import ml_dtypes
                arr = arr.view(np.dtype(getattr(ml_dtypes, meta["dtype"],
                                                meta["dtype"])))
            if verify and _sha(arr) != meta["sha256"]:
                raise IOError(f"checkpoint corruption in {key!r}")
            if key in flat_s:
                out[key] = jax.device_put(arr, flat_s[key])
            else:
                out[key] = jax.device_put(arr)
        # rebuild the pytree
        flat_paths = jax.tree_util.tree_flatten_with_path(target_tree)
        leaves = []
        for path, _ in flat_paths[0]:
            key = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                           for p in path)
            leaves.append(out[key])
        return jax.tree_util.tree_unflatten(flat_paths[1], leaves)

"""Deterministic synthetic token pipeline: sharded, seekable, prefetched.

Deterministic seekability (batch i is a pure function of (seed, i)) is what
makes checkpoint-resume exact: after restart, training continues from step
N with the same data stream it would have seen — no data-loader state to
persist.  A background thread keeps a small prefetch queue full so host-side
batch construction overlaps device compute.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch: int = 8
    seq_len: int = 128
    vocab_size: int = 50257
    # synthetic structure: repeated n-grams make loss visibly learnable
    ngram: int = 8


def make_batch(cfg: DataConfig, index: int, model_cfg: Optional[ModelConfig] = None):
    """Batch `index` of the stream — pure function of (seed, index)."""
    rng = np.random.default_rng((cfg.seed << 32) ^ index)
    base = rng.integers(0, cfg.vocab_size,
                        (cfg.batch, cfg.seq_len // cfg.ngram + 2, 1))
    tokens = (base + np.arange(cfg.ngram)[None, None, :]) % cfg.vocab_size
    tokens = tokens.reshape(cfg.batch, -1)[:, :cfg.seq_len + 1].astype(np.int32)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if model_cfg is not None and model_cfg.n_encoder_layers:
        rng2 = np.random.default_rng((cfg.seed << 32) ^ index ^ 0xE5C0DE)
        batch["encoder_tokens"] = rng2.standard_normal(
            (cfg.batch, model_cfg.n_frontend_tokens, model_cfg.d_model),
            dtype=np.float32)
    if model_cfg is not None and model_cfg.frontend == "vision_patches":
        rng2 = np.random.default_rng((cfg.seed << 32) ^ index ^ 0x1A6E)
        batch["frontend_embeds"] = rng2.standard_normal(
            (cfg.batch, model_cfg.n_frontend_tokens, model_cfg.d_model),
            dtype=np.float32)
    return batch


class Pipeline:
    """Prefetching iterator starting at an arbitrary step (resume support)."""

    def __init__(self, cfg: DataConfig, model_cfg: Optional[ModelConfig] = None,
                 start_step: int = 0, prefetch: int = 2):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        i = self.step
        while not self._stop.is_set():
            b = make_batch(self.cfg, i, self.model_cfg)
            while not self._stop.is_set():
                try:
                    self._q.put((i, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            i += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        i, b = self._q.get()
        self.step = i + 1
        return b

    def close(self):
        self._stop.set()

"""Unified model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM stacks.

The layer stack is a ``lax.scan`` over *superblocks* — one period of
``cfg.block_pattern`` per step — which keeps HLO size O(1) in depth (critical
for 512-device AOT compiles).  Heterogeneous stacks (gemma2 local/global
alternation, jamba 1:7 mamba:attn, vision cross-attn interleave) unroll their
pattern *within* the superblock body.

Params are plain nested dicts; block params are stacked along a leading
superblock axis (via vmapped init) so the scan can slice one step at a time.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.layers import attention as attn
from repro.layers import mamba as mamba_l
from repro.layers import rwkv as rwkv_l
from repro.layers.common import (
    dense_init, embed_tokens, mlp_fwd, mlp_init, rmsnorm, rmsnorm_init,
    split_keys, unembed,
)
from repro.layers.moe import MeshContext, moe_fwd, moe_init

Shard = Callable[[str, jax.Array], jax.Array]
_id_shard: Shard = lambda name, x: x


# =========================================================== initialization
def _slot_init(key, cfg: ModelConfig, kind: str, slot: int, decoder: bool):
    ks = split_keys(key, 4)
    p = {"norm1": rmsnorm_init(cfg.d_model, cfg.pdtype)}
    if kind in ("attn", "attn_local"):
        p["mixer"] = attn.attention_init(ks[0], cfg)
    elif kind == "xattn":
        p["mixer"] = attn.attention_init(ks[0], cfg, cross=True)
        p["xgate"] = jnp.zeros((), jnp.float32)
    elif kind == "mamba":
        p["mixer"] = mamba_l.mamba_init(ks[0], cfg)
    elif kind == "rwkv6":
        p["mixer"] = rwkv_l.rwkv_time_init(ks[0], cfg)
    else:
        raise ValueError(kind)

    if decoder and cfg.n_encoder_layers and kind != "xattn":
        # enc-dec decoder: every block also cross-attends to the encoder
        p["norm_x"] = rmsnorm_init(cfg.d_model, cfg.pdtype)
        p["xattn"] = attn.attention_init(ks[2], cfg, cross=True)

    p["norm2"] = rmsnorm_init(cfg.d_model, cfg.pdtype)
    if kind == "rwkv6":
        p["mlp"] = rwkv_l.rwkv_channel_init(ks[1], cfg)
    elif slot in cfg.moe_slots and cfg.moe is not None:
        p["moe"] = moe_init(ks[1], cfg)
    else:
        p["mlp"] = mlp_init(ks[1], cfg)
    return p


def _superblock_init(key, cfg: ModelConfig, decoder: bool = True):
    ks = split_keys(key, len(cfg.block_pattern))
    return {f"slot{i}": _slot_init(ks[i], cfg, kind, i, decoder)
            for i, kind in enumerate(cfg.block_pattern)}


def _stack_init(key, cfg: ModelConfig, n: int, decoder: bool = True):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _superblock_init(k, cfg, decoder))(keys)


def init_params(key, cfg: ModelConfig):
    ks = split_keys(key, 5)
    p = {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), cfg.pdtype),
        "blocks": _stack_init(ks[1], cfg, cfg.n_superblocks),
        "final_norm": rmsnorm_init(cfg.d_model, cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], (cfg.d_model, cfg.vocab_size), cfg.pdtype)
    if cfg.n_encoder_layers:
        enc_cfg = cfg.replace(block_pattern=("attn",), moe_slots=())
        n_enc = cfg.n_encoder_layers
        p["enc_blocks"] = _stack_init(ks[3], enc_cfg, n_enc, decoder=False)
        p["enc_norm"] = rmsnorm_init(cfg.d_model, cfg.pdtype)
    return p


# =========================================================== cache
def _slot_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                src_len: int, dtype, decoder: bool):
    c = {}
    if kind in ("attn", "attn_local"):
        c["self"] = attn.make_self_cache(cfg, batch, max_len, dtype)
    elif kind == "xattn":
        c["cross"] = attn.make_self_cache(cfg, batch, src_len, dtype)
    elif kind == "mamba":
        d_inner = cfg.mamba.expand * cfg.d_model
        c["mamba"] = {
            "conv": jnp.zeros((batch, cfg.mamba.d_conv - 1, d_inner), dtype),
            "h": jnp.zeros((batch, d_inner, cfg.mamba.d_state), jnp.float32)}
    elif kind == "rwkv6":
        c["rwkv"] = {
            "shift": jnp.zeros((batch, cfg.d_model), dtype),
            "s": jnp.zeros((batch, cfg.n_heads, cfg.hd, cfg.hd), jnp.float32),
            "shift_c": jnp.zeros((batch, cfg.d_model), dtype)}
    if decoder and cfg.n_encoder_layers and kind != "xattn":
        c["cross"] = attn.make_self_cache(cfg, batch, src_len, dtype)
    return c


def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               src_len: int = 0, dtype=None):
    """Stacked (over superblocks) decode cache pytree."""
    dtype = dtype or cfg.cdtype
    per_sb = {f"slot{i}": _slot_cache(cfg, kind, batch, max_len, src_len,
                                      dtype, decoder=True)
              for i, kind in enumerate(cfg.block_pattern)}
    n = cfg.n_superblocks
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape),
                        per_sb)


def make_paged_cache(cfg: ModelConfig, num_pages: int, block_size: int,
                     dtype=None, kv_dtype: str = "bf16"):
    """Stacked (over superblocks) PAGED decode cache: per attention slot a
    pool of ``num_pages`` fixed-size token pages shared across batch rows
    through block tables (``forward(..., block_tables=...)``).  Only
    pure-attention stacks page — recurrent state (mamba/rwkv) is O(1) per
    slot and has nothing to page.

    ``kv_dtype="int8"`` swaps each slot's pages for the quantized layout
    (int8 payload + per-(token, head) f32 scale pages); ``forward``
    dispatches on the ``k_scale`` leaf, so callers thread the pytree
    through unchanged."""
    dtype = dtype or cfg.cdtype
    unsupported = [k for k in cfg.block_pattern
                   if k not in ("attn", "attn_local")]
    if unsupported:
        raise ValueError(
            f"paged KV cache supports pure-attention stacks only; "
            f"{cfg.name} has block kinds {unsupported}")
    if cfg.n_encoder_layers:
        raise ValueError("paged KV cache does not support enc-dec models")
    per_sb = {f"slot{i}": {"self": attn.make_paged_self_cache(
                  cfg, num_pages, block_size, dtype,
                  quantized=(kv_dtype == "int8"))}
              for i, kind in enumerate(cfg.block_pattern)}
    n = cfg.n_superblocks
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape),
                        per_sb)


# =========================================================== forward
# jax.named_scope tag per mixer kind: these names land in each traced
# eqn's source_info.name_stack, which core.tracing copies onto
# Kernel.operator — the provenance the telemetry attribution layer keys on
_MIXER_SCOPE = {"attn": "attn", "attn_local": "attn", "xattn": "xattn",
                "mamba": "mamba", "rwkv6": "rwkv"}


def _apply_slot(bp, x, cfg: ModelConfig, kind: str, slot: int, *,
                positions, causal, cache, cache_index, encoder_out,
                dist, shd, aux, lengths=None, block_tables=None,
                reduce=None):
    with jax.named_scope("norm1"):
        h = rmsnorm(x, bp["norm1"]["scale"], cfg.norm_eps)
    new_cache = dict(cache) if cache is not None else None

    with jax.named_scope(_MIXER_SCOPE.get(kind, kind)):
        if kind in ("attn", "attn_local"):
            window = cfg.sliding_window if kind == "attn_local" else 0
            o, nc = attn.attention_fwd(
                bp["mixer"], h, cfg, positions=positions, causal=causal,
                window=window,
                cache=None if cache is None else cache.get("self"),
                cache_index=cache_index, lengths=lengths,
                block_tables=block_tables,
                shd=None if shd is _id_shard else shd, reduce=reduce)
            if nc is not None:
                new_cache["self"] = nc
        elif kind == "xattn":
            o, nc = attn.attention_fwd(
                bp["mixer"], h, cfg, positions=positions, is_cross=True,
                cross_kv=encoder_out,
                cache=None if cache is None else cache.get("cross"),
                cache_index=cache_index)
            if nc is not None:
                new_cache["cross"] = nc
            o = o * jnp.tanh(bp["xgate"]).astype(o.dtype)
        elif kind == "mamba":
            o, nc = mamba_l.mamba_fwd(
                bp["mixer"], h, cfg,
                state=None if cache is None else cache.get("mamba"))
            if cache is not None:
                new_cache["mamba"] = nc
        elif kind == "rwkv6":
            st = None if cache is None else \
                {"shift": cache["rwkv"]["shift"], "s": cache["rwkv"]["s"]}
            o, nst = rwkv_l.rwkv_time_fwd(bp["mixer"], h, cfg, state=st,
                                          shd=shd)
            if cache is not None:
                new_cache["rwkv"] = dict(cache["rwkv"], **nst)
        else:
            raise ValueError(kind)
    with jax.named_scope("resid"):
        x = x + shd("resid", checkpoint_name(o, "block_out"))

    # enc-dec cross attention (seamless decoder)
    if "xattn" in bp and kind != "xattn":
        with jax.named_scope("xattn"):
            h = rmsnorm(x, bp["norm_x"]["scale"], cfg.norm_eps)
            o, nc = attn.attention_fwd(
                bp["xattn"], h, cfg, positions=positions, is_cross=True,
                cross_kv=encoder_out,
                cache=None if cache is None else cache.get("cross"),
                cache_index=cache_index)
            if nc is not None:
                new_cache["cross"] = nc
            x = x + shd("resid", o)

    with jax.named_scope("norm2"):
        h = rmsnorm(x, bp["norm2"]["scale"], cfg.norm_eps)
    if kind == "rwkv6":
        with jax.named_scope("rwkv_channel"):
            st = None if cache is None else {"shift": cache["rwkv"]["shift_c"]}
            o, nst = rwkv_l.rwkv_channel_fwd(bp["mlp"], h, cfg, state=st)
            if cache is not None:
                new_cache["rwkv"]["shift_c"] = nst["shift"]
    elif "moe" in bp:
        with jax.named_scope("moe"):
            o, a = moe_fwd(bp["moe"], h, cfg, dist=dist)
            o = checkpoint_name(o, "block_out")
            aux = aux + a
    else:
        with jax.named_scope("mlp"):
            o = mlp_fwd(bp["mlp"], h, cfg, reduce=reduce)
    with jax.named_scope("resid"):
        x = x + shd("resid", o)
    return x, new_cache, aux


REMAT_POLICIES = {
    "nothing": None,   # jax.checkpoint default: save nothing, recompute all
    "dots": "dots_saveable",
    "dots_no_batch": "dots_with_no_batch_dims_saveable",
    # "names": save tagged block outputs — backward skips re-running the
    # mixers/MoE (and their FSDP weight gathers / dispatch all_to_alls) at
    # the cost of one extra (B,S,D) per sub-block
    "names": "names",
}


def _run_stack(blocks, x, cfg: ModelConfig, pattern, *, positions, causal,
               cache, cache_index, encoder_out, dist, shd, remat: bool,
               remat_policy: str = "nothing", unroll: bool = False,
               lengths=None, block_tables=None, reduce=None):
    def body(carry, xs):
        x, aux = carry
        bp, cache_sb = xs
        new_cache_sb = {}
        for i, kind in enumerate(pattern):
            sl = f"slot{i}"
            with jax.named_scope(sl):
                x, nc, aux = _apply_slot(
                    bp[sl], x, cfg, kind, i, positions=positions,
                    causal=causal,
                    cache=None if cache_sb is None else cache_sb[sl],
                    cache_index=cache_index, encoder_out=encoder_out,
                    dist=dist, shd=shd, aux=aux, lengths=lengths,
                    block_tables=block_tables, reduce=reduce)
            new_cache_sb[sl] = nc if nc is not None else {}
        return (shd("resid", x), aux), new_cache_sb

    if remat:
        pol = REMAT_POLICIES.get(remat_policy, None)
        if pol == "names":
            kw = {"policy": jax.checkpoint_policies.save_only_these_names(
                "block_out")}
        elif pol:
            kw = {"policy": getattr(jax.checkpoint_policies, pol)}
        else:
            kw = {}
        body = jax.checkpoint(body, **kw)
    if unroll:
        # python loop: per-layer kernel streams stay visible to the SKIP
        # profiler (and to XLA's scheduler for overlap experiments)
        n = jax.tree.leaves(blocks)[0].shape[0]
        carry = (x, jnp.zeros((), jnp.float32))
        caches = []
        for i in range(n):
            with jax.named_scope(f"layer{i}"):
                xs = jax.tree.map(lambda a: a[i], (blocks, cache))
                carry, nc = body(carry, xs)
            caches.append(nc)
        new_cache = jax.tree.map(lambda *cs: jnp.stack(cs), *caches) \
            if caches and jax.tree.leaves(caches[0]) else caches[0]
        (x, aux) = carry
        return x, aux, new_cache
    (x, aux), new_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (blocks, cache))
    return x, aux, new_cache


def forward(params, tokens, cfg: ModelConfig, *,
            positions: Optional[jax.Array] = None,
            cache=None, cache_index=None,
            encoder_tokens=None,          # enc-dec: (B,S_enc,D) frame embeds
            frontend_embeds=None,         # vlm: (B,T_img,D) patch embeds
            dist: Optional[MeshContext] = None,
            shd: Shard = _id_shard,
            remat: bool = False,
            remat_policy: str = "nothing",
            return_hidden: bool = False,
            unroll: bool = False,
            lengths: Optional[jax.Array] = None,
            block_tables: Optional[jax.Array] = None,
            reduce=None):
    """Returns (logits_f32, aux, new_cache) — or final hidden states instead
    of logits when return_hidden (chunked-loss path skips the unembed).
    unroll=True runs the layer stack as a python loop (SKIP profiling).
    lengths: (B,) per-row positions for continuous-batching decode.
    block_tables: (B,NB) page ids when ``cache`` is paged (make_paged_cache);
    shared by every layer — the table redirects where pages live, and the
    same block layout is used across the stack.
    reduce: tensor-parallel output hook ``(name, x) -> x`` applied to the
    partial-sum attention/MLP outputs — psum inside a shard_map body when
    params are Megatron-sharded over a model axis (cfg then carries LOCAL
    head counts); None everywhere else."""
    b, s = tokens.shape
    if cache_index is None:
        cache_index = jnp.zeros((), jnp.int32)
    if positions is None:
        if lengths is not None:
            positions = (lengths[:, None].astype(jnp.int32)
                         + jnp.arange(s, dtype=jnp.int32))
        else:
            positions = cache_index + jnp.arange(s, dtype=jnp.int32)
            positions = jnp.broadcast_to(positions[None], (b, s))
    causal = cfg.family != "encoder"

    encoder_out = None
    if cfg.n_encoder_layers and encoder_tokens is not None:
        enc_cfg = cfg.replace(block_pattern=("attn",), moe_slots=())
        enc_x = shd("act", encoder_tokens.astype(cfg.cdtype))
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc_x.shape[1], dtype=jnp.int32)[None],
            enc_x.shape[:2])
        enc_x, _, _ = _run_stack(
            params["enc_blocks"], enc_x, enc_cfg, ("attn",),
            positions=enc_pos, causal=False, cache=None, cache_index=None,
            encoder_out=None, dist=dist, shd=shd, remat=remat,
            remat_policy=remat_policy, unroll=unroll)
        encoder_out = rmsnorm(enc_x, params["enc_norm"]["scale"], cfg.norm_eps)
    elif frontend_embeds is not None:
        encoder_out = frontend_embeds.astype(cfg.cdtype)

    with jax.named_scope("embed"):
        x = embed_tokens(params["embed"], tokens, cfg).astype(cfg.cdtype)
        x = shd("act", x)
    x, aux, new_cache = _run_stack(
        params["blocks"], x, cfg, cfg.block_pattern,
        positions=positions, causal=causal, cache=cache,
        cache_index=cache_index, encoder_out=encoder_out,
        dist=dist, shd=shd, remat=remat, remat_policy=remat_policy,
        unroll=unroll, lengths=lengths, block_tables=block_tables,
        reduce=reduce)
    with jax.named_scope("final_norm"):
        x = rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if return_hidden:
        return x, aux, (new_cache if cache is not None else None)
    with jax.named_scope("unembed"):
        logits = unembed(x, params["embed"], params.get("lm_head"), cfg)
        logits = shd("logits", logits)
    return logits, aux, (new_cache if cache is not None else None)


# =========================================================== loss
def loss_fn(params, batch, cfg: ModelConfig, *, dist=None, shd=_id_shard,
            remat: bool = True, remat_policy: str = "nothing",
            aux_weight: float = 0.01, loss_chunks: int = 1):
    """Next-token CE.  batch: {"tokens","labels", optional encoder inputs}.

    loss_chunks > 1 computes the CE over sequence chunks inside a scan so the
    full (B,S,V) logits tensor is never materialized (vocab-heavy archs).
    """
    labels = batch["labels"]
    if loss_chunks > 1:
        hidden, aux, _ = forward(
            params, batch["tokens"], cfg,
            encoder_tokens=batch.get("encoder_tokens"),
            frontend_embeds=batch.get("frontend_embeds"),
            dist=dist, shd=shd, remat=remat, remat_policy=remat_policy,
            return_hidden=True)
        ce = _chunked_ce(hidden, labels, params, cfg, loss_chunks, shd)
    else:
        logits, aux, _ = forward(
            params, batch["tokens"], cfg,
            encoder_tokens=batch.get("encoder_tokens"),
            frontend_embeds=batch.get("frontend_embeds"),
            dist=dist, shd=shd, remat=remat, remat_policy=remat_policy)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        ce = jnp.mean(logz - gold)
    return ce + aux_weight * aux, (ce, aux)


def _chunked_ce(hidden, labels, params, cfg: ModelConfig, n_chunks: int, shd):
    """CE over sequence chunks: the (B,S,V) logits tensor never materializes;
    jax.checkpoint recomputes each chunk's logits in backward."""
    b, s, d = hidden.shape
    assert s % n_chunks == 0, (s, n_chunks)
    sc = s // n_chunks
    xs = hidden.reshape(b, n_chunks, sc, d).swapaxes(0, 1)
    ls = labels.reshape(b, n_chunks, sc).swapaxes(0, 1)

    def chunk(carry, xl):
        xc, lc = xl
        logits = unembed(xc, params["embed"], params.get("lm_head"), cfg)
        logits = shd("logits", logits)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(jax.checkpoint(chunk), jnp.zeros((), jnp.float32),
                            (xs, ls))
    return total / (b * s)


# =========================================================== stats
def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def active_param_count(params, cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top_k of n_experts), excl. embeddings."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if "embed" in keys or "lm_head" in keys:
            continue
        n = leaf.size
        if cfg.moe and any(k in ("w_in", "w_gate", "w_out") for k in keys) \
                and "moe" in keys:
            n = n * cfg.moe.top_k // cfg.moe.n_experts
        total += n
    return total

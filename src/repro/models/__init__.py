from repro.models.transformer import (  # noqa: F401
    init_params, forward, make_cache, make_paged_cache, loss_fn, param_count,
    active_param_count,
)

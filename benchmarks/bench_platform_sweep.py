"""Figs. 10/11 reproduction: prefill latency (TTFT), GPU idle and CPU idle
vs batch size for all four paper workloads on the three platforms —
crossover points (CP) between LC and CC included."""
from __future__ import annotations

from benchmarks.common import build_skip, csv_row
from repro.configs import PAPER_WORKLOADS

BATCHES = (1, 2, 4, 8, 16, 32, 64, 128, 256)
PLATS = ("Intel+H100", "AMD+A100", "GH200")


def run() -> list[str]:
    rows = []
    for model in PAPER_WORKLOADS:
        skip = build_skip(model)
        per_plat = {}
        for plat in PLATS:
            reps = [skip.report(plat, b, use_host_scale=False) for b in BATCHES]
            per_plat[plat] = reps
            curve = ";".join(f"b{b}={r.il*1e6:.0f}us"
                             for b, r in zip(BATCHES, reps))
            rows.append(csv_row(f"platform_ttft/{model}/{plat}",
                                reps[0].il * 1e6, curve))
            idle = ";".join(
                f"b{b}=g{r.gpu_idle*1e6:.0f}/c{r.cpu_idle*1e6:.0f}"
                for b, r in zip(BATCHES, reps))
            rows.append(csv_row(f"platform_idle/{model}/{plat}",
                                reps[0].gpu_idle * 1e6, idle))
        # crossover: first batch where GH200 TTFT beats the best LC
        cp = None
        for i, b in enumerate(BATCHES):
            lc = min(per_plat["Intel+H100"][i].il, per_plat["AMD+A100"][i].il)
            if per_plat["GH200"][i].il < lc:
                cp = b
                break
        b0 = 0
        speedup64 = min(per_plat["Intel+H100"][-1].il,
                        per_plat["AMD+A100"][-1].il) / \
            per_plat["GH200"][-1].il
        low_batch_penalty = per_plat["GH200"][b0].il / \
            per_plat["Intel+H100"][b0].il
        rows.append(csv_row(
            f"platform_ttft/{model}/crossover", 0.0,
            f"cp_batch={cp};gh200_speedup_b64={speedup64:.2f};"
            f"gh200_lowbatch_penalty_b1={low_batch_penalty:.2f}"))
    return rows

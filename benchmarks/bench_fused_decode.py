"""Fused decode path: measured host dispatch of the rule-substituted
fused plan against eager / chain / auto on the decode-step trace — the
speedup trajectory of the paper's kernel-fusion claim at batch=1 (the
CPU-bound region).  Reports per-plan launch counts, measured host
dispatch totals, modeled TKLQT, and the fused-rule match census."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import FAST, csv_row
from repro.configs import get_config, reduced
from repro.core.tracing import trace_fn
from repro.models import forward, init_params, make_cache
from repro.runtime import LaunchPlan, PlanExecutor, Planner, find_matches

ARCH = "smollm-360m"
REPEATS = 2 if FAST else 3
MAX_LEN = 64
PLATFORM = "TPU-v5e"


def _decode_trace(cfg, params):
    cache = make_cache(cfg, 1, MAX_LEN, src_len=1, dtype=cfg.cdtype)
    toks = jnp.zeros((1, 1), jnp.int32)
    lengths = jnp.ones((1,), jnp.int32)

    def decode_body(params, cache, tokens, lengths):
        logits, _, cache2 = forward(params, tokens, cfg, cache=cache,
                                    lengths=lengths, unroll=True)
        return logits[:, 0], cache2

    return trace_fn(decode_body, params, cache, toks, lengths), (
        params, cache, toks, lengths)


def run() -> list[str]:
    cfg = reduced(get_config(ARCH), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    trace, args = _decode_trace(cfg, params)
    planner = Planner(trace, PLATFORM)

    matches = find_matches(trace)
    rows = [csv_row(
        "fused_decode/matches", 0.0,
        f"n={len(matches)};"
        + ";".join(f"{m.rule_name}@{m.start}" for m in matches))]

    n = len(trace.kernels)
    plans = [
        ("eager", LaunchPlan.eager(n)),
        ("chain", planner.chain(8)),
        ("auto", planner.auto().plan),
        ("fused", planner.fused_rules()),
    ]
    eager_host = None
    for name, plan in plans:
        ex = PlanExecutor(trace, plan)
        host = sum(ex.measure_host(*args, repeats=REPEATS))
        if name == "eager":
            eager_host = host
        tklqt = planner.evaluate(plan).tklqt
        speedup = eager_host / host if host > 0 else float("inf")
        rows.append(csv_row(
            f"fused_decode/{name}", host * 1e6,
            f"launches={plan.n_launches};fused={plan.n_fused_rules};"
            f"speedup_vs_eager={speedup:.2f};"
            f"modeled_tklqt_us={tklqt * 1e6:.1f}"))
    return rows

"""Fig. 7 reproduction: fusion-candidate statistics vs chain length —
unique candidates, total instances, deterministic (PS=1) fused chains, and
K_eager, for the two CPU-bound workloads (GPT2, XLM-R)."""
from __future__ import annotations

from benchmarks.common import build_skip, csv_row

LENGTHS = (2, 4, 8, 16, 32, 64, 128, 256)
MODELS = ("gpt2", "xlm-roberta-base")


def run() -> list[str]:
    rows = []
    for model in MODELS:
        skip = build_skip(model)
        for res in skip.recommend_sweep(LENGTHS):
            rows.append(csv_row(
                f"chain_candidates/{model}/L{res.length}", 0.0,
                f"unique={res.n_unique};instances={res.n_instances};"
                f"fused={res.c_fused};k_eager={res.k_eager}"))
    return rows

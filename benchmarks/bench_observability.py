"""Telemetry overhead gate: serve the same closed workload with the
observability stack off (no monitor, no span recorder) and fully on
(monitor + capped SpanRecorder + registry-backed stats), and gate the
enabled decode-step median at <5% over disabled — the registry sits on
the decode hot path, so its cost budget is part of the contract, not an
aspiration.  A third row prices the registry write path directly
(counter inc + gauge set + histogram observe per iteration)."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import FAST, csv_row
from repro.configs import get_config, reduced
from repro.inference.engine import Request, ServeEngine
from repro.models import init_params
from repro.telemetry.registry import MetricsRegistry
from repro.telemetry.spans import SpanRecorder

ARCH = "smollm-360m"
MAX_LEN = 64
ROUNDS = 3 if FAST else 5
OVERHEAD_GATE = 1.05          # enabled median <= 1.05x disabled median


def _requests(cfg, n=4, max_new=8):
    rng = np.random.default_rng(0)
    return [Request(i, prompt=list(rng.integers(0, cfg.vocab_size, 12)),
                    max_new_tokens=max_new) for i in range(n)]


def _engine(cfg, params, *, telemetry_on: bool) -> ServeEngine:
    kw = (dict(monitor=True, telemetry=SpanRecorder(max_spans=4096))
          if telemetry_on else dict(monitor=False, telemetry=None))
    eng = ServeEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                      plan="eager", **kw)
    eng.run(_requests(cfg))            # warmup: pay tracing/jit once
    return eng


def _median(xs: list) -> float:
    xs = sorted(xs)
    return xs[len(xs) // 2] if xs else 0.0


def _measure_pair(cfg, params) -> tuple:
    """Median decode-step time (disabled, enabled), with the rounds of
    the two engines INTERLEAVED so background load drift hits both
    measurement pools equally instead of biasing one side."""
    eng_off = _engine(cfg, params, telemetry_on=False)
    eng_on = _engine(cfg, params, telemetry_on=True)
    off_steps, on_steps = [], []
    for _ in range(ROUNDS):
        for eng, pool in ((eng_off, off_steps), (eng_on, on_steps)):
            eng.reset()
            eng.run(_requests(cfg))
            pool.extend(eng.stats.step_times_s)
    return _median(off_steps), _median(on_steps)


def run() -> list[str]:
    rows = []
    cfg = reduced(get_config(ARCH), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)

    t_off, t_on = _measure_pair(cfg, params)
    ratio = t_on / t_off if t_off > 0 else 0.0
    if ratio > OVERHEAD_GATE:
        # one noise retry before declaring a regression: ms-scale CPU
        # step times jitter by a few percent run to run
        t_off, t_on = _measure_pair(cfg, params)
        ratio = t_on / t_off if t_off > 0 else 0.0
    verdict = "ok" if ratio <= OVERHEAD_GATE else "OVER_BUDGET"
    rows.append(csv_row("observability/decode_step_disabled", t_off * 1e6,
                        "monitor=off;spans=off"))
    rows.append(csv_row("observability/decode_step_enabled", t_on * 1e6,
                        f"monitor=on;spans=on;overhead={ratio:.3f}x;"
                        f"gate={OVERHEAD_GATE}x;{verdict}"))
    if ratio > OVERHEAD_GATE:
        raise RuntimeError(
            f"telemetry overhead {ratio:.3f}x exceeds the "
            f"{OVERHEAD_GATE}x decode-step budget "
            f"(enabled {t_on * 1e6:.1f}us vs disabled {t_off * 1e6:.1f}us)")

    # registry write path in isolation: one counter inc + gauge set +
    # histogram observe, the exact per-step instrument mix
    reg = MetricsRegistry()
    c = reg.counter("bench_total")
    g = reg.gauge("bench_gauge")
    h = reg.histogram("bench_seconds")
    n = 20_000 if FAST else 100_000
    t0 = time.perf_counter()
    for i in range(n):
        c.inc()
        g.set(float(i))
        h.observe(1e-4)
    dt = time.perf_counter() - t0
    rows.append(csv_row("observability/registry_write_triplet",
                        dt / n * 1e6, f"iters={n}"))
    return rows

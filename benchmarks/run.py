"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only nullkernel,tklqt_sweep]

Prints ``name,us_per_call,derived`` CSV rows.  BENCH_FAST=1 trims depth.
With ``--json-dir DIR`` (or ``BENCH_JSON=DIR``) each benchmark also writes
a machine-readable ``BENCH_<name>.json`` artifact — rows, wall time,
status — for CI perf-trajectory tracking.
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time
import traceback

BENCHES = [
    ("nullkernel", "benchmarks.bench_nullkernel"),        # Table V
    ("exec_modes", "benchmarks.bench_exec_modes"),        # Table I
    ("fusion_ttft", "benchmarks.bench_fusion_ttft"),      # Fig 3
    ("tklqt_sweep", "benchmarks.bench_tklqt_sweep"),      # Fig 6
    ("chain_candidates", "benchmarks.bench_chain_candidates"),  # Fig 7
    ("ideal_speedup", "benchmarks.bench_ideal_speedup"),  # Fig 8
    ("ps_vs_graph", "benchmarks.bench_ps_vs_graph"),      # Fig 9
    ("platform_sweep", "benchmarks.bench_platform_sweep"),  # Figs 10/11
    ("roofline", "benchmarks.bench_roofline"),            # beyond paper
    ("characterize", "benchmarks.bench_characterize"),    # measured serving
]


def _parse_row(row: str) -> dict:
    name, us, derived = (row.split(",", 2) + ["", ""])[:3]
    try:
        us_f = float(us)
    except ValueError:
        us_f = None
    return {"name": name, "us_per_call": us_f, "derived": derived}


def _write_artifact(json_dir: str, name: str, payload: dict) -> None:
    # artifacts are best-effort telemetry: a write failure must neither
    # abort the remaining benchmarks nor relabel a passing one as failed
    path = os.path.join(json_dir, f"BENCH_{name}.json")
    try:
        os.makedirs(json_dir, exist_ok=True)
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=2)
    except OSError as e:
        print(f"# artifact write failed for {path}: {e!r}", flush=True)
        return
    print(f"# wrote {path}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--json-dir", default=os.environ.get("BENCH_JSON"),
                    help="write BENCH_<name>.json artifacts here "
                         "(default: $BENCH_JSON, off when unset)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = []
    for name, module in BENCHES:
        if only and name not in only:
            continue
        t0 = time.time()
        rows: list[str] = []
        try:
            mod = importlib.import_module(module)
            for row in mod.run():
                rows.append(row)
                print(row, flush=True)
            elapsed = time.time() - t0
            print(f"# {name} done in {elapsed:.0f}s", flush=True)
            if args.json_dir:
                _write_artifact(args.json_dir, name, {
                    "name": name, "status": "ok",
                    "elapsed_s": round(elapsed, 2),
                    "fast_mode": bool(int(os.environ.get("BENCH_FAST", "0"))),
                    "rows": [_parse_row(r) for r in rows],
                })
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((name, repr(e)))
            print(f"# {name} FAILED: {e!r}", flush=True)
            if args.json_dir:
                _write_artifact(args.json_dir, name, {
                    "name": name, "status": "failed", "error": repr(e),
                    "elapsed_s": round(time.time() - t0, 2),
                    "rows": [_parse_row(r) for r in rows],
                })
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

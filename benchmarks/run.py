"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only nullkernel,tklqt_sweep]

Prints ``name,us_per_call,derived`` CSV rows.  BENCH_FAST=1 trims depth.
With ``--json-dir DIR`` (or ``BENCH_JSON=DIR``) each benchmark also writes
a machine-readable ``BENCH_<name>.json`` artifact — rows, wall time,
status — for CI perf-trajectory tracking.

``--check-baseline [benchmarks/baselines.json]`` turns the artifacts into
a regression gate: per bench, the median positive ``us_per_call`` must
stay within ``tolerance x`` of the committed baseline median, or the run
exits nonzero.  ``--update-baseline`` rewrites the baseline file from the
current artifacts (commit the result deliberately).
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time
import traceback

BENCHES = [
    ("nullkernel", "benchmarks.bench_nullkernel"),        # Table V
    ("exec_modes", "benchmarks.bench_exec_modes"),        # Table I
    ("fusion_ttft", "benchmarks.bench_fusion_ttft"),      # Fig 3
    ("tklqt_sweep", "benchmarks.bench_tklqt_sweep"),      # Fig 6
    ("chain_candidates", "benchmarks.bench_chain_candidates"),  # Fig 7
    ("ideal_speedup", "benchmarks.bench_ideal_speedup"),  # Fig 8
    ("ps_vs_graph", "benchmarks.bench_ps_vs_graph"),      # Fig 9
    ("platform_sweep", "benchmarks.bench_platform_sweep"),  # Figs 10/11
    ("roofline", "benchmarks.bench_roofline"),            # beyond paper
    ("characterize", "benchmarks.bench_characterize"),    # measured serving
    ("fused_decode", "benchmarks.bench_fused_decode"),    # fusion rules
    ("paged_decode", "benchmarks.bench_paged_decode"),    # paged KV cache
    ("sharded_decode", "benchmarks.bench_sharded_decode"),  # tensor parallel
    ("speculative_decode", "benchmarks.bench_speculative_decode"),
    ("observability", "benchmarks.bench_observability"),  # telemetry gate
    ("router", "benchmarks.bench_router"),                # replica fleet
    ("tracing", "benchmarks.bench_tracing"),              # request tracing
]

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baselines.json")


def _parse_row(row: str) -> dict:
    name, us, derived = (row.split(",", 2) + ["", ""])[:3]
    try:
        us_f = float(us)
    except ValueError:
        us_f = None
    return {"name": name, "us_per_call": us_f, "derived": derived}


def _json_sanitize(obj):
    """Strict-JSON payloads: inf/nan floats (e.g. a measured_speedup of
    inf from a 0-cost fused run) become their string names instead of the
    invalid bare ``Infinity``/``NaN`` tokens ``json.dump`` would emit.
    Delegates to ``repro.core.fusion.json_sanitize`` so every export path
    (bench artifacts, serve CLI reports) shares one representation."""
    from repro.core.fusion import json_sanitize
    return json_sanitize(obj)


def _write_artifact(json_dir: str, name: str, payload: dict) -> None:
    # artifacts are best-effort telemetry: a write failure must neither
    # abort the remaining benchmarks nor relabel a passing one as failed
    path = os.path.join(json_dir, f"BENCH_{name}.json")
    try:
        os.makedirs(json_dir, exist_ok=True)
        with open(path, "w") as fh:
            json.dump(_json_sanitize(payload), fh, indent=2,
                      allow_nan=False)
    except OSError as e:
        print(f"# artifact write failed for {path}: {e!r}", flush=True)
        return
    print(f"# wrote {path}", flush=True)


def _bench_median(payload: dict):
    """Median of the positive us_per_call rows of one artifact (None when
    the bench reports no positive timings — derived-only benches)."""
    vals = sorted(r["us_per_call"] for r in payload.get("rows", [])
                  if isinstance(r.get("us_per_call"), (int, float))
                  and r["us_per_call"] > 0.0)
    if not vals:
        return None
    mid = len(vals) // 2
    return (vals[mid] if len(vals) % 2
            else 0.5 * (vals[mid - 1] + vals[mid]))


def check_baseline(json_dir: str, baseline_path: str, *,
                   tolerance: float = None, update: bool = False,
                   only=None) -> list:
    """Compare BENCH_*.json medians against the committed baselines.

    Returns a list of violation strings (empty = gate passes).  Only
    benches with BOTH an artifact and a committed positive baseline are
    gated, and ``only`` (the run's bench selection) further restricts the
    gate to what THIS run produced — stale artifacts from earlier runs in
    the same ``--json-dir`` never fail a partial ``--only`` run.
    """
    try:
        with open(baseline_path) as fh:
            base = json.load(fh)
    except FileNotFoundError:
        base = {"tolerance": 4.0, "benches": {}}
    tol = tolerance if tolerance is not None else base.get("tolerance", 4.0)
    violations = []
    for name, _ in BENCHES:
        if only and name not in only:
            continue
        path = os.path.join(json_dir, f"BENCH_{name}.json")
        if not os.path.exists(path):
            continue
        with open(path) as fh:
            payload = json.load(fh)
        med = _bench_median(payload)
        if update:
            if med is not None:
                base["benches"][name] = {"median_us": round(med, 3)}
            continue
        entry = base.get("benches", {}).get(name)
        if entry is None or not entry.get("median_us"):
            print(f"# baseline: {name} has no committed median, skipping",
                  flush=True)
            continue
        if payload.get("status") != "ok":
            violations.append(f"{name}: status={payload.get('status')}")
            continue
        if med is None:
            violations.append(f"{name}: no positive timings to compare")
            continue
        limit = entry["median_us"] * tol
        verdict = "ok" if med <= limit else "REGRESSION"
        print(f"# baseline: {name} median={med:.1f}us "
              f"baseline={entry['median_us']}us x{tol} "
              f"limit={limit:.1f}us {verdict}", flush=True)
        if med > limit:
            violations.append(
                f"{name}: median {med:.1f}us > {limit:.1f}us "
                f"(baseline {entry['median_us']}us x {tol})")
    if update:
        base.setdefault("tolerance", tol)
        with open(baseline_path, "w") as fh:
            json.dump(base, fh, indent=2)
            fh.write("\n")
        print(f"# wrote {baseline_path}", flush=True)
    return violations


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--json-dir", default=os.environ.get("BENCH_JSON"),
                    help="write BENCH_<name>.json artifacts here "
                         "(default: $BENCH_JSON, off when unset)")
    ap.add_argument("--check-baseline", nargs="?", const=DEFAULT_BASELINE,
                    default=None, metavar="PATH",
                    help="fail when any BENCH_*.json median regresses "
                         "past tolerance x its committed baseline")
    ap.add_argument("--baseline-tolerance", type=float, default=None,
                    help="override the tolerance stored in the baseline "
                         "file")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline file from this run's "
                         "artifacts instead of gating")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if (args.check_baseline or args.update_baseline) and not args.json_dir:
        ap.error("--check-baseline/--update-baseline need --json-dir")

    print("name,us_per_call,derived")
    failures = []
    for name, module in BENCHES:
        if only and name not in only:
            continue
        t0 = time.time()
        rows: list[str] = []
        try:
            mod = importlib.import_module(module)
            for row in mod.run():
                rows.append(row)
                print(row, flush=True)
            elapsed = time.time() - t0
            print(f"# {name} done in {elapsed:.0f}s", flush=True)
            if args.json_dir:
                _write_artifact(args.json_dir, name, {
                    "name": name, "status": "ok",
                    "elapsed_s": round(elapsed, 2),
                    "fast_mode": bool(int(os.environ.get("BENCH_FAST", "0"))),
                    "rows": [_parse_row(r) for r in rows],
                })
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((name, repr(e)))
            print(f"# {name} FAILED: {e!r}", flush=True)
            if args.json_dir:
                _write_artifact(args.json_dir, name, {
                    "name": name, "status": "failed", "error": repr(e),
                    "elapsed_s": round(time.time() - t0, 2),
                    "rows": [_parse_row(r) for r in rows],
                })
    if failures:
        sys.exit(1)
    if args.check_baseline or args.update_baseline:
        baseline_path = args.check_baseline or DEFAULT_BASELINE
        violations = check_baseline(args.json_dir, baseline_path,
                                    tolerance=args.baseline_tolerance,
                                    update=args.update_baseline, only=only)
        if violations:
            print("# BASELINE REGRESSIONS:", flush=True)
            for v in violations:
                print(f"#   {v}", flush=True)
            sys.exit(2)


if __name__ == "__main__":
    main()

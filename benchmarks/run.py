"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only nullkernel,tklqt_sweep]

Prints ``name,us_per_call,derived`` CSV rows.  BENCH_FAST=1 trims depth.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

BENCHES = [
    ("nullkernel", "benchmarks.bench_nullkernel"),        # Table V
    ("exec_modes", "benchmarks.bench_exec_modes"),        # Table I
    ("fusion_ttft", "benchmarks.bench_fusion_ttft"),      # Fig 3
    ("tklqt_sweep", "benchmarks.bench_tklqt_sweep"),      # Fig 6
    ("chain_candidates", "benchmarks.bench_chain_candidates"),  # Fig 7
    ("ideal_speedup", "benchmarks.bench_ideal_speedup"),  # Fig 8
    ("ps_vs_graph", "benchmarks.bench_ps_vs_graph"),      # Fig 9
    ("platform_sweep", "benchmarks.bench_platform_sweep"),  # Figs 10/11
    ("roofline", "benchmarks.bench_roofline"),            # beyond paper
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = []
    for name, module in BENCHES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(module)
            for row in mod.run():
                print(row, flush=True)
            print(f"# {name} done in {time.time()-t0:.0f}s", flush=True)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((name, repr(e)))
            print(f"# {name} FAILED: {e!r}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Paged KV decode path: ops-level paged vs contiguous decode-attention
latency (interpret-mode Pallas on CPU), engine-level paged vs contiguous
decode steps, and the measured offload traffic + link-priced tax of a
pool-constrained run — the capacity half of the serving story."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST, csv_row
from repro.configs import get_config, reduced
from repro.core.device_model import PLATFORMS, offload_cost_s
from repro.inference.engine import Request, ServeEngine
from repro.inference.kv_quant import quantize_kv
from repro.kernels.decode_attention.ops import (decode_attention,
                                                paged_decode_attention)
from repro.models import init_params

ARCH = "smollm-360m"
REPEATS = 3 if FAST else 5
MAX_LEN = 64
BLOCK = 8


def _time(fn, repeats=REPEATS):
    jax.block_until_ready(fn())        # compile outside the timed region
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / repeats


def _requests(cfg, n):
    rng = np.random.default_rng(0)
    return [Request(i, prompt=list(rng.integers(0, cfg.vocab_size, 12)),
                    max_new_tokens=8) for i in range(n)]


def _serve(cfg, params, **kw):
    eng = ServeEngine(cfg, params, max_batch=2, max_len=MAX_LEN, **kw)
    eng.run(_requests(cfg, 6))         # warmup: pay jit once
    eng.reset()
    eng.run(_requests(cfg, 6))
    return eng.stats


def run() -> list[str]:
    rows = []
    # ---- ops level: one decode-attention call, contiguous vs block-table
    B, HQ, HKV, hd, bs, nb = 2, 4, 2, 64, 64, 4
    t = bs * nb
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, HQ, hd))
    k = jax.random.normal(ks[1], (B, HKV, t, hd))
    v = jax.random.normal(ks[2], (B, HKV, t, hd))
    # identity page layout: page b*nb+i holds row b's tokens [i*bs,(i+1)*bs)
    kp = k.transpose(0, 2, 1, 3).reshape(B * nb, bs, HKV, hd)
    vp = v.transpose(0, 2, 1, 3).reshape(B * nb, bs, HKV, hd)
    tables = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    lens = jnp.full((B,), t, jnp.int32)
    tc = _time(lambda: decode_attention(q, k, v, t, scale=0.2, block_kv=bs))
    tp = _time(lambda: paged_decode_attention(q, kp, vp, tables, lens,
                                              scale=0.2))
    rows.append(csv_row("paged_decode/ops_contiguous", tc * 1e6,
                        f"B={B};T={t};block_kv={bs}"))
    rows.append(csv_row("paged_decode/ops_paged", tp * 1e6,
                        f"B={B};pages={B * nb};bs={bs};"
                        f"vs_contig={tp / tc:.2f}x"))
    # quantized pool: int8 payloads + per-(token, head) f32 scales,
    # dequantized inside the kernel after each page DMA
    qk, sk = quantize_kv(kp)
    qv, sv = quantize_kv(vp)
    tq = _time(lambda: paged_decode_attention(q, qk, qv, tables, lens,
                                              scale=0.2, k_scale=sk,
                                              v_scale=sv))
    rows.append(csv_row("paged_decode/ops_paged_int8", tq * 1e6,
                        f"B={B};pages={B * nb};bs={bs};"
                        f"vs_paged_bf16={tq / tp:.2f}x"))

    # ---- engine level: decode steps through each cache, same traffic
    cfg = reduced(get_config(ARCH), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    st_c = _serve(cfg, params)
    st_p = _serve(cfg, params, cache="paged", block_size=BLOCK)
    st_q = _serve(cfg, params, cache="paged", block_size=BLOCK,
                  kv_dtype="int8")
    for name, st in (("engine_contiguous", st_c), ("engine_paged", st_p),
                     ("engine_paged_int8", st_q)):
        steps = st.step_times_s
        mean_step = sum(steps) / len(steps) if steps else 0.0
        rows.append(csv_row(
            f"paged_decode/{name}", mean_step * 1e6,
            f"decode_steps={st.decode_steps};tokens={st.tokens_out}"))

    # ---- pool pressure: measured offload traffic, link-priced LC vs CC
    # (same per-block transfer count the engine itself prices with, so
    # these rows agree with serve/characterize for identical traffic)
    st_o = _serve(cfg, params, cache="paged", block_size=4, num_blocks=8,
                  offload="host", prefill_chunk=8)
    for plat in ("Intel+H100", "GH200"):
        spec = PLATFORMS[plat]
        tax = offload_cost_s(spec, st_o.offload_bytes + st_o.restore_bytes,
                             transfers=max(st_o.offload_transfers, 1))
        rows.append(csv_row(
            f"paged_decode/offload_tax_{spec.coupling}", 0.0,
            f"platform={plat};preemptions={st_o.preemptions};"
            f"offload_bytes={st_o.offload_bytes};"
            f"transfers={st_o.offload_transfers};"
            f"modeled_tax_us={tax * 1e6:.1f}"))
    return rows

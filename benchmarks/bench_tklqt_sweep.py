"""Fig. 6 reproduction: TKLQT vs batch size for the encoder workloads on the
three platforms, with the CPU->GPU-bound inflection (star markers)."""
from __future__ import annotations

from benchmarks.common import build_skip, csv_row

BATCHES = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)
MODELS = ("bert-base-uncased", "xlm-roberta-base")
PLATS = ("Intel+H100", "AMD+A100", "GH200")


def run() -> list[str]:
    rows = []
    for model in MODELS:
        skip = build_skip(model)
        for plat in PLATS:
            sweep, reps = skip.batch_sweep(plat, batches=BATCHES, use_host_scale=False)
            curve = ";".join(f"b{b}={t*1e6:.0f}us"
                             for b, t in zip(BATCHES, sweep.tklqt))
            rows.append(csv_row(
                f"tklqt_sweep/{model}/{plat}",
                reps[0].tklqt * 1e6,
                f"inflection_batch={sweep.inflection_batch};{curve}"))
    # the paper's headline: GH200 stays CPU-bound to larger batch than LC
    for model in MODELS:
        skip = build_skip(model)
        inf = {p: skip.batch_sweep(p, batches=BATCHES, use_host_scale=False)[0].inflection_batch
               for p in PLATS}
        ratio = (inf["GH200"] or BATCHES[-1]) / max(
            inf["Intel+H100"] or 1, 1)
        rows.append(csv_row(
            f"tklqt_sweep/{model}/cc_vs_lc_inflection_ratio", 0.0,
            f"gh200_x_larger={ratio:.1f};"
            + ";".join(f"{p}={v}" for p, v in inf.items())))
    return rows

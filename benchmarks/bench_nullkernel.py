"""Table V reproduction: nullKernel launch overhead per platform.

The host column is MEASURED on this machine (the real dispatch cost of a
null JAX op — the quantity the paper isolates with cudaLaunchKernel); the
three GPU platforms report the paper's measured constants, which the
device model uses for simulation.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core.device_model import PLATFORMS


def measure_null_dispatch(repeats: int = 2000) -> float:
    """Median dispatch time of a trivial jitted op (seconds)."""
    f = jax.jit(lambda x: x)
    x = jnp.zeros((8,), jnp.float32)
    f(x).block_until_ready()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        y = f(x)
        times.append(time.perf_counter() - t0)
        y.block_until_ready()
    times.sort()
    return times[len(times) // 2]


def run() -> list[str]:
    rows = []
    host_ns = measure_null_dispatch() * 1e9
    rows.append(csv_row("nullkernel_launch/jax_host_measured", host_ns / 1e3,
                        f"launch_ns={host_ns:.0f}"))
    for name, spec in PLATFORMS.items():
        rows.append(csv_row(
            f"nullkernel_launch/{name}", spec.launch_overhead_ns / 1e3,
            f"launch_ns={spec.launch_overhead_ns:.1f};"
            f"duration_ns={spec.null_duration_ns:.1f};src="
            + ("paper_tableV" if name != "TPU-v5e" else "model")))
    return rows

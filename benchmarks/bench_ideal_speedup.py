"""Fig. 8 reproduction: idealized launch-saving speedup (Eqs. 7-8) vs chain
length for GPT2 and XLM-RoBERTa."""
from __future__ import annotations

from benchmarks.common import build_skip, csv_row

LENGTHS = (2, 4, 8, 16, 32, 64, 128, 256)
MODELS = ("gpt2", "xlm-roberta-base")


def run() -> list[str]:
    rows = []
    for model in MODELS:
        skip = build_skip(model)
        best = 0.0
        for res in skip.recommend_sweep(LENGTHS):
            best = max(best, res.speedup)
            rows.append(csv_row(
                f"ideal_speedup/{model}/L{res.length}", 0.0,
                f"k_eager={res.k_eager};k_fused={res.k_fused};"
                f"speedup={res.speedup:.2f}"))
        rows.append(csv_row(f"ideal_speedup/{model}/best", 0.0,
                            f"speedup={best:.2f}"))
    return rows

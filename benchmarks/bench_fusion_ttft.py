"""Fig. 3 reproduction: TTFT speedup of domain-specific fusion
(FlashAttention-analogue) and graph capture over eager, on the modeled
Intel+H100 platform, for decoder workloads.

The fused-attention variant collapses every attention-chain occurrence into
one kernel (what FlashAttention does to the ATen attention ops); graph mode
collapses everything (torch.compile max-autotune analogue).
"""
from __future__ import annotations

from benchmarks.common import build_skip, csv_row
from repro.core.device_model import PLATFORMS, simulate
from repro.core.metrics import report
from repro.core.proximity import fusion_segments
from repro.core.tracing import Kernel

MODELS = ("gpt2", "llama-3.2-1b")
ATTN_PRIMS = {"dot_general", "reduce_max", "max", "sub", "exp", "reduce_sum",
              "div", "broadcast_in_dim", "stop_gradient"}


def _fused_kernels(kernels, segments):
    """Collapse segments into single pseudo-kernels (sum flops/bytes)."""
    out = []
    for seg in segments:
        ks = [kernels[i] for i in seg]
        out.append(Kernel(
            index=seg[0], name="+".join(k.name for k in ks[:2]) +
            (f"+{len(ks)-2}" if len(ks) > 2 else ""),
            eqn=None, flops=sum(k.flops for k in ks),
            bytes=sum(k.bytes for k in ks),
            out_shapes=(), host_dispatch_s=ks[0].host_dispatch_s))
    return out


def run() -> list[str]:
    plat = PLATFORMS["Intel+H100"]
    rows = []
    for model in MODELS:
        skip = build_skip(model)
        kernels = skip.trace_.kernels
        names = skip.trace_.kernel_names
        n = len(names)

        def ttft(klist, batch=1):
            ev = simulate(klist, plat, batch_scale=batch)
            return report(ev, plat.name, plat.launch_overhead_ns * 1e-9).il

        base = ttft(kernels)
        # flash-analogue: fuse deterministic chains of attention primitives
        segs = fusion_segments(names, 8)
        merged = []
        for s in segs:
            if len(s) > 1 and all(names[j] in ATTN_PRIMS for j in s):
                merged.append(s)
            else:
                merged.extend([[j] for j in s])
        flash = ttft(_fused_kernels(kernels, merged))
        graph = ttft(_fused_kernels(kernels, [list(range(n))]))
        rows.append(csv_row(
            f"fusion_ttft/{model}/eager", base * 1e6, "speedup=1.00"))
        rows.append(csv_row(
            f"fusion_ttft/{model}/flash_analogue", flash * 1e6,
            f"speedup={base / flash:.2f}"))
        rows.append(csv_row(
            f"fusion_ttft/{model}/graph", graph * 1e6,
            f"speedup={base / graph:.2f}"))
    return rows

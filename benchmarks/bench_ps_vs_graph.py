"""Fig. 9 reproduction: proximity-score fusion vs whole-graph capture for
GPT-2 prefill — idealized (Eq. 8) AND measured (chain-jit actually runs),
which the paper leaves as future work."""
from __future__ import annotations

from benchmarks.common import build_skip, csv_row

LENGTHS = (8, 32, 128, 256)


def run() -> list[str]:
    skip = build_skip("gpt2")
    rows = []
    eager_host = None
    for L in LENGTHS:
        out = skip.fuse(length=L, repeats=2)
        if eager_host is None:
            eager_host = out.eager_host_s
        rows.append(csv_row(
            f"ps_vs_graph/gpt2/ps_L{L}", out.fused_host_s * 1e6,
            f"k_fused={out.k_fused};ideal={out.ideal_speedup:.2f};"
            f"measured={out.measured_speedup:.2f};err={out.max_abs_err:.1e}"))
    # graph mode = single segment
    from repro.core.tracing import Executor
    n = len(skip.trace_.kernel_names)
    ex = Executor(skip.trace_, segments=[list(range(n))])
    ts = ex.measure_host(*skip.args, repeats=3)
    graph_host = sum(ts)
    rows.append(csv_row(
        "ps_vs_graph/gpt2/graph", graph_host * 1e6,
        f"k_fused=1;measured={eager_host / graph_host:.2f}"))
    return rows

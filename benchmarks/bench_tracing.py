"""Request-tracing overhead gate + blame-attribution sanity check.

Part one serves the same closed workload with the request tracer off and
on (everything else identical, monitor/spans disabled so only the tracer
is priced) and gates the traced decode-step median at <5% over untraced
— lifecycle stamping rides the decode hot path, so its budget is part of
the tracing contract.  Part two drains the router bench's Poisson
chatbot workload through a single traced replica and asserts the
critical-path analyzer (a) conserves every request's E2E and (b) names a
dominant blame segment for the p99-TTFT tail — the triage headline
("p99 TTFT violators: NN% <segment> at replicas=1") the acceptance
criteria pin.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import FAST, csv_row
from repro.configs import get_config, reduced
from repro.inference.engine import Request, ServeEngine
from repro.inference.fleet import ReplicaFleet
from repro.inference.router import RequestRouter
from repro.models import init_params
from repro.telemetry.critical_path import SEGMENTS, analyze
from repro.telemetry.tracing import RequestTracer
from repro.workload import sample_requests

ARCH = "smollm-360m"
MAX_LEN = 64
ROUNDS = 3 if FAST else 5
OVERHEAD_GATE = 1.05          # traced median <= 1.05x untraced median


def _requests(cfg, n=4, max_new=8):
    rng = np.random.default_rng(0)
    return [Request(i, prompt=list(rng.integers(0, cfg.vocab_size, 12)),
                    max_new_tokens=max_new) for i in range(n)]


def _engine(cfg, params, *, traced: bool) -> ServeEngine:
    tracer = RequestTracer() if traced else None
    eng = ServeEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                      plan="eager", monitor=False, telemetry=None,
                      tracer=tracer)
    eng.run(_requests(cfg))            # warmup: pay jit once
    if tracer is not None:
        tracer.clear()
    return eng


def _median(xs: list) -> float:
    xs = sorted(xs)
    return xs[len(xs) // 2] if xs else 0.0


def _measure_pair(cfg, params) -> tuple:
    """Median decode-step time (untraced, traced), rounds INTERLEAVED so
    background load drift hits both measurement pools equally."""
    eng_off = _engine(cfg, params, traced=False)
    eng_on = _engine(cfg, params, traced=True)
    off_steps, on_steps = [], []
    for _ in range(ROUNDS):
        for eng, pool in ((eng_off, off_steps), (eng_on, on_steps)):
            eng.reset()
            if eng.tracer is not None:
                eng.tracer.clear()     # reset() keeps the shared tracer
            eng.run(_requests(cfg))
            pool.extend(eng.stats.step_times_s)
    return _median(off_steps), _median(on_steps)


def _tail_blame_row(cfg, params) -> str:
    """The router bench's Poisson chatbot drain at replicas=1, traced:
    the analyzer must conserve every request and name a dominant blame
    segment for the p99-TTFT tail."""
    wl = sample_requests("chatbot", 8 if FAST else 12, seed=0,
                         vocab_size=cfg.vocab_size, prompt_cap=12,
                         output_cap=6, time_scale=100.0)
    tracer = RequestTracer()
    fleet = ReplicaFleet(cfg, params, replicas=1, max_batch=2,
                         max_len=MAX_LEN, plan="eager", monitor=False,
                         tracer=tracer)
    router = RequestRouter(fleet, policy="least-queue-depth",
                           tracer=tracer)
    router.route([Request(w.rid, prompt=list(w.prompt),
                          max_new_tokens=w.max_new_tokens,
                          arrival_s=w.arrival_s) for w in wl.requests])
    analysis = analyze(tracer)
    if not analysis.conservation_ok:
        raise RuntimeError(
            "conservation invariant violated in the blame scenario: "
            "max error "
            f"{max(b.conservation_error for b in analysis.breakdowns)}s")
    tail = analysis.tail_blame(99.0)
    dom = tail["dominant"]
    if dom not in SEGMENTS or tail["share"].get(dom, 0.0) <= 0.0:
        raise RuntimeError(
            f"p99 TTFT tail has no nameable blame segment: {tail!r}")
    return csv_row("tracing/p99_ttft_blame", tail["threshold_s"] * 1e6,
                   f"dominant={dom};share={tail['share'][dom]:.3f};"
                   f"tail_n={tail['n']};replicas=1")


def run() -> list[str]:
    rows = []
    cfg = reduced(get_config(ARCH), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)

    t_off, t_on = _measure_pair(cfg, params)
    ratio = t_on / t_off if t_off > 0 else 0.0
    if ratio > OVERHEAD_GATE:
        # one noise retry before declaring a regression: ms-scale CPU
        # step times jitter by a few percent run to run
        t_off, t_on = _measure_pair(cfg, params)
        ratio = t_on / t_off if t_off > 0 else 0.0
    verdict = "ok" if ratio <= OVERHEAD_GATE else "OVER_BUDGET"
    rows.append(csv_row("tracing/decode_step_untraced", t_off * 1e6,
                        "tracer=off"))
    rows.append(csv_row("tracing/decode_step_traced", t_on * 1e6,
                        f"tracer=on;overhead={ratio:.3f}x;"
                        f"gate={OVERHEAD_GATE}x;{verdict}"))
    if ratio > OVERHEAD_GATE:
        raise RuntimeError(
            f"tracing overhead {ratio:.3f}x exceeds the "
            f"{OVERHEAD_GATE}x decode-step budget "
            f"(traced {t_on * 1e6:.1f}us vs untraced {t_off * 1e6:.1f}us)")

    rows.append(_tail_blame_row(cfg, params))
    return rows

"""Shared benchmark helpers: SKIP traces of the paper's four workloads.

Models are traced at FULL width/vocab (per-kernel flops/bytes — which set
the CPU-vs-GPU-bound physics — must be the real ones) but with a 4-layer
trunk: the kernel stream is per-layer periodic, so chain statistics and
boundedness are depth-invariant, and host measurement stays tractable on
one CPU core.  Absolute TKLQT/IL numbers are per-4-layer-trunk; inflection
batches, crossovers, and speedup ratios — the paper's claims — are the
deliverable and are depth-independent.
"""
from __future__ import annotations

import functools
import os

import jax

from repro.configs import get_config
from repro.core import SKIP
from repro.models import forward, init_params

FAST = bool(int(os.environ.get("BENCH_FAST", "0")))

BENCH_LAYERS = 2 if FAST else 4
PAPER_SEQ = 128 if FAST else 512   # the paper benchmarks at 512 tokens


@functools.lru_cache(maxsize=None)
def build_skip(arch: str, seq: int = PAPER_SEQ, layers: int = BENCH_LAYERS,
               measure: bool = True) -> SKIP:
    cfg = get_config(arch).replace(
        n_layers=layers * len(get_config(arch).block_pattern),
        param_dtype="float32", compute_dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, seq), 0,
                                cfg.vocab_size)

    def fwd(params, tokens):
        logits, _, _ = forward(params, tokens, cfg, unroll=True)
        return logits

    skip = SKIP.trace(fwd, params, tokens, base_batch=1)
    if measure:
        skip.measure_host(repeats=2)
    return skip


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"

"""Roofline table from the dry-run artifacts (beyond-paper deliverable).

Reads results/dryrun/*.json (written by repro.launch.dryrun), augments each
cell with analytically-derived ideal terms (params/cache bytes from the
config via eval_shape — no compilation here), and emits per-cell rows plus
the EXPERIMENTS.md markdown table via `markdown_table()`.
"""
from __future__ import annotations

import functools
import glob
import json
import os

import jax

from benchmarks.common import csv_row
from repro.configs import SHAPES, get_config
from repro.launch.roofline import ideal_times
from repro.launch.steps import batch_specs, encoder_len, params_sds
from repro.models import make_cache


@functools.lru_cache(maxsize=None)
def _static_bytes(arch: str, shape_name: str):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    p = params_sds(cfg)
    pbytes = sum(s.size * s.dtype.itemsize for s in jax.tree.leaves(p))
    cbytes = 0
    if shape.kind in ("prefill", "decode"):
        el = encoder_len(cfg, shape)
        c = jax.eval_shape(lambda: make_cache(
            cfg, shape.global_batch, shape.seq_len, src_len=max(el, 1)))
        cbytes = sum(s.size * s.dtype.itemsize for s in jax.tree.leaves(c))
    b = batch_specs(cfg, shape)
    iobytes = sum(s.size * s.dtype.itemsize for s in jax.tree.leaves(b))
    return pbytes, cbytes, iobytes


def load_cells(out_dir: str = "results/dryrun") -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            cells.append(r)
            continue
        shape = SHAPES[r["shape"]]
        rf = r["roofline"]
        pb, cb, iob = _static_bytes(r["arch"], r["shape"])
        t_ci, t_mi = ideal_times(shape.kind, rf["model_flops_total"],
                                 pb, cb, iob, rf["n_chips"])
        step = max(rf["t_compute"], rf["t_memory"], rf["t_collective"])
        rf["t_compute_ideal"] = t_ci
        rf["t_memory_ideal"] = t_mi
        rf["ideal_step"] = max(t_ci, t_mi)
        rf["roofline_frac"] = rf["ideal_step"] / step if step else 0.0
        r["params_bytes"] = pb
        r["cache_bytes"] = cb
        cells.append(r)
    return cells


def run() -> list[str]:
    rows = []
    for r in load_cells():
        if r.get("status") != "ok":
            rows.append(csv_row(
                f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
                "status=failed"))
            continue
        rf = r["roofline"]
        rows.append(csv_row(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            max(rf["t_compute"], rf["t_memory"], rf["t_collective"]) * 1e6,
            f"dom={rf['dominant']};tC_ms={rf['t_compute']*1e3:.2f};"
            f"tM_ms={rf['t_memory']*1e3:.2f};"
            f"tX_ms={rf['t_collective']*1e3:.2f};"
            f"useful={rf['useful_flops_frac']:.2f};"
            f"roofline_frac={rf['roofline_frac']:.3f}"))
    return rows


def markdown_table(out_dir: str = "results/dryrun",
                   mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | tC (ms) | tM (ms) | tX (ms) | dominant | "
        "useful FLOPs | roofline frac | temp GB/dev |",
        "|---|---|---:|---:|---:|---|---:|---:|---:|",
    ]
    for r in load_cells(out_dir):
        if r.get("status") != "ok" or r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['t_compute']*1e3:.1f} | "
            f"{rf['t_memory']*1e3:.1f} | {rf['t_collective']*1e3:.1f} | "
            f"{rf['dominant']} | {rf['useful_flops_frac']:.2f} | "
            f"{rf['roofline_frac']:.3f} | "
            f"{r['memory']['temp_bytes']/1e9:.1f} |")
    return "\n".join(lines)

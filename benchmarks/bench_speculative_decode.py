"""Speculative decode path: draft-propose / batched-verify vs plain greedy
at batch=1 — the launch-bound corner where speculation pays most.  Asserts
the emitted tokens are byte-identical to greedy and that speculation
actually amortizes launches (steps per emitted token <= 0.75), then prices
the draft's extra dispatch stream on LC vs CC device models."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import FAST, csv_row
from repro.configs import get_config, reduced
from repro.core.device_model import PLATFORMS, dispatch_fanout_s
from repro.inference.engine import Request, ServeEngine
from repro.models import init_params

ARCH = "smollm-360m"
REPEATS = 2 if FAST else 3
MAX_LEN = 96
MAX_NEW = 16
SPEC_K = 4
STEPS_PER_TOKEN_GATE = 0.75


def _requests(cfg, n=3):
    rng = np.random.default_rng(0)
    return [Request(i, prompt=list(rng.integers(0, cfg.vocab_size, 12)),
                    max_new_tokens=MAX_NEW) for i in range(n)]


def _serve(eng, cfg):
    eng.run(_requests(cfg))            # warmup: pay jit once
    eng.reset()
    t0 = time.perf_counter()
    done = eng.run(_requests(cfg))
    dt = time.perf_counter() - t0
    toks = [list(r.generated) for r in sorted(done, key=lambda r: r.rid)]
    return toks, dt


def run() -> list[str]:
    rows = []
    cfg = reduced(get_config(ARCH), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)

    # batch=1: each request decodes alone — every target step is one
    # launch stream per token, the dispatch-bound worst case
    base = ServeEngine(cfg, params, max_batch=1, max_len=MAX_LEN)
    ref_toks, base_dt = _serve(base, cfg)
    base_steps = base.stats.decode_steps
    rows.append(csv_row(
        "speculative_decode/greedy_b1", base_dt / max(base_steps, 1) * 1e6,
        f"decode_steps={base_steps};tokens={base.stats.tokens_out}"))

    spec = ServeEngine(cfg, params, max_batch=1, max_len=MAX_LEN,
                       speculative=True, spec_k=SPEC_K)
    spec_toks, spec_dt = _serve(spec, cfg)
    st = spec.stats

    # greedy preservation is the contract: every emitted token is a
    # target argmax, so the streams must match byte for byte
    assert spec_toks == ref_toks, (
        f"speculative tokens diverged from greedy: {spec_toks} != "
        f"{ref_toks}")
    spt = st.steps_per_emitted_token
    assert 0.0 < spt <= STEPS_PER_TOKEN_GATE, (
        f"speculation failed to amortize launches: "
        f"{spt:.3f} steps/emitted token > {STEPS_PER_TOKEN_GATE} "
        f"(accept_rate={st.accept_rate:.3f}, k={SPEC_K})")
    rows.append(csv_row(
        "speculative_decode/spec_b1",
        spec_dt / max(st.spec_rounds, 1) * 1e6,
        f"k={SPEC_K};rounds={st.spec_rounds};"
        f"accept_rate={st.accept_rate:.3f};"
        f"steps_per_token={spt:.3f};byte_identical=True"))

    # the trade per platform, at kernel-stream granularity: every SKIPPED
    # target step saves its whole eager launch stream, every draft call
    # adds the (shallower) draft stream — priced over each device model's
    # host path.  CC's costlier launches scale both sides but its wider
    # dispatch-bound region is where these launches actually serialize.
    import jax.numpy as jnp

    from repro.core.tracing import trace_fn
    from repro.models import forward, make_cache

    def _stream_len(body_cfg, body_params):
        cache = make_cache(body_cfg, 1, MAX_LEN, src_len=1,
                           dtype=body_cfg.cdtype)

        def decode_body(p, c, toks, lens):
            logits, _, c2 = forward(p, toks, body_cfg, cache=c,
                                    lengths=lens, unroll=True)
            return logits[:, 0], c2

        return len(trace_fn(decode_body, body_params, cache,
                            jnp.zeros((1, 1), jnp.int32),
                            jnp.zeros((1,), jnp.int32)).kernels)

    n_target = _stream_len(cfg, params)
    n_draft = _stream_len(spec.draft_cfg, spec.backend.draft_params)
    saved_steps = max(st.spec_emitted - st.spec_rounds, 0)
    for plat in ("Intel+H100", "GH200"):
        pspec = PLATFORMS[plat]
        per_launch = dispatch_fanout_s(pspec, 1)
        draft_tax = st.draft_dispatches * n_draft * per_launch
        saved = saved_steps * n_target * per_launch
        rows.append(csv_row(
            f"speculative_decode/launch_trade_{pspec.coupling}", 0.0,
            f"platform={plat};draft_launches={st.draft_dispatches * n_draft};"
            f"modeled_draft_tax_us={draft_tax * 1e6:.1f};"
            f"saved_launches={saved_steps * n_target};"
            f"modeled_saved_launch_us={saved * 1e6:.1f};"
            f"net_win={saved > draft_tax}"))
    return rows

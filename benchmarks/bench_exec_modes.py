"""Table I reproduction: compilation time & speedup per execution mode.

eager (per-op dispatch) / chain-fused L=8 / chain-fused L=32 / graph
(whole-jaxpr jit = torch.compile analogue).  Compile time and host dispatch
time are REAL measurements on this machine; the paper's observation — graph
modes trade large compile time for dispatch-tax savings — reproduces
directly in JAX.
"""
from __future__ import annotations

import time


from benchmarks.common import build_skip, csv_row
from repro.core.proximity import fusion_segments
from repro.core.tracing import Executor

MODEL = "gpt2"


def _time_mode(skip, segments) -> tuple[float, float]:
    """Returns (compile_s, host_dispatch_s)."""
    ex = Executor(skip.trace_, segments=segments)
    t0 = time.perf_counter()
    ex.run(*skip.args)                      # builds + compiles + runs
    compile_s = time.perf_counter() - t0
    ts = ex.measure_host(*skip.args, repeats=3)
    return compile_s, sum(ts)


def run() -> list[str]:
    skip = build_skip(MODEL)
    names = skip.trace_.kernel_names
    n = len(names)
    modes = {
        "eager": [[i] for i in range(n)],
        "chain_fused_L8": fusion_segments(names, 8),
        "chain_fused_L32": fusion_segments(names, 32),
        "graph": [list(range(n))],
    }
    rows = []
    base_host = None
    for mode, segs in modes.items():
        compile_s, host_s = _time_mode(skip, segs)
        if base_host is None:
            base_host = host_s
        rows.append(csv_row(
            f"exec_modes/{MODEL}/{mode}", host_s * 1e6,
            f"compile_s={compile_s:.2f};launches={len(segs)};"
            f"dispatch_speedup={base_host / host_s:.2f}"))
    return rows

"""Tensor-parallel sharded serving: measured tp=1 vs tp=2 engine decode
steps on reduced smollm (byte-identical greedy tokens asserted), plus the
modeled per-layer collective tax of a full-size decode step on LC vs CC
coupling fabrics — the multi-GPU half of the serving story.

The tp comparison runs in a subprocess with a forced host-platform device
count (this process may hold a single device); the child prints one
parseable line per engine and the parent re-emits benchmark rows."""
from __future__ import annotations

import subprocess
import sys
import textwrap

from benchmarks.common import FAST, csv_row
from repro.configs import get_config
from repro.core.device_model import PLATFORMS, allreduce_cost_s
from repro.telemetry.characterize import decode_collective_sites

ARCH = "smollm-360m"
DEVICES = 4
MAX_LEN = 64
REQUESTS = 4 if FAST else 6
MAX_NEW = 4 if FAST else 8

_CHILD = """
import json, jax, numpy as np
from repro.configs import get_config, reduced
from repro.inference.engine import Request, ServeEngine
from repro.models import init_params

cfg = reduced(get_config("{arch}"), n_layers=2)
params = init_params(jax.random.PRNGKey(0), cfg)

def reqs():
    rng = np.random.default_rng(0)
    return [Request(i, prompt=list(rng.integers(0, cfg.vocab_size, 8)),
                    max_new_tokens={max_new}) for i in range({requests})]

def measure(tp):
    eng = ServeEngine(cfg, params, max_batch=2, max_len={max_len}, tp=tp)
    eng.run(reqs())                 # warmup: pay jit/shard_map compiles
    eng.reset()
    done = eng.run(reqs())
    toks = [r.generated for r in sorted(done, key=lambda r: r.rid)]
    st = eng.stats
    steps = st.step_times_s
    return toks, {{
        "tp": tp,
        "mean_step_us": 1e6 * sum(steps) / len(steps) if steps else 0.0,
        "decode_steps": st.decode_steps,
        "decode_dispatches": st.decode_dispatches,
        "per_device": st.per_device_dispatches,
        "collective_bytes_per_step": st.collective_bytes_per_decode_step,
        "modeled_collective_tax_us": st.modeled_collective_tax_s * 1e6,
    }}

t1, r1 = measure(1)
t2, r2 = measure(2)
assert t1 == t2, ("tp=2 tokens diverged from tp=1", t1, t2)
print("ROW", json.dumps(r1))
print("ROW", json.dumps(r2))
"""


def _measure_tp_pair() -> list[dict]:
    import json
    import os
    env = dict(os.environ)
    env.update({
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={DEVICES}",
        "JAX_PLATFORMS": "cpu", "PYTHONPATH": "src"})
    code = textwrap.dedent(_CHILD).format(
        arch=ARCH, requests=REQUESTS, max_new=MAX_NEW, max_len=MAX_LEN)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=540)
    if out.returncode != 0:
        raise RuntimeError(f"sharded child failed: {out.stderr[-2000:]}")
    return [json.loads(line.split(" ", 1)[1])
            for line in out.stdout.splitlines() if line.startswith("ROW")]


def run() -> list[str]:
    rows = []
    for r in _measure_tp_pair():
        per_dev = ";".join(f"d{d}={n}" for d, n in
                           sorted(r["per_device"].items()))
        rows.append(csv_row(
            f"sharded_decode/engine_tp{r['tp']}", r["mean_step_us"],
            f"decode_steps={r['decode_steps']};"
            f"dispatches={r['decode_dispatches']};{per_dev};"
            f"coll_B_per_step={r['collective_bytes_per_step']:.0f};"
            f"coll_tax_us={r['modeled_collective_tax_us']:.1f};"
            "tokens=byte-identical-vs-tp1"))

    # modeled: per-step collective tax of FULL smollm decode, LC vs CC —
    # the same per-layer psum payloads the sharded backend captures,
    # priced per coupling fabric (no weights materialized)
    cfg = get_config(ARCH)
    batch, tp = 8, 2
    sites = [c for c in decode_collective_sites(cfg, batch, 2 * cfg.n_layers)
             if c]
    for plat in ("Intel+H100", "GH200"):
        spec = PLATFORMS[plat]
        tax = sum(allreduce_cost_s(spec, c, tp) for c in sites)
        rows.append(csv_row(
            f"sharded_decode/allreduce_tax_{spec.coupling}", 0.0,
            f"platform={plat};arch={cfg.name};batch={batch};tp={tp};"
            f"psums={len(sites)};payload_B={int(sum(sites))};"
            f"modeled_tax_us={tax * 1e6:.1f}"))
    return rows

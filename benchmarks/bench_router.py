"""Fleet scaling bench: routed throughput + TTFT vs replica count.

Drains one fixed Poisson chatbot workload through the router at replica
counts 1 and 2 (same requests, same arrival schedule, same per-replica
engine config) and reports, per count, fleet throughput over the virtual
makespan and TTFT p50/p99 off each replica's serving clock.  The derived
column carries the 2-replica makespan ratio.  On a CPU-reduced model a
single engine already batch-saturates its decode steps, so the honest
expectation is p50 TTFT dropping with replica count while makespan stays
near 1.0x — the queueing win arrives before the throughput win, exactly
the data-parallel serving tradeoff.  A routing regression (a policy
pinning everything to one replica) shows up as the TTFT split
collapsing back to the 1-replica numbers.
"""
from __future__ import annotations

import jax

from benchmarks.common import FAST, csv_row
from repro.configs import get_config, reduced
from repro.inference.engine import Request, ServeEngine
from repro.inference.fleet import ReplicaFleet
from repro.inference.router import RequestRouter
from repro.models import init_params
from repro.telemetry.metrics import percentile
from repro.workload import sample_requests

ARCH = "smollm-360m"
MAX_LEN = 64
N_REQUESTS = 6 if FAST else 10
REPLICA_COUNTS = (1, 2)
POLICY = "least-queue-depth"


def _requests(wl):
    return [Request(w.rid, prompt=list(w.prompt),
                    max_new_tokens=w.max_new_tokens, arrival_s=w.arrival_s)
            for w in wl.requests]


def run() -> list[str]:
    rows = []
    cfg = reduced(get_config(ARCH), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    wl = sample_requests("chatbot", N_REQUESTS, seed=0,
                         vocab_size=cfg.vocab_size, prompt_cap=12,
                         output_cap=6, time_scale=100.0)
    kw = dict(max_batch=2, max_len=MAX_LEN, plan="jit")

    # warmup: pay jit/plan compile once so measured drains are steady-state
    ServeEngine(cfg, params, **kw).run(_requests(wl)[:2])

    makespans = {}
    for n in REPLICA_COUNTS:
        fleet = ReplicaFleet(cfg, params, replicas=n, **kw)
        router = RequestRouter(fleet, policy=POLICY)
        report = router.route(_requests(wl))
        if len(report.completed) != N_REQUESTS:
            raise RuntimeError(
                f"fleet of {n} drained {len(report.completed)}/"
                f"{N_REQUESTS} requests")
        ttft = sorted(t for rep in fleet.live()
                      for t in rep.engine.stats.ttft_s.values())
        tokens = sum(rep.engine.stats.tokens_out for rep in fleet.live())
        makespans[n] = report.clock_s
        tput = tokens / report.clock_s if report.clock_s else 0.0
        us_per_tok = (report.clock_s / tokens * 1e6) if tokens else 0.0
        rows.append(csv_row(
            f"router/replicas{n}_per_token", us_per_tok,
            f"policy={POLICY};tok_per_s={tput:.1f};"
            f"ttft_p50_ms={percentile(ttft, 50.0) * 1e3:.1f};"
            f"ttft_p99_ms={percentile(ttft, 99.0) * 1e3:.1f};"
            f"makespan_s={report.clock_s:.3f}"))
    speedup = (makespans[1] / makespans[2]
               if makespans.get(2) else 0.0)
    rows.append(csv_row("router/fleet_speedup_2x", 0.0,
                        f"makespan_1r/makespan_2r={speedup:.3f}x"))
    return rows

"""Measured serving characterization: scenario x batch sweep of the live
engine with telemetry — serving percentiles (TTFT/ITL/E2E), measured
launch tax per step, and the measured boundedness classification.  This
is the measured companion of ``tklqt_sweep`` (which models the curve)."""
from __future__ import annotations

import jax

from benchmarks.common import FAST, csv_row
from repro.configs import get_config, reduced
from repro.models import init_params
from repro.telemetry.characterize import characterize

ARCH = "smollm-360m"
SCENARIOS = ("chatbot",) if FAST else ("chatbot", "agentic")
BATCHES = (1, 2) if FAST else (1, 2, 4)
N_REQUESTS = 3 if FAST else 6


def run() -> list[str]:
    cfg = reduced(get_config(ARCH), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rows = []
    for scenario in SCENARIOS:
        res = characterize(cfg, params, scenario=scenario, batches=BATCHES,
                           plan="chain", n_requests=N_REQUESTS, seed=0,
                           max_len=128, prompt_cap=16, output_cap=4)
        for p in res.points:
            r = p.row()
            rows.append(csv_row(
                f"characterize/{scenario}/b{p.batch}",
                r["decode_launch_tax_us"],
                f"class={res.boundedness.classify(p.batch)};"
                f"step_us={r['mean_decode_step_us']};"
                f"ttft_p50_ms={r['ttft_p50_ms']};"
                f"ttft_p99_ms={r['ttft_p99_ms']};"
                f"itl_p50_ms={r['itl_p50_ms']};"
                f"itl_p99_ms={r['itl_p99_ms']};"
                f"e2e_p99_ms={r['e2e_p99_ms']};"
                f"tok_per_s={r['tokens_per_s']}"))
        rows.append(csv_row(
            f"characterize/{scenario}/inflection", 0.0,
            f"inflection_batch={res.boundedness.inflection_batch}"))
    return rows

"""Docs smoke gate: links resolve, CLI examples actually run.

    python tools/docs_smoke.py [--no-exec]

Two checks over README.md + docs/*.md:

1. **Link check** — every relative markdown link (``[x](docs/cli.md)``,
   ``[y](metrics.md#anchor)``) must point at a file that exists, and a
   ``#fragment`` must match a heading in the target (GitHub anchor
   slugging: lowercase, spaces to dashes, punctuation dropped).
2. **Example execution** — every fenced block in docs/cli.md whose info
   string is exactly ``bash`` runs under ``bash -e`` with PYTHONPATH=src
   from the repo root; nonzero exit fails the gate.  Blocks tagged
   ``bash skip-smoke`` are rendered as bash but skipped (documented
   invocations too heavy for CI).

Stdlib-only on purpose: the CI job runs it before installing anything
beyond the test requirements.
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_FILES = ["README.md", "docs/architecture.md", "docs/metrics.md",
             "docs/cli.md"]
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```(.*)$")


def _anchors(path: str) -> set:
    """GitHub-style anchor slugs for every heading in a markdown file."""
    out = set()
    in_fence = False
    with open(path) as fh:
        for line in fh:
            if line.startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence or not line.startswith("#"):
                continue
            text = line.lstrip("#").strip()
            slug = re.sub(r"[^\w\- ]", "", text.lower())
            out.add(re.sub(r" +", "-", slug).strip("-"))
    return out


def check_links() -> list:
    """Resolve every relative link + fragment; return failure strings."""
    bad = []
    for doc in DOC_FILES:
        src = os.path.join(ROOT, doc)
        base = os.path.dirname(src)
        in_fence = False
        for lineno, line in enumerate(open(src), 1):
            if line.startswith("```"):
                in_fence = not in_fence
            if in_fence:
                continue
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                path, _, frag = target.partition("#")
                dest = os.path.normpath(os.path.join(base, path)) \
                    if path else src
                if not os.path.exists(dest):
                    bad.append(f"{doc}:{lineno}: broken link -> {target}")
                    continue
                if frag and dest.endswith(".md") and \
                        frag not in _anchors(dest):
                    bad.append(f"{doc}:{lineno}: missing anchor "
                               f"#{frag} in {path or doc}")
    return bad


def bash_blocks(path: str) -> list:
    """(start_line, info, script) for each fenced block in ``path``."""
    blocks, info, buf, start = [], None, [], 0
    for lineno, line in enumerate(open(path), 1):
        m = FENCE_RE.match(line)
        if m and info is None:
            info, buf, start = m.group(1).strip(), [], lineno
        elif m:
            blocks.append((start, info, "".join(buf)))
            info = None
        elif info is not None:
            buf.append(line)
    return blocks


def run_examples() -> list:
    """Execute the ``bash``-tagged docs/cli.md blocks; return failures."""
    path = os.path.join(ROOT, "docs", "cli.md")
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.setdefault("JAX_PLATFORMS", "cpu")
    bad = []
    ran = 0
    for start, info, script in bash_blocks(path):
        if info != "bash":
            if info.startswith("bash"):
                print(f"docs/cli.md:{start}: skipped ({info})")
            continue
        ran += 1
        t0 = time.time()
        proc = subprocess.run(["bash", "-e"], input=script, text=True,
                              cwd=ROOT, env=env,
                              stdout=subprocess.PIPE,
                              stderr=subprocess.STDOUT)
        status = "ok" if proc.returncode == 0 else \
            f"FAILED (exit {proc.returncode})"
        print(f"docs/cli.md:{start}: {status} in {time.time() - t0:.0f}s")
        if proc.returncode != 0:
            tail = "\n".join(proc.stdout.splitlines()[-15:])
            bad.append(f"docs/cli.md:{start}: exit {proc.returncode}\n"
                       f"{tail}")
    print(f"executed {ran} example blocks")
    return bad


def main() -> int:
    """Run both checks; print failures; return a shell exit code."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-exec", action="store_true",
                    help="link-check only (skip running cli.md examples)")
    args = ap.parse_args()
    bad = check_links()
    print(f"link check: {len(bad)} problems across {len(DOC_FILES)} files")
    if not args.no_exec:
        bad += run_examples()
    for b in bad:
        print(b)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())

"""Local stand-in for the CI pydocstyle gate (ruff D100-D103).

    python tools/check_docstrings.py src/repro/inference/engine.py ...

CI runs the real `ruff check --select D100,D101,D102,D103` on the public
serving surface; this script applies the same four rules with the same
exemptions (nested defs exempt from D103 per pydocstyle, private names
still checked only when ruff would check them — ruff flags every
def/class regardless of leading underscore for D1xx, so we do too,
except `__init__`-style dunders other than module-level ones are D105/
D107 territory and NOT in the selected set).  Exit 1 with a
file:line rule name listing when anything is missing.
"""
from __future__ import annotations

import ast
import sys


def _missing(path: str) -> list:
    with open(path) as fh:
        tree = ast.parse(fh.read(), filename=path)
    out = []
    if ast.get_docstring(tree) is None:
        out.append((path, 1, "D100", "module"))

    def visit(node, in_class):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                if ast.get_docstring(child) is None:
                    out.append((path, child.lineno, "D101", child.name))
                visit(child, True)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                name = child.name
                dunder = name.startswith("__") and name.endswith("__")
                rule = "D102" if in_class else "D103"
                # D105/D107 (magic methods, __init__) are not selected
                if not (in_class and dunder) and \
                        ast.get_docstring(child) is None:
                    out.append((path, child.lineno, rule, name))
                # nested defs are exempt (pydocstyle checks only
                # module/class scope)

    visit(tree, False)
    return out


def main(paths: list) -> int:
    """Check every path; print violations; return a shell exit code."""
    bad = []
    for p in paths:
        bad.extend(_missing(p))
    for path, line, rule, name in bad:
        print(f"{path}:{line}: {rule} missing docstring ({name})")
    print(f"{len(bad)} missing docstrings in {len(paths)} files"
          if bad else f"docstrings ok across {len(paths)} files")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

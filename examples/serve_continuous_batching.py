"""End-to-end serving driver: continuous batching over a small model.

    PYTHONPATH=src python examples/serve_continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.inference.engine import Request, ServeEngine
from repro.models import init_params

cfg = reduced(get_config("smollm-360m"), n_layers=4, d_model=128, d_ff=256)
params = init_params(jax.random.PRNGKey(0), cfg)
engine = ServeEngine(cfg, params, max_batch=4, max_len=128)

rng = np.random.default_rng(0)
requests = [
    Request(i, prompt=list(rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 24)))),
            max_new_tokens=int(rng.integers(4, 20)))
    for i in range(12)
]

t0 = time.time()
done = engine.run(requests)
dt = time.time() - t0

print(f"served {len(done)} requests, {engine.stats.tokens_out} tokens "
      f"in {dt:.1f}s ({engine.stats.tokens_out/dt:.1f} tok/s)")
print(f"decode steps: {engine.stats.decode_steps}, "
      f"mean slot occupancy {np.mean(engine.stats.slot_occupancy):.2f}/4")
for r in done[:3]:
    print(f"  req {r.rid}: prompt[:4]={r.prompt[:4]} -> {r.generated}")

"""Quickstart: profile a model with SKIP-JAX, classify PU-boundedness,
mine proximity-score fusion chains, and ACTUALLY fuse them.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config, reduced
from repro.core import SKIP
from repro.models import forward, init_params

# 1. a small GPT-2-family model (per-layer kernel streams via unroll=True)
cfg = reduced(get_config("gpt2"), n_layers=4)
params = init_params(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0, cfg.vocab_size)


def fwd(params, tokens):
    return forward(params, tokens, cfg, unroll=True)[0]


# 2. trace -> operator/kernel stream + measured host dispatch costs
skip = SKIP.trace(fwd, params, tokens)
skip.measure_host(repeats=2)
print(f"traced {len(skip.trace_.kernels)} kernels")

# 3. simulate the paper's three platforms (Table V constants)
for plat in ("Intel+H100", "AMD+A100", "GH200"):
    r = skip.report(plat, batch=1)
    print(f"{plat:12s} TKLQT={r.tklqt*1e6:7.0f}us  IL={r.il*1e6:7.0f}us  "
          f"GPU idle={r.gpu_idle*1e6:7.0f}us  queue share={r.queue_share:.2f}")

# 4. CPU-bound -> GPU-bound inflection (paper Fig. 6)
sweep, _ = skip.batch_sweep("GH200", batches=(1, 4, 16, 64, 256))
print(f"GH200 inflection batch: {sweep.inflection_batch} "
      f"(CPU-bound region: {sweep.cpu_bound_region})")

# 5. proximity-score mining (Eq. 6) and the idealized speedup (Eqs. 7-8)
rec = skip.recommend(length=8)
print(f"L=8 chains: {len(rec.deterministic)} deterministic (PS=1), "
      f"ideal speedup {rec.speedup:.2f}x")

# 6. beyond the paper: apply the fusion and measure real dispatch savings
out = skip.fuse(length=8, repeats=2)
print(f"chain-jit: {out.k_eager} -> {out.k_fused} launches, measured host "
      f"speedup {out.measured_speedup:.2f}x (ideal {out.ideal_speedup:.2f}x), "
      f"max |err| {out.max_abs_err:.1e}")

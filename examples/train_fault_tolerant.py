"""Fault-tolerant training: checkpoint, simulated crash, exact resume.

    PYTHONPATH=src python examples/train_fault_tolerant.py
"""
import shutil

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.training.loop import TrainConfig, Trainer

CKPT = "/tmp/repro_example_ckpt"
shutil.rmtree(CKPT, ignore_errors=True)

cfg = reduced(get_config("smollm-360m"))
data = DataConfig(batch=4, seq_len=64, vocab_size=cfg.vocab_size)

# run 1: crashes (simulated node failure) right after the step-20 checkpoint
try:
    Trainer(cfg, data, TrainConfig(steps=40, ckpt_every=10, ckpt_dir=CKPT,
                                   fail_at_step=20)).run()
except RuntimeError as e:
    print(f"crashed as planned: {e}")

# run 2: auto-resumes from step 20 and completes
out = Trainer(cfg, data, TrainConfig(steps=40, ckpt_every=10,
                                     ckpt_dir=CKPT)).run()
h = out["history"]
print(f"resumed at step {h[0]['step']}, finished at {out['final_step']}; "
      f"loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f}")
print(f"stragglers flagged: {out['stragglers']}")

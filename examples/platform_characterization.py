"""Reproduce the paper's platform characterization on one model: TTFT vs
batch on LC (PCIe A100/H100) and CC (GH200) platform models, crossover
point, and the fusion opportunity in the CPU-bound region.

    PYTHONPATH=src python examples/platform_characterization.py
"""
import jax

from repro.configs import get_config
from repro.core import SKIP
from repro.models import forward, init_params

# full-width 4-layer GPT-2 trunk at the paper's 512-token sequence
cfg = get_config("gpt2").replace(n_layers=4, param_dtype="float32",
                                 compute_dtype="float32")
params = init_params(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 512), 0,
                            cfg.vocab_size)
skip = SKIP.trace(lambda p, t: forward(p, t, cfg, unroll=True)[0],
                  params, tokens)

BATCHES = (1, 4, 16, 64, 256)
print(f"{'batch':>6} | " + " | ".join(f"{p:>12}" for p in
                                      ("Intel+H100", "AMD+A100", "GH200")))
rows = {}
for plat in ("Intel+H100", "AMD+A100", "GH200"):
    rows[plat] = [skip.report(plat, b, use_host_scale=False).il
                  for b in BATCHES]
for i, b in enumerate(BATCHES):
    print(f"{b:>6} | " + " | ".join(f"{rows[p][i]*1e3:10.2f}ms"
                                    for p in rows))

cp = next((b for i, b in enumerate(BATCHES)
           if rows["GH200"][i] < min(rows["Intel+H100"][i],
                                     rows["AMD+A100"][i])), None)
print(f"\ncrossover (GH200 beats LC): batch {cp}")
print("GH200 low-batch penalty (b=1): "
      f"{rows['GH200'][0]/rows['Intel+H100'][0]:.2f}x")
print("GH200 speedup at b=256: "
      f"{min(rows['Intel+H100'][-1], rows['AMD+A100'][-1])/rows['GH200'][-1]:.2f}x")

rec = skip.recommend(length=32)
print("\nfusion opportunity (CPU-bound region): L=32 ideal speedup "
      f"{rec.speedup:.2f}x from {rec.c_fused} deterministic chains")

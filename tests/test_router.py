"""Router + replica-fleet tests: byte-determinism, policies, elasticity.

The serving tier's correctness bar is that routing NEVER changes what a
request generates — only where and when.  Greedy decode is
batch-composition-independent (locked by the tp and preemption
equivalence tests), so a fleet drain must produce per-request tokens
byte-identical to single-engine runs of each replica's partition.
"""
import pytest

from repro.configs import get_config, reduced
from repro.inference.engine import Request, ServeEngine
from repro.inference.fleet import ReplicaFleet
from repro.inference.router import (LeastQueueDepthPolicy,
                                    PrefixAffinityPolicy, RequestRouter,
                                    RoundRobinPolicy, TokenEvent,
                                    make_policy)
from repro.launch.elastic import plan_fleet
from repro.models import init_params
from repro.workload import sample_requests

import jax


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("smollm-360m"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(wl):
    return [Request(w.rid, prompt=list(w.prompt),
                    max_new_tokens=w.max_new_tokens, arrival_s=w.arrival_s)
            for w in wl.requests]


def _fleet(tiny, n=2, **kw):
    cfg, params = tiny
    return ReplicaFleet(cfg, params, replicas=n, max_batch=2, max_len=64,
                        plan="jit", **kw)


class TestSteppableEngine:
    def test_run_equals_submit_tick(self, tiny):
        cfg, params = tiny
        wl = sample_requests("chatbot", 5, seed=1, vocab_size=cfg.vocab_size,
                             prompt_cap=10, output_cap=5, time_scale=100.0)
        e1 = ServeEngine(cfg, params, max_batch=2, max_len=64, plan="jit")
        done1 = e1.run(_requests(wl))
        e2 = ServeEngine(cfg, params, max_batch=2, max_len=64, plan="jit")
        reqs2 = _requests(wl)
        for r in reqs2:
            e2.submit(r)
        while e2.tick():
            pass
        assert {r.rid: r.generated for r in done1} == \
               {r.rid: r.generated for r in reqs2}
        assert all(r.done for r in reqs2)

    def test_queue_depth_and_outstanding(self, tiny):
        cfg, params = tiny
        eng = ServeEngine(cfg, params, max_batch=2, max_len=64, plan="jit")
        assert eng.queue_depth == 0 and not eng.busy
        eng.submit(Request(0, prompt=[1, 2, 3], max_new_tokens=4))
        assert eng.busy and eng.queue_depth == 1
        assert eng.outstanding_tokens == 3 + 4
        while eng.tick():
            pass
        assert eng.queue_depth == 0 and eng.outstanding_tokens == 0


class TestFleetByteDeterminism:
    def test_two_replica_drain_matches_single_engine_partitions(self, tiny):
        cfg, params = tiny
        wl = sample_requests("agentic", 8, seed=3, vocab_size=cfg.vocab_size,
                             prompt_cap=12, output_cap=6, time_scale=50.0)
        fleet = _fleet(tiny)
        router = RequestRouter(fleet, policy="round-robin")
        report = router.route(_requests(wl))
        assert len(report.completed) == 8
        fleet_tokens = report.tokens_by_rid

        # replay each replica's partition on a lone engine
        for rep_rid in sorted(set(report.assignment.values())):
            part = [w for w in wl.requests
                    if report.assignment[w.rid] == rep_rid]
            eng = ServeEngine(cfg, params, max_batch=2, max_len=64,
                              plan="jit")
            class _W:
                requests = part
            done = eng.run(_requests(_W))
            for r in done:
                assert fleet_tokens[r.rid] == list(r.generated), \
                    f"rid {r.rid} diverged on replica {rep_rid}"

    def test_streaming_covers_all_tokens_in_order(self, tiny):
        cfg, params = tiny
        wl = sample_requests("chatbot", 5, seed=2, vocab_size=cfg.vocab_size,
                             prompt_cap=8, output_cap=4, time_scale=100.0)
        events = []
        fleet = _fleet(tiny)
        router = RequestRouter(fleet, on_token=events.append)
        report = router.route(_requests(wl))
        assert all(isinstance(ev, TokenEvent) for ev in events)
        streamed = {}
        last_t = {}
        for ev in events:
            streamed.setdefault(ev.rid, []).append(ev.token)
            assert ev.index == len(streamed[ev.rid]) - 1  # in-order
            assert ev.t >= last_t.get(ev.rid, 0.0)        # monotonic
            last_t[ev.rid] = ev.t
        assert streamed == report.tokens_by_rid
        assert report.token_events == sum(len(v) for v in streamed.values())


class TestPolicies:
    def _reps(self, tiny, n):
        return _fleet(tiny, n=n).serving()

    def test_round_robin_cycles(self, tiny):
        reps = self._reps(tiny, 3)
        pol = RoundRobinPolicy()
        req = Request(0, prompt=[1], max_new_tokens=1)
        picks = [pol.choose(req, reps).rid for _ in range(6)]
        assert picks == [0, 1, 2, 0, 1, 2]

    def test_least_queue_depth_picks_emptier(self, tiny):
        reps = self._reps(tiny, 2)
        reps[0].engine.submit(Request(0, prompt=[1, 2], max_new_tokens=2))
        pol = LeastQueueDepthPolicy()
        assert pol.choose(Request(1, prompt=[3], max_new_tokens=1),
                          reps).rid == 1

    def test_least_queue_depth_token_tiebreak(self, tiny):
        reps = self._reps(tiny, 2)
        # equal depth, unequal work: replica 0 holds the heavier request
        reps[0].engine.submit(Request(0, prompt=[1] * 8, max_new_tokens=16))
        reps[1].engine.submit(Request(1, prompt=[1], max_new_tokens=1))
        pol = LeastQueueDepthPolicy()
        assert pol.choose(Request(2, prompt=[2], max_new_tokens=1),
                          reps).rid == 1

    def test_prefix_affinity_sticks_and_rehomes(self, tiny):
        reps = self._reps(tiny, 2)
        pol = PrefixAffinityPolicy(prefix_len=4)
        a = Request(0, prompt=[7, 7, 7, 7, 1], max_new_tokens=1)
        b = Request(1, prompt=[7, 7, 7, 7, 2], max_new_tokens=1)
        home = pol.choose(a, reps)
        assert pol.choose(b, reps).rid == home.rid      # sticky
        other = [r for r in reps if r.rid != home.rid]
        assert pol.choose(b, other).rid != home.rid     # re-home
        assert pol._home[(7, 7, 7, 7)] == other[0].rid

    def test_make_policy_rejects_unknown(self):
        with pytest.raises(ValueError, match="unknown routing policy"):
            make_policy("weighted-random")


class TestLeastQueueDepthBeatsRoundRobin:
    def test_skewed_lengths_measured_makespan(self, tiny):
        cfg, params = tiny
        # alternating long/short closed burst: RR piles longs onto one
        # replica by arrival parity; LQD balances by outstanding work
        reqs = []
        for i in range(8):
            reqs.append(Request(i, prompt=[(i % 50) + 2] * 4,
                                max_new_tokens=32 if i % 2 == 0 else 1))
        makespan = {}
        for policy in ("round-robin", "least-queue-depth"):
            fleet = _fleet(tiny)
            router = RequestRouter(fleet, policy=policy)
            rep = router.route([Request(r.rid, prompt=list(r.prompt),
                                        max_new_tokens=r.max_new_tokens)
                                for r in reqs])
            assert len(rep.completed) == 8
            # fleet makespan in decode steps: replicas drain concurrently,
            # so the slowest replica's measured step count is the drain
            # length.  Steps rather than clock_s — per-step wall time is
            # noisy under a contended CI host and can flip a marginal
            # seconds comparison, while the step count only depends on
            # the (deterministic) assignment each policy produced.
            makespan[policy] = max(r.engine.stats.decode_steps
                                   for r in fleet.live())
        assert makespan["least-queue-depth"] < makespan["round-robin"], \
            f"measured makespans (decode steps): {makespan}"


class TestElasticity:
    def test_remove_then_add_mid_load_loses_nothing(self, tiny):
        cfg, params = tiny
        wl = sample_requests("agentic", 10, seed=5, vocab_size=cfg.vocab_size,
                             prompt_cap=10, output_cap=5, time_scale=50.0)
        fleet = _fleet(tiny)
        router = RequestRouter(fleet)
        reqs = _requests(wl)
        report = router.route(reqs, actions=[
            (3, lambda rt: rt.remove_replica(0)),
            (6, lambda rt: rt.add_replica()),
        ])
        assert len(report.completed) == 10
        assert all(r.done for r in reqs)
        assert 0 not in fleet.replicas               # drained and reaped
        assert any(rep.rid >= 2 for rep in fleet.live())   # fresh replica
        # requeued requests went somewhere and finished
        snap = fleet.registry.snapshot()
        retired = snap["fleet_replicas_retired_total"]["series"][0]["value"]
        assert retired == 1

    def test_requeued_results_still_byte_identical(self, tiny):
        cfg, params = tiny
        wl = sample_requests("chatbot", 6, seed=7, vocab_size=cfg.vocab_size,
                             prompt_cap=8, output_cap=4, time_scale=50.0)
        fleet = _fleet(tiny)
        router = RequestRouter(fleet)
        report = router.route(_requests(wl),
                              actions=[(2, lambda rt: rt.remove_replica(0))])
        assert len(report.completed) == 6
        for w in wl.requests:
            eng = ServeEngine(cfg, params, max_batch=2, max_len=64,
                              plan="jit")
            done = eng.run([Request(w.rid, prompt=list(w.prompt),
                                    max_new_tokens=w.max_new_tokens)])
            assert report.tokens_by_rid[w.rid] == list(done[0].generated)

    def test_cannot_remove_last_serving_replica(self, tiny):
        fleet = _fleet(tiny)
        fleet.remove_replica(0)
        with pytest.raises(ValueError, match="last serving replica"):
            fleet.remove_replica(1)

    def test_plan_fleet_pins_model_axis(self):
        assert plan_fleet(8, tp=2).mesh_shape == (4, 2)
        assert plan_fleet(8, tp=2, lost=3).mesh_shape == (2, 2)
        assert plan_fleet(6, tp=1, lost=1).mesh_shape == (5, 1)
        with pytest.raises(ValueError, match="cannot hold"):
            plan_fleet(4, tp=4, lost=1)


class TestFleetMetrics:
    def test_aggregation_has_per_replica_labels(self, tiny):
        cfg, params = tiny
        wl = sample_requests("chatbot", 4, seed=1, vocab_size=cfg.vocab_size,
                             prompt_cap=8, output_cap=3, time_scale=100.0)
        fleet = _fleet(tiny)
        router = RequestRouter(fleet, policy="round-robin")
        router.route(_requests(wl))
        snap = fleet.snapshot()
        agg = snap["fleet"]
        for fam in ("fleet_engine_tokens_out",
                    "fleet_replica_queue_depth",
                    "fleet_replica_clock_seconds",
                    "fleet_replicas", "router_dispatches_total",
                    "router_completed_total",
                    "router_token_events_total", "router_queue_depth"):
            assert fam in agg, f"missing family {fam}"
        tok = {s["labels"]["replica"]: s["value"]
               for s in agg["fleet_engine_tokens_out"]["series"]}
        assert set(tok) == {"0", "1"} and all(v > 0 for v in tok.values())
        done = agg["router_completed_total"]["series"][0]["value"]
        assert done == 4
        disp = {s["labels"]["replica"]: s["value"]
                for s in agg["router_dispatches_total"]["series"]}
        assert sum(disp.values()) == 4
        assert set(snap["replicas"]) == {"0", "1"}
        assert "engine_tokens_out" in snap["replicas"]["0"]

    def test_route_with_no_serving_replica_raises(self, tiny):
        fleet = _fleet(tiny)
        fleet.remove_replica(0)
        # drain the survivor too, bypassing the guard, to simulate a bug
        fleet.replicas[1].state = "draining"
        router = RequestRouter(fleet)
        with pytest.raises(RuntimeError, match="no serving replica"):
            router.route([Request(0, prompt=[1, 2], max_new_tokens=1)])

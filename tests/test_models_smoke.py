"""Per-architecture reduced-config smoke tests: one forward + one train
step on CPU, asserting output shapes and finiteness (assignment req)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, PAPER_WORKLOADS, get_config, reduced
from repro.models import forward, init_params, loss_fn, make_cache


def _inputs(cfg, key, B=2, S=16):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    kwargs = {}
    if cfg.n_encoder_layers:
        kwargs["encoder_tokens"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model))
        batch["encoder_tokens"] = kwargs["encoder_tokens"]
    if cfg.frontend == "vision_patches":
        kwargs["frontend_embeds"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model))
        batch["frontend_embeds"] = kwargs["frontend_embeds"]
    return batch, kwargs


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_smoke(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch, kwargs = _inputs(cfg, key)
    logits, aux, _ = forward(params, batch["tokens"], cfg, **kwargs)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    batch, _ = _inputs(cfg, key)
    (loss, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch, cfg)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(loss)) and float(gnorm) > 0
    assert np.isfinite(float(gnorm))


@pytest.mark.parametrize("arch", PAPER_WORKLOADS)
def test_paper_workloads_forward(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    logits, _, _ = forward(params, tokens, cfg)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ["internlm2-20b", "gemma2-27b", "rwkv6-3b",
                                  "jamba-1.5-large-398b",
                                  "llama-3.2-vision-11b",
                                  "seamless-m4t-medium"])
def test_decode_matches_full_forward(arch):
    """Prefill+decode through the KV cache == one full forward."""
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    B, S = 2, 17
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    _, kwargs = _inputs(cfg, key, B=B, S=S)
    src = max(cfg.n_frontend_tokens, 1)
    full, _, _ = forward(params, tokens, cfg, **kwargs)
    cache = make_cache(cfg, B, S, src_len=src)
    _, _, cache = forward(params, tokens[:, :S - 1], cfg, cache=cache,
                          cache_index=jnp.zeros((), jnp.int32), **kwargs)
    dec, _, _ = forward(params, tokens[:, S - 1:], cfg, cache=cache,
                        cache_index=jnp.asarray(S - 1, jnp.int32))
    err = np.max(np.abs(np.asarray(full[:, -1]) - np.asarray(dec[:, 0])))
    assert err < 2e-3, err


def test_chunked_loss_matches_full():
    cfg = reduced(get_config("gemma2-27b"))
    key = jax.random.PRNGKey(4)
    params = init_params(key, cfg)
    batch, _ = _inputs(cfg, key)
    l1, _ = loss_fn(params, batch, cfg, loss_chunks=1)
    l2, _ = loss_fn(params, batch, cfg, loss_chunks=4)
    assert abs(float(l1) - float(l2)) < 1e-4


def test_unroll_matches_scan():
    cfg = reduced(get_config("internlm2-20b"))
    key = jax.random.PRNGKey(5)
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    a, _, _ = forward(params, tokens, cfg, unroll=False)
    b, _, _ = forward(params, tokens, cfg, unroll=True)
    assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-5)

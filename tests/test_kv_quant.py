"""Quantized KV cache: per-(token, head) int8 quantization properties
(round-trip bound, degenerate inputs, jit dtype stability), quantized
paged Pallas kernel vs oracles, quantized forward/engine tolerance vs the
bf16 paged path, and the byte-budget capacity gain the quantization buys
(admission capacity / pool utilization acceptance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config, reduced
from repro.inference.engine import Request, ServeEngine
from repro.inference.kv_quant import (KV_DTYPES, capacity_ratio,
                                      dequantize_kv, kv_entry_bytes,
                                      make_quantized_cache, quantize_kv,
                                      read_kv, write_kv)
from repro.kernels.decode_attention.ops import paged_decode_attention
from repro.kernels.decode_attention.ref import (
    paged_decode_attention_quant_ref, paged_decode_attention_ref)
from repro.kvcache import default_num_blocks
from repro.models import forward, init_params, make_paged_cache
from repro.telemetry.characterize import memory_pressure_sweep

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("smollm-360m"), n_layers=2)
    params = init_params(KEY, cfg)
    return cfg, params


# ------------------------------------------------------------ quant math
def test_entry_bytes_and_capacity_ratio():
    assert kv_entry_bytes(64) == 128
    assert kv_entry_bytes(64, "int8") == 68
    assert capacity_ratio(64) == pytest.approx(128 / 68)
    # the ratio grows toward 2x as hd grows (the 4-byte scale amortizes)
    assert capacity_ratio(16) < capacity_ratio(64) < capacity_ratio(256) < 2
    with pytest.raises(ValueError):
        kv_entry_bytes(64, "fp8")


def _roundtrip_bound(x):
    """Round-trip |x - deq(quant(x))| <= scale/2 element-wise (symmetric
    rounding), with scale the per-(token, head) row scale."""
    q, scale = quantize_kv(x)
    back = dequantize_kv(q, scale, jnp.float32)
    err = np.abs(np.asarray(x, np.float32) - np.asarray(back))
    bound = np.asarray(scale)[..., None] / 2 + 1e-7
    assert (err <= bound).all(), (err.max(), bound.min())


def test_quant_roundtrip_bound_seeded():
    for i, shape in enumerate([(8, 16), (2, 5, 3, 32), (1, 64)]):
        x = jax.random.normal(jax.random.PRNGKey(i), shape) * (10.0 ** i)
        _roundtrip_bound(x)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(min_value=-1e4, max_value=1e4,
                          allow_nan=False, width=32),
                min_size=4, max_size=64))
def test_quant_roundtrip_bound_property(row):
    _roundtrip_bound(jnp.asarray([row], jnp.float32))


def test_quant_zero_rows_exact():
    """All-zero rows must quantize to exact zeros (scale floors at 1e-8,
    never divides by zero) — zero-filled fresh cache pages stay zero."""
    q, scale = quantize_kv(jnp.zeros((3, 4, 16)))
    assert np.asarray(q).dtype == np.int8 and not np.asarray(q).any()
    assert (np.asarray(scale) > 0).all()
    back = dequantize_kv(q, scale, jnp.float32)
    np.testing.assert_array_equal(np.asarray(back), 0.0)


def test_quant_denormal_rows_bounded():
    """Sub-floor magnitudes (denormal-scale inputs) hit the 1e-8 scale
    floor: they round to zero payloads with error below the floor."""
    x = jnp.full((2, 8), 1e-30, jnp.float32)
    q, scale = quantize_kv(x)
    assert not np.asarray(q).any()
    assert np.asarray(scale) == pytest.approx(1e-8 / 127.0)
    _roundtrip_bound(x)


def test_quant_dtype_stability_under_jit():
    x = jax.random.normal(KEY, (4, 3, 16), jnp.bfloat16)
    qe, se = quantize_kv(x)
    qj, sj = jax.jit(quantize_kv)(x)
    assert qj.dtype == qe.dtype == jnp.int8
    assert sj.dtype == se.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(qe), np.asarray(qj))
    np.testing.assert_array_equal(np.asarray(se), np.asarray(sj))
    for dt in (jnp.bfloat16, jnp.float32):
        assert dequantize_kv(qe, se, dt).dtype == dt
        assert jax.jit(dequantize_kv, static_argnums=2)(qe, se, dt).dtype \
            == dt


def test_write_read_roundtrip_contiguous_helper():
    cache = make_quantized_cache(2, 8, 3, 16)
    k = jax.random.normal(KEY, (2, 4, 3, 16))
    v = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 3, 16))
    cache = write_kv(cache, k, v, 2)
    kb, vb = read_kv(cache, jnp.float32)
    _, sk = quantize_kv(k)
    err = np.abs(np.asarray(k) - np.asarray(kb[:, 2:6]))
    assert (err <= np.asarray(sk)[..., None] / 2 + 1e-7).all()
    assert not np.asarray(kb[:, :2]).any() and not np.asarray(vb[:, 6:]).any()


# ------------------------------------------------------------ kernel
def _quant_pool(b, hq, hkv, t, hd, bs, seed=0):
    n_pages = 2 * (b * t // bs)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, hd))
    k = jax.random.normal(ks[1], (b, hkv, t, hd))
    v = jax.random.normal(ks[2], (b, hkv, t, hd))
    lens = np.array([t - 3 * i for i in range(b)], np.int32)
    # pack contiguous rows into pool pages (identity layout is fine here;
    # table-steering is covered by the bf16 kernel tests)
    nb = t // bs
    kp = np.zeros((n_pages, bs, hkv, hd), np.float32)
    vp = np.zeros((n_pages, bs, hkv, hd), np.float32)
    tables = np.full((b, nb), n_pages + 3, np.int32)
    nxt = 0
    for row in range(b):
        for i in range(nb):
            tables[row, i] = nxt
            kp[nxt] = np.asarray(k[row, :, i * bs:(i + 1) * bs]).transpose(
                1, 0, 2)
            vp[nxt] = np.asarray(v[row, :, i * bs:(i + 1) * bs]).transpose(
                1, 0, 2)
            nxt += 1
    qk, sk = quantize_kv(jnp.asarray(kp))
    qv, sv = quantize_kv(jnp.asarray(vp))
    return (q, jnp.asarray(kp), jnp.asarray(vp), qk, sk, qv, sv,
            jnp.asarray(tables), jnp.asarray(lens))


@pytest.mark.parametrize("shape,bs", [
    ((2, 6, 2, 32, 32), 8),            # GQA g=3
    ((1, 4, 4, 64, 16), 16),           # MHA, hd=16 (pads to 128)
])
def test_quant_paged_kernel_matches_quant_ref(shape, bs):
    b, hq, hkv, t, hd = shape
    q, _, _, qk, sk, qv, sv, tables, lens = _quant_pool(b, hq, hkv, t, hd,
                                                        bs)
    o = paged_decode_attention(q, qk, qv, tables, lens, scale=0.2,
                               k_scale=sk, v_scale=sv)
    r = paged_decode_attention_quant_ref(q, qk, qv, sk, sv, tables, lens,
                                         scale=0.2)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               atol=2e-5, rtol=2e-5)


def test_quant_paged_kernel_tolerance_vs_fp_oracle():
    b, hq, hkv, t, hd, bs = 2, 6, 2, 32, 32, 8
    q, kp, vp, qk, sk, qv, sv, tables, lens = _quant_pool(b, hq, hkv, t,
                                                          hd, bs)
    o = paged_decode_attention(q, qk, qv, tables, lens, scale=0.2,
                               k_scale=sk, v_scale=sv)
    fp = paged_decode_attention_ref(q, kp, vp, tables, lens, scale=0.2)
    # stated decode tolerance of the int8 path vs the exact fp pool: the
    # softmax mix of <=scale/2 per-element dequant error stays well under
    # 5e-2 for unit-normal KV
    err = np.abs(np.asarray(o) - np.asarray(fp)).max()
    assert err < 5e-2, err
    # and the unquantized call on the SAME wrapper is unaffected
    o_fp = paged_decode_attention(q, kp, vp, tables, lens, scale=0.2)
    np.testing.assert_allclose(np.asarray(o_fp), np.asarray(fp),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ forward
def test_forward_quantized_paged_tolerance(small_model):
    """Chunked prefill + one decode step through an int8 paged cache stay
    within a stated max-abs logits tolerance of the bf16 paged path."""
    cfg, params = small_model
    b, max_len, bs = 2, 32, 8
    pool = b * (max_len // bs)
    prompts = [[5, 9, 2, 7, 1], [3, 8, 4, 4, 6, 2, 9, 1, 5]]
    tol = 5e-2

    logits = {}
    for kv_dtype in KV_DTYPES:
        pcache = make_paged_cache(cfg, pool, bs, dtype=cfg.cdtype,
                                  kv_dtype=kv_dtype)
        layer0 = next(iter(pcache.values()))["self"]
        assert ("k_scale" in layer0) == (kv_dtype == "int8")
        tables = np.full((b, max_len // bs), pool + 5, np.int32)
        free = list(range(pool))
        outs = []
        for i, p in enumerate(prompts):
            n = -(-len(p) // bs)
            tables[i, :n] = [free.pop(0) for _ in range(n)]
            lg, _, pcache = forward(
                params, jnp.asarray([p]), cfg, cache=pcache,
                cache_index=jnp.zeros((), jnp.int32),
                block_tables=jnp.asarray(tables[i:i + 1]))
            outs.append(np.asarray(lg[0, -1], np.float32))
        lengths = jnp.asarray([len(p) for p in prompts], jnp.int32)
        toks = jnp.asarray([[int(o.argmax())] for o in outs], jnp.int32)
        lg, _, _ = forward(params, toks, cfg, cache=pcache, lengths=lengths,
                           block_tables=jnp.asarray(tables))
        logits[kv_dtype] = (outs, np.asarray(lg, np.float32))

    for (pf_b, dec_b), (pf_q, dec_q) in [(logits["bf16"], logits["int8"])]:
        for a, bq in zip(pf_b, pf_q):
            assert np.abs(a - bq).max() < tol
        assert np.abs(dec_b - dec_q).max() < tol


# ------------------------------------------------------------ capacity
def test_default_num_blocks_dtype_aware():
    base = default_num_blocks(4, 64, 16)
    assert base == 16
    # explicit pool wins regardless of dtype
    assert default_num_blocks(4, 64, 16, num_blocks=5, kv_dtype="int8",
                              hd=64) == 5
    # int8 grows the default by payload_bytes*hd/(hd+4)
    got = default_num_blocks(4, 64, 16, kv_dtype="int8", hd=64,
                             payload_bytes=2)
    assert got == int(16 * 128 / 68)
    assert default_num_blocks(4, 64, 16, kv_dtype="int8", hd=16,
                              payload_bytes=4) == int(16 * 64 / 20)
    # no hd -> no byte math possible, stay at base
    assert default_num_blocks(4, 64, 16, kv_dtype="int8") == base


def test_int8_admission_capacity_acceptance(small_model):
    """Acceptance: at the same device byte budget the int8 pool holds
    >= 1.8x the blocks (so admits >= 1.8x the concurrent sequences), and
    serving the same workload at fixed admission uses at most ~half the
    pool."""
    cfg, params = small_model
    sweep = memory_pressure_sweep(
        cfg, params, scenario="summarization", platforms=("GH200",),
        pool_fracs=(1.0,), kv_dtypes=("bf16", "int8"), max_batch=2,
        max_len=32, block_size=4, n_requests=4, seed=0, prompt_cap=12,
        output_cap=6)
    bf16, int8 = sweep["points"]
    assert bf16["kv_dtype"] == "bf16" and int8["kv_dtype"] == "int8"
    # same byte budget, >= 1.8x the block capacity
    assert int8["num_blocks"] * int8["block_bytes"] <= \
        bf16["num_blocks"] * bf16["block_bytes"]
    ratio = int8["num_blocks"] / bf16["num_blocks"]
    assert ratio >= 1.8, ratio
    assert sweep["kv_dtype_deltas"][0]["capacity_ratio"] >= 1.8
    # identical traffic served: at fixed admission the quantized pool
    # runs at <= ~half the utilization
    assert int8["tokens_out"] == bf16["tokens_out"]
    assert int8["peak_pool_utilization"] <= \
        0.56 * bf16["peak_pool_utilization"]


# ------------------------------------------------------------ engine
def test_engine_int8_paged_token_tolerance(small_model):
    """Engine-level: int8 serving completes the same workload with every
    request done; token streams agree with bf16 for this workload (greedy
    argmax is tolerance-stable here) and the default pool is bigger."""
    cfg, params = small_model
    rng = np.random.default_rng(3)
    reqs = lambda: [Request(i, prompt=[int(t) for t in
                                       rng2.integers(1, 100, 8 + 2 * i)],
                            max_new_tokens=5) for i in range(4)]
    outs = {}
    pools = {}
    for dt in KV_DTYPES:
        rng2 = np.random.default_rng(3)
        eng = ServeEngine(cfg, params, max_batch=2, max_len=32,
                          cache="paged", block_size=8, kv_dtype=dt)
        outs[dt] = {r.rid: list(r.generated) for r in eng.run(reqs())}
        pools[dt] = eng.kv.num_blocks
        assert all(len(v) == 5 for v in outs[dt].values())
    assert pools["int8"] / pools["bf16"] >= 1.8
    assert outs["bf16"] == outs["int8"]


def test_engine_rejects_bad_kv_config(small_model):
    cfg, params = small_model
    with pytest.raises(ValueError, match="kv_dtype"):
        ServeEngine(cfg, params, cache="paged", kv_dtype="fp8")
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, kv_dtype="int8")
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, share_prefix=True)
    with pytest.raises(ValueError, match="prefix_len"):
        ServeEngine(cfg, params, cache="paged", share_prefix=True,
                    prefix_len=0)

"""Pipeline parallelism, int8 KV cache, and trace export."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.core.device_model import PLATFORMS, simulate
from repro.core.export import to_chrome_trace
from repro.core.tracing import Kernel
from repro.inference.kv_quant import (
    make_quantized_cache, read_kv, write_kv)


def _run_sub(code: str, devices: int = 4) -> str:
    # forcing a host-platform device count only works on the CPU backend;
    # on an accelerator backend we need that many real devices
    if jax.default_backend() != "cpu" and jax.device_count() < devices:
        pytest.skip(f"needs {devices} devices, have {jax.device_count()} "
                    f"on backend {jax.default_backend()!r}")
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu", "PATH": "/usr/bin:/bin"}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         cwd="/root/repo", timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ------------------------------------------------------------ pipeline
def test_pipeline_matches_sequential_multidevice():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline import pipeline_forward, reference_forward
    P, n_micro, mb, d = 4, 6, 2, 8
    mesh = jax.make_mesh((P,), ("pipe",))
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (P, d, d)) * 0.3,
              "b": jax.random.normal(key, (P, d)) * 0.1}
    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
    stage_fn = lambda p, x_: jnp.tanh(x_ @ p["w"] + p["b"])
    y = pipeline_forward(stage_fn, params, x, mesh)
    ref = reference_forward(stage_fn, params, x)
    err = float(jnp.max(jnp.abs(y - ref)))
    print("pp err", err)
    assert err < 1e-5
    print("PP_OK")
    """
    assert "PP_OK" in _run_sub(code)


def test_pipeline_single_stage_degenerates():
    code = """
    import jax, jax.numpy as jnp
    from repro.distributed.pipeline import pipeline_forward, reference_forward
    mesh = jax.make_mesh((1,), ("pipe",))
    params = {"w": jnp.eye(4)[None] * 2.0}
    x = jnp.ones((3, 2, 4))
    y = pipeline_forward(lambda p, x_: x_ @ p["w"], params, x, mesh)
    assert jnp.allclose(y, 2 * x)
    print("PP1_OK")
    """
    assert "PP1_OK" in _run_sub(code, devices=1)


# ------------------------------------------------------------ int8 KV
def test_kv_quant_roundtrip_accuracy():
    key = jax.random.PRNGKey(0)
    k = jax.random.normal(key, (2, 8, 4, 16))
    cache = make_quantized_cache(2, 32, 4, 16)
    cache = write_kv(cache, k, k * 0.5, jnp.asarray(0, jnp.int32))
    kd, vd = read_kv(cache, jnp.float32)
    rel = float(jnp.max(jnp.abs(kd[:, :8] - k)) / jnp.max(jnp.abs(k)))
    assert rel < 0.02, rel           # int8 symmetric: <2% relative error


def test_kv_quant_attention_close_to_fp():
    """Decode attention over an int8 cache matches the fp cache closely."""
    from repro.kernels.decode_attention.ref import decode_attention_ref
    key = jax.random.PRNGKey(1)
    B, H, T, hd = 2, 4, 32, 16
    q = jax.random.normal(key, (B, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, T, H, hd))
    cache = make_quantized_cache(B, T, H, hd)
    cache = write_kv(cache, k, v, jnp.asarray(0, jnp.int32))
    kd, vd = read_kv(cache, jnp.float32)
    o_q = decode_attention_ref(q, kd.transpose(0, 2, 1, 3),
                               vd.transpose(0, 2, 1, 3), T, scale=0.25)
    o_f = decode_attention_ref(q, k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), T, scale=0.25)
    assert float(jnp.max(jnp.abs(o_q - o_f))) < 0.05


# ------------------------------------------------------------ export
def test_chrome_trace_export(tmp_path):
    ks = [Kernel(i, f"k{i}", None, 1e6, 1e5, ()) for i in range(5)]
    ev = simulate(ks, PLATFORMS["GH200"])
    doc = to_chrome_trace(ev, "GH200")
    # host + kernel slice plus an s/f flow pair per kernel
    assert len(doc["traceEvents"]) == 20
    host = [e for e in doc["traceEvents"]
            if e["tid"] == 0 and e["ph"] == "X"]
    dev = [e for e in doc["traceEvents"]
           if e["tid"] == 1 and e["ph"] == "X"]
    assert len(host) == len(dev) == 5
    # device events never start before their launch call
    for h, d in zip(host, dev):
        assert d["ts"] >= h["ts"]
    json.dumps(doc)                  # serializable

"""MoE: router math, dispatch exactness vs dense reference, capacity
dropping semantics, shared experts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.layers.moe import (
    capacity, dispatch_slots, moe_dense_fwd, moe_init, moe_local_fwd, route)


def _cfg(cf=8.0, shared=0, top_k=2, experts=4):
    cfg = reduced(get_config("moonshot-v1-16b-a3b"))
    return cfg.replace(moe=dataclasses.replace(
        cfg.moe, capacity_factor=cf, n_shared_experts=shared,
        top_k=top_k, n_experts=experts))


def test_local_matches_dense_no_drops():
    cfg = _cfg(cf=8.0, shared=1)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    yd, aux_d = moe_dense_fwd(params, x, cfg)
    yl, aux_l = moe_local_fwd(params, x, cfg)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yl),
                               atol=1e-5, rtol=1e-5)
    assert abs(float(aux_d) - float(aux_l)) < 1e-6


def test_router_gates_normalized():
    cfg = _cfg()
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    gates, eids, aux = route(x, params["router"], cfg)
    np.testing.assert_allclose(np.asarray(jnp.sum(gates, -1)), 1.0,
                               atol=1e-6)
    assert float(aux) >= 1.0 - 1e-3   # aux >= 1 at uniform; > under skew


def test_dispatch_slots_unique_and_capped():
    eids = jnp.asarray([[0, 1], [0, 1], [0, 2], [0, 2], [3, 0]], jnp.int32)
    cap = 8
    slot, keep = dispatch_slots(eids, 4, cap)
    kept = np.asarray(slot)[np.asarray(keep)]
    assert len(set(kept.tolist())) == len(kept)      # no collisions
    assert (kept < 4 * cap).all()
    # expert 0 appears 5 times; with cap 2 only 2 kept
    slot2, keep2 = dispatch_slots(eids, 4, 2)
    e0 = [s for s, k in zip(np.asarray(slot2).tolist(),
                            np.asarray(keep2).tolist())
          if k and s < 2]
    assert len(e0) == 2


def test_capacity_formula():
    cfg = _cfg(cf=1.25, top_k=2, experts=4)
    c = capacity(64, cfg)
    assert c >= 64 * 2 * 1.25 / 4
    assert c % 8 == 0


def test_drops_occur_at_low_capacity():
    cfg = _cfg(cf=0.25)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    yd, _ = moe_dense_fwd(params, x, cfg)
    yl, _ = moe_local_fwd(params, x, cfg)
    # dropping must change the result (tokens silently skipped)
    assert float(jnp.max(jnp.abs(yd - yl))) > 1e-4


def test_moe_grads_flow_to_router():
    cfg = _cfg()
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))

    def loss(p):
        y, aux = moe_local_fwd(p, x, cfg)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    assert float(jnp.max(jnp.abs(g["router"]))) > 0
    assert float(jnp.max(jnp.abs(g["w_in"]))) > 0

"""SKIP profiler: tracing exactness, queue-sim invariants, TKLQT closed
forms, boundedness inflection, proximity mining (Eqs. 6-8), chain-jit."""
import jax
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.boundedness import find_inflection
from repro.core.device_model import PlatformSpec, simulate
from repro.core.metrics import report
from repro.core.proximity import fusion_segments, mine_chains
from repro.core.skip import SKIP
from repro.core.tracing import Executor, Kernel, trace_fn


def _toy_fn(x, w1, w2):
    h = jax.nn.gelu(x @ w1)
    h = jax.jit(lambda a: a * 2 + 1)(h)        # nested jit gets inlined
    return jax.nn.softmax(h @ w2, axis=-1)


def _toy_args():
    key = jax.random.PRNGKey(0)
    return (jax.random.normal(key, (4, 8)),
            jax.random.normal(key, (8, 16)),
            jax.random.normal(key, (16, 8)))


# ------------------------------------------------------------ tracing
def test_trace_and_eager_execution_match():
    args = _toy_args()
    tr = trace_fn(_toy_fn, *args)
    assert len(tr.kernels) > 10
    out, _ = Executor(tr).run(*args)
    np.testing.assert_allclose(np.asarray(out[-1]),
                               np.asarray(_toy_fn(*args)), atol=1e-6)


def test_fused_segments_match_eager():
    args = _toy_args()
    tr = trace_fn(_toy_fn, *args)
    n = len(tr.kernels)
    eager, _ = Executor(tr).run(*args)
    for segs in ([[i] for i in range(n)],
                 [list(range(n))],
                 [list(range(n // 2)), list(range(n // 2, n))]):
        out, _ = Executor(tr, segments=segs).run(*args)
        if len(segs) == n:
            # per-eqn segments dispatch the same executables: bit-identical
            np.testing.assert_array_equal(np.asarray(out[-1]),
                                          np.asarray(eager[-1]))
        else:
            # XLA may fuse within a multi-eqn segment and change rounding
            np.testing.assert_allclose(np.asarray(out[-1]),
                                       np.asarray(eager[-1]), atol=1e-6)


def test_nested_jit_inlined():
    args = _toy_args()
    tr = trace_fn(_toy_fn, *args)
    assert "pjit" not in tr.kernel_names and "jit" not in tr.kernel_names


# ------------------------------------------------------------ queue sim
def _kernels(n, flops, bts):
    return [Kernel(i, f"k{i}", None, flops, bts, ()) for i in range(n)]


def test_tklqt_cpu_bound_closed_form():
    """Tiny kernels, no queuing: TKLQT == n * launch overhead exactly."""
    plat = PlatformSpec("T", "LC", 1000.0, 0.0, 1e15, 1e15,
                        op_tax_ns=0.0, mxu_efficiency=1.0, bw_efficiency=1.0)
    ks = _kernels(10, flops=1.0, bts=1.0)
    ev = simulate(ks, plat)
    rep = report(ev, "T", 1000e-9)
    assert abs(rep.tklqt - 10 * 1000e-9) < 1e-12
    assert rep.queue_share == 0.0


def test_tklqt_gpu_bound_queuing():
    """Huge kernels: queuing dominates, TKLQT >> n * launch."""
    plat = PlatformSpec("T", "LC", 1000.0, 0.0, 1e12, 1e15,
                        op_tax_ns=0.0, mxu_efficiency=1.0, bw_efficiency=1.0)
    ks = _kernels(10, flops=1e9, bts=1.0)   # 1 ms per kernel
    ev = simulate(ks, plat)
    rep = report(ev, "T", 1000e-9)
    assert rep.tklqt > 10 * 1000e-9 * 100
    assert rep.queue_share > 0.9


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 30),
       flops=st.floats(1.0, 1e10),
       launch_ns=st.floats(100.0, 5000.0))
def test_queue_sim_invariants(n, flops, launch_ns):
    """Kernel start >= launch end; in-order; busy + idle == IL."""
    plat = PlatformSpec("T", "LC", launch_ns, 100.0, 1e12, 1e12,
                        op_tax_ns=0.0, mxu_efficiency=1.0, bw_efficiency=1.0)
    ev = simulate(_kernels(n, flops, flops), plat)
    for e in ev:
        assert e.kernel_start >= e.launch_end - 1e-15
        assert e.t_l >= 0 and e.duration > 0
    for a, b in zip(ev, ev[1:]):
        assert b.kernel_start >= a.kernel_end - 1e-15   # in-order stream
    rep = report(ev, "T", launch_ns * 1e-9)
    assert rep.gpu_idle >= -1e-12
    total_busy = sum(e.duration for e in ev)
    assert abs((rep.gpu_idle + total_busy) - rep.il) < 1e-12


# ------------------------------------------------------------ boundedness
def test_inflection_detection():
    assert find_inflection([1, 2, 4, 8], [1.0, 1.0, 1.1, 2.0]) == 8
    assert find_inflection([1, 2, 4, 8], [1.0, 1.0, 1.1, 1.2]) is None
    assert find_inflection([1, 2, 4], [1.0, 2.0, 4.0]) == 2


# ------------------------------------------------------------ proximity
def test_proximity_score_exact():
    seq = ["a", "b", "c"] * 10 + ["a", "x"]
    res = mine_chains(seq, 2, threshold=0.0)
    by_chain = {c.chain: c for c in res.candidates}
    # f(("a","b")) = 10, f("a") = 11 -> PS = 10/11
    assert by_chain[("a", "b")].frequency == 10
    assert abs(by_chain[("a", "b")].ps - 10 / 11) < 1e-12
    # ("b","c") is deterministic: f=10, f("b")=10 -> PS=1
    assert by_chain[("b", "c")].ps == 1.0


def test_eq7_eq8_exact():
    seq = ["a", "b", "c", "d"] * 8           # 32 kernels
    res = mine_chains(seq, 4, threshold=1.0)
    assert res.c_fused == 8
    assert res.k_fused == 32 - 8 * 3         # Eq. 7
    assert abs(res.speedup - 32 / 8) < 1e-12  # Eq. 8


def test_fusion_segments_cover():
    seq = ["a", "b", "a", "b", "x", "a", "b"]
    segs = fusion_segments(seq, 2)
    flat = [i for s in segs for i in s]
    assert flat == list(range(len(seq)))      # exact cover, in order


@settings(max_examples=20, deadline=None)
@given(st.lists(st.sampled_from("abcd"), min_size=4, max_size=60),
       st.sampled_from([2, 3, 4]))
def test_fusion_segments_property(seq, length):
    segs = fusion_segments(seq, length)
    flat = [i for s in segs for i in s]
    assert flat == list(range(len(seq)))
    res = mine_chains(seq, length, threshold=1.0)
    # segment count == Eq. 7 launch count
    assert len(segs) == res.k_fused


# ------------------------------------------------------------ skip facade
def test_skip_end_to_end():
    args = _toy_args()
    skip = SKIP.trace(_toy_fn, *args)
    rep = skip.report("GH200", batch=1)
    assert rep.tklqt > 0 and rep.il >= rep.tklqt * 0.5
    sweep, _ = skip.batch_sweep("GH200", batches=(1, 4, 16, 64))
    assert sweep.tklqt[0] <= sweep.tklqt[-1] + 1e-12
    out = skip.fuse(length=4, repeats=1)
    assert out.k_fused <= out.k_eager
    assert out.max_abs_err < 1e-5

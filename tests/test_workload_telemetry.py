"""Workload & telemetry subsystem: generator determinism, JSONL
record/replay round-trip, percentile aggregation vs numpy, measured-sweep
boundedness classification, degenerate find_inflection guards, and the
engine's per-request TTFT/ITL accounting."""
import json
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.boundedness import find_inflection
from repro.core.export import merged_chrome_trace
from repro.inference.engine import Request, ServeEngine
from repro.models import init_params
from repro.telemetry.characterize import (characterize,
                                          classify_measured_sweep)
from repro.telemetry.metrics import (RequestTiming, percentile, percentiles,
                                     summarize)
from repro.telemetry.spans import SpanRecorder
from repro.workload import (get_scenario, list_scenarios, load_workload,
                            sample_requests, save_workload)


# ------------------------------------------------------------ workload
def test_scenario_catalog_complete():
    names = list_scenarios()
    for expected in ("chatbot", "code-completion", "summarization",
                     "agentic"):
        assert expected in names
    with pytest.raises(KeyError):
        get_scenario("nope")


@pytest.mark.parametrize("scenario", ["chatbot", "code-completion",
                                      "summarization", "agentic"])
def test_generator_deterministic_under_seed(scenario):
    a = sample_requests(scenario, 12, seed=7, vocab_size=503)
    b = sample_requests(scenario, 12, seed=7, vocab_size=503)
    c = sample_requests(scenario, 12, seed=8, vocab_size=503)
    assert [r.to_json() for r in a.requests] == \
        [r.to_json() for r in b.requests]
    assert [r.to_json() for r in a.requests] != \
        [r.to_json() for r in c.requests]
    # arrivals are sorted; prompts within the vocab
    arr = [r.arrival_s for r in a.requests]
    assert arr == sorted(arr)
    assert all(0 <= t < 503 for r in a.requests for t in r.prompt)


def test_scenario_rejects_degenerate_params():
    from repro.workload.scenarios import LengthDist, Scenario
    dist = LengthDist("fixed", 4)
    with pytest.raises(ValueError, match="rate_rps"):
        Scenario("x", "", "poisson", dist, dist)          # rate_rps=0
    with pytest.raises(ValueError, match="burst_s"):
        Scenario("x", "", "bursty", dist, dist, rate_rps=1.0)
    with pytest.raises(ValueError, match="arrival"):
        Scenario("x", "", "warp", dist, dist)


def test_closed_loop_arrivals_all_zero():
    wl = sample_requests("summarization", 5, seed=0)
    assert all(r.arrival_s == 0.0 for r in wl.requests)


def test_bursty_arrivals_have_idle_gaps():
    sc = get_scenario("agentic")
    wl = sample_requests(sc, 32, seed=3)
    gaps = np.diff([r.arrival_s for r in wl.requests])
    # at least one inter-burst gap of ~idle_s must appear in 32 arrivals
    assert gaps.max() >= sc.idle_s


def test_time_scale_compresses_arrivals():
    slow = sample_requests("chatbot", 16, seed=0)
    fast = sample_requests("chatbot", 16, seed=0, time_scale=4.0)
    assert fast.requests[-1].arrival_s < slow.requests[-1].arrival_s
    with pytest.raises(ValueError):
        sample_requests("chatbot", 4, seed=0, time_scale=0.0)


def test_length_caps_apply():
    wl = sample_requests("summarization", 8, seed=0, prompt_cap=16,
                         output_cap=4)
    assert all(len(r.prompt) <= 16 for r in wl.requests)
    assert all(r.max_new_tokens <= 4 for r in wl.requests)


def test_record_replay_roundtrip_byte_identical(tmp_path):
    wl = sample_requests("chatbot", 9, seed=5, vocab_size=211)
    p1 = str(tmp_path / "wl.jsonl")
    p2 = str(tmp_path / "wl2.jsonl")
    save_workload(wl, p1)
    wl2 = load_workload(p1)
    save_workload(wl2, p2)
    assert open(p1, "rb").read() == open(p2, "rb").read()
    assert wl2.scenario == wl.scenario and wl2.seed == wl.seed
    assert [r.to_json() for r in wl2.requests] == \
        [r.to_json() for r in wl.requests]


def test_load_rejects_header_mismatch(tmp_path):
    p = str(tmp_path / "bad.jsonl")
    wl = sample_requests("chatbot", 3, seed=0)
    save_workload(wl, p)
    lines = open(p).read().splitlines()
    open(p, "w").write("\n".join(lines[:-1]) + "\n")  # drop one request
    with pytest.raises(ValueError):
        load_workload(p)


# ------------------------------------------------------------ metrics
def test_percentiles_match_numpy_reference():
    rng = np.random.default_rng(0)
    for vals in ([1.0], [3.0, 1.0, 2.0], list(rng.lognormal(size=101)),
                 list(rng.uniform(0, 1, size=40))):
        for q in (50, 95, 99, 0, 100, 12.5):
            np.testing.assert_allclose(
                percentile(vals, q), np.percentile(vals, q), rtol=1e-12)


def test_percentiles_empty_is_nan():
    assert math.isnan(percentile([], 50))
    assert all(math.isnan(v) for v in percentiles([]).values())


def test_request_timing_derived_metrics():
    t = RequestTiming(0, arrival_s=1.0, first_token_s=1.5, done_s=2.5,
                      token_times_s=[1.5, 2.0, 2.5])
    assert t.ttft_s == pytest.approx(0.5)
    assert t.e2e_s == pytest.approx(1.5)
    assert t.itl_s == pytest.approx([0.5, 0.5])
    s = summarize([t])
    assert s.ttft["p50"] == pytest.approx(0.5)
    assert s.mean_itl_s == pytest.approx(0.5)
    assert s.n_requests == 1


# ------------------------------------------------------------ spans
def test_span_recorder_disabled_records_nothing():
    rec = SpanRecorder(enabled=False)
    rec.add("x", "host", 0.0, 1.0)
    with rec.span("y"):
        pass
    assert rec.spans == []


def test_span_recorder_chrome_export():
    rec = SpanRecorder()
    rec.add("a", "decode", 0.0, 0.001, batch=2)
    rec.add("b", "dispatch", 0.0, 0.0005, tid=1)
    doc = merged_chrome_trace(rec.spans, "TPU-v5e")
    assert len(doc["traceEvents"]) == 2
    ev = doc["traceEvents"][0]
    assert ev["ts"] == 0.0 and ev["dur"] == pytest.approx(1000.0)
    assert ev["args"] == {"batch": 2}
    assert doc["metadata"]["platform"] == "TPU-v5e"
    # valid JSON end to end (Perfetto-loadable shape)
    json.dumps(doc)


def test_plan_executor_records_dispatch_spans():
    from repro.core.tracing import trace_fn
    from repro.runtime import LaunchPlan, PlanExecutor

    def f(x, w):
        return jax.nn.gelu(x @ w) * 2

    key = jax.random.PRNGKey(0)
    args = (jax.random.normal(key, (4, 8)), jax.random.normal(key, (8, 8)))
    tr = trace_fn(f, *args)
    rec = SpanRecorder()
    ex = PlanExecutor(tr, LaunchPlan.chain(tr.kernel_names, 2),
                      recorder=rec)
    ex.run(*args)
    assert len(rec.spans) == ex.n_launches
    assert all(s.cat == "dispatch" and s.tid == 1 for s in rec.spans)


# ------------------------------------------------------------ boundedness
def test_find_inflection_degenerate_cases():
    assert find_inflection([], []) is None
    assert find_inflection([1, 2], [1.0]) is None          # length mismatch
    assert find_inflection([1, 2, 4], [0.0, 1.0, 2.0]) is None   # zero base
    assert find_inflection([1, 2, 4], [1e-15, 1.0, 2.0]) is None  # near-zero
    assert find_inflection([1, 2, 4], [1.0, 1.1, 2.0]) == 4       # sane


def test_measured_sweep_agrees_with_classify_sweep():
    """classify_measured_sweep on a synthetic measured curve must agree
    with classify_sweep fed the same TKLQT values."""
    from repro.core.boundedness import classify_sweep

    class R:
        def __init__(self, t):
            self.tklqt = t
            self.queue_share = 0.0

    batches = [1, 2, 4, 8, 16]
    flat_then_rising = [1.0, 1.05, 1.1, 1.9, 3.9]
    measured = classify_measured_sweep(batches, flat_then_rising)
    modeled = classify_sweep(batches, [R(t) for t in flat_then_rising])
    assert measured.inflection_batch == modeled.inflection_batch == 8
    assert measured.classify(4) == modeled.classify(4) == "CPU-bound"
    assert measured.classify(8) == modeled.classify(8) == "GPU-bound"
    # always-flat curve: no inflection, CPU-bound everywhere
    flat = classify_measured_sweep(batches, [1.0] * 5)
    assert flat.inflection_batch is None


# ------------------------------------------------------------ engine+sweep
@pytest.fixture(scope="module")
def tiny_setup():
    cfg = reduced(get_config("smollm-360m"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_engine_reports_ttft_itl_and_telemetry(tiny_setup):
    cfg, params = tiny_setup
    rec = SpanRecorder()
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64, telemetry=rec)
    done = eng.run([Request(0, prompt=list(range(5, 13)), max_new_tokens=4),
                    Request(1, prompt=list(range(3, 9)), max_new_tokens=3,
                            arrival_s=0.001)])
    st = eng.stats
    assert len(done) == 2
    assert set(st.ttft_s) == {0, 1}
    assert all(t > 0 for t in st.ttft_s.values())
    assert st.mean_itl_s > 0 and len(st.itl_samples_s) > 0
    assert set(st.e2e_s) == {0, 1}
    # e2e covers ttft plus decoding
    assert st.e2e_s[0] >= st.ttft_s[0]
    assert st.measured_dispatch_s > 0
    cats = {s.cat for s in rec.spans}
    assert "prefill" in cats and "decode" in cats
    # spans sit on the engine's virtual clock
    assert all(0 <= s.t0 <= s.t1 <= eng.now for s in rec.spans)
    # per-request timings round-trip through the summary
    summary = summarize(list(eng.timings.values()))
    assert summary.n_requests == 2
    assert summary.ttft["p50"] > 0


def test_engine_rejects_zero_slots(tiny_setup):
    cfg, params = tiny_setup
    with pytest.raises(ValueError, match="max_batch"):
        ServeEngine(cfg, params, max_batch=0)


def test_engine_single_token_budget_exact(tiny_setup):
    cfg, params = tiny_setup
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64)
    done = eng.run([Request(0, prompt=[3, 4, 5], max_new_tokens=1)])
    assert len(done) == 1
    assert len(done[0].generated) == 1        # exactly the budget
    assert eng.stats.tokens_out == 1
    assert eng.stats.decode_steps == 0        # never occupied a slot
    assert eng.stats.e2e_s[0] == eng.stats.ttft_s[0]


def test_engine_open_loop_fast_forwards_idle(tiny_setup):
    cfg, params = tiny_setup
    eng = ServeEngine(cfg, params, max_batch=1, max_len=64)
    # second request arrives 100 virtual seconds later: the engine clock
    # must jump, not sleep — measured TTFT stays small for both
    done = eng.run([Request(0, prompt=[1, 2, 3, 4], max_new_tokens=2),
                    Request(1, prompt=[5, 6, 7, 8], max_new_tokens=2,
                            arrival_s=100.0)])
    assert len(done) == 2
    assert eng.now >= 100.0
    assert eng.stats.ttft_s[1] < 50.0   # did not wait out the gap


def test_engine_reset_keeps_plans_clears_state(tiny_setup):
    cfg, params = tiny_setup
    eng = ServeEngine(cfg, params, max_batch=1, max_len=64, plan="chain")
    eng.run([Request(0, prompt=list(range(4, 12)), max_new_tokens=3)])
    planned = eng._planned_decode
    assert planned is not None
    eng.reset()
    assert eng.stats.decode_steps == 0 and eng.timings == {}
    assert eng.now == 0.0
    assert eng._planned_decode is planned          # compiled plans survive
    done = eng.run([Request(0, prompt=list(range(4, 12)),
                            max_new_tokens=3)])
    assert len(done) == 1


def test_characterize_sweep_replay_and_artifacts(tiny_setup, tmp_path):
    cfg, params = tiny_setup
    res = characterize(cfg, params, scenario="chatbot", batches=(1, 2),
                       plan="chain", n_requests=3, seed=0, max_len=64,
                       output_cap=3, prompt_cap=10)
    assert [p.batch for p in res.points] == [1, 2]
    for p in res.points:
        assert p.latency.ttft["p50"] > 0
        assert p.launch_tax_per_step_s > 0
        assert p.dispatches_per_decode_step > 1     # planned, not jit
        assert p.modeled_events and p.decode_anchors
        assert res.boundedness.classify(p.batch) in ("CPU-bound",
                                                     "GPU-bound")
    s = res.summary()
    json.dumps(s)                                   # JSON-serializable
    assert s["scenario"] == "chatbot" and len(s["points"]) == 2

    # replaying the recorded workload reproduces the exact traffic
    p = str(tmp_path / "wl.jsonl")
    save_workload(res.workload, p)
    res2 = characterize(cfg, params, batches=(1,), plan="chain",
                        max_len=64, workload=load_workload(p))
    assert res2.workload.n == res.workload.n
    assert [r.prompt for r in res2.workload.requests] == \
        [r.prompt for r in res.workload.requests]


def test_characterize_rejects_vocab_mismatch_replay(tiny_setup):
    cfg, params = tiny_setup
    wl = sample_requests("chatbot", 2, seed=0,
                         vocab_size=cfg.vocab_size * 10)
    with pytest.raises(ValueError, match="vocab_size"):
        characterize(cfg, params, batches=(1,), workload=wl)

"""Optimizers, data pipeline, checkpoint manager, trainer FT loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, Pipeline, make_batch
from repro.training.loop import StragglerWatchdog, TrainConfig, Trainer
from repro.training.optim import (
    OptConfig, adafactor_init, adafactor_update, adamw_init, adamw_update,
    clip_by_global_norm, schedule)


# ------------------------------------------------------------ optimizers
@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(kind):
    w = {"a": jnp.asarray([3.0, -2.0]), "b": jnp.ones((4, 8)) * 2}
    cfg = OptConfig(kind=kind, lr=0.1, weight_decay=0.0, warmup_steps=0,
                    total_steps=100, min_lr_frac=1.0)
    init = adamw_init if kind == "adamw" else adafactor_init
    upd = adamw_update if kind == "adamw" else adafactor_update
    state = init(w)
    def loss(p):
        return sum(jnp.sum(x ** 2) for x in jax.tree.leaves(p))
    l0 = float(loss(w))
    for _ in range(50):
        g = jax.grad(loss)(w)
        w, state, _ = upd(cfg, g, state, w)
    assert float(loss(w)) < 0.05 * l0


def test_clip_preserves_dtype_and_norm():
    g = {"x": jnp.ones((1000,), jnp.bfloat16) * 10}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert clipped["x"].dtype == jnp.bfloat16
    from repro.training.optim import global_norm
    assert float(global_norm(clipped)) < 1.1


def test_schedule_warmup_and_decay():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-3
    assert float(schedule(cfg, jnp.asarray(100))) <= 0.11


# ------------------------------------------------------------ data
def test_data_deterministic_and_seekable():
    cfg = DataConfig(seed=7, batch=2, seq_len=16, vocab_size=100)
    b1 = make_batch(cfg, 5)
    b2 = make_batch(cfg, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    p = Pipeline(cfg, start_step=5)
    b3 = next(p)
    p.close()
    np.testing.assert_array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_and_integrity(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), async_write=False)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(3, tree)
    assert ckpt.latest_step() == 3
    restored = ckpt.restore(3, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16
    # corruption detection
    d = tmp_path / "step_00000003"
    victim = next(f for f in os.listdir(d) if f.endswith(".npy"))
    arr = np.load(d / victim)
    arr = arr.copy()
    arr.flat[0] += 1
    np.save(d / victim, arr)
    with pytest.raises(IOError):
        ckpt.restore(3, tree)


def test_checkpoint_gc_keeps_latest(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        ckpt.save(s, {"x": jnp.zeros(1)})
    assert ckpt.all_steps() == [3, 4]


# ------------------------------------------------------------ trainer FT
def test_trainer_failure_resume_exact(tmp_path):
    cfg = reduced(get_config("smollm-360m"), n_layers=2)
    data = DataConfig(batch=2, seq_len=32, vocab_size=cfg.vocab_size)

    def mk(fail):
        return Trainer(cfg, data,
                       TrainConfig(steps=12, ckpt_every=4,
                                   ckpt_dir=str(tmp_path),
                                   fail_at_step=fail))

    # uninterrupted run
    ref = mk(None).run()
    import shutil
    shutil.rmtree(tmp_path)
    # crash at 8, restart
    with pytest.raises(RuntimeError):
        mk(8).run()
    out = mk(None).run()
    assert out["final_step"] == 12
    # resumed training reaches the identical final loss (exact resume)
    assert abs(out["history"][-1]["loss"] - ref["history"][-1]["loss"]) < 1e-6


def test_straggler_watchdog():
    w = StragglerWatchdog(window=10, z=3.0)
    for i in range(8):
        assert not w.observe(i, 0.1 + 0.001 * (i % 2))
    assert w.observe(8, 5.0)        # 50x outlier flagged
    assert w.flagged[0][0] == 8

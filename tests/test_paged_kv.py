"""Paged KV-cache subsystem: allocator invariants, paged decode-attention
kernel vs oracles, paged forward vs contiguous forward, engine-level token
equivalence (plain / chunked prefill / preempt-recompute / preempt-offload),
the admit() overflow guard, and LC-vs-CC offload pricing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.device_model import PLATFORMS, offload_cost_s
from repro.inference.engine import Request, ServeEngine
from repro.kernels.decode_attention.ops import paged_decode_attention
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                paged_decode_attention_ref)
from repro.kvcache import BlockPool, HostOffloadTier, default_num_blocks
from repro.models import forward, init_params, make_cache, make_paged_cache
from repro.telemetry.characterize import memory_pressure_sweep

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("smollm-360m"), n_layers=2)
    params = init_params(KEY, cfg)
    return cfg, params


def _mk_requests(cfg, n=4, base_plen=7, max_new=5):
    rng = np.random.default_rng(0)
    return [Request(i, prompt=list(rng.integers(0, cfg.vocab_size,
                                                base_plen + 3 * i)),
                    max_new_tokens=max_new)
            for i in range(n)]


def _tokens(done):
    return {r.rid: list(r.generated) for r in done}


# ------------------------------------------------------------ allocator
def test_block_pool_alloc_free_invariants():
    pool = BlockPool(8, 4)
    a = pool.alloc("a", 3)
    assert a == [0, 1, 2] and pool.used_blocks == 3
    b = pool.alloc("b", 2)
    assert b == [3, 4] and pool.free_blocks == 3
    assert pool.blocks_for(9) == 3 and pool.blocks_for(8) == 2
    freed = pool.free("a")
    assert freed == [0, 1, 2] and pool.free_blocks == 6
    # lowest ids first, including recycled ones
    c = pool.alloc("c", 4)
    assert c == [0, 1, 2, 5]
    assert pool.owned("c") == [0, 1, 2, 5]
    with pytest.raises(MemoryError):
        pool.alloc("d", 3)
    assert pool.ensure("c", 16) == []          # already covered
    assert pool.utilization == pytest.approx(6 / 8)


def test_block_pool_table_row_and_validation():
    with pytest.raises(ValueError):
        BlockPool(0, 4)
    with pytest.raises(ValueError):
        BlockPool(4, 0)
    pool = BlockPool(4, 2)
    pool.alloc("x", 2)
    row = pool.table_row("x", 4, sentinel=99)
    assert list(row) == [0, 1, 99, 99]
    assert list(pool.table_row("ghost", 3, sentinel=7)) == [7, 7, 7]


def test_default_num_blocks():
    assert default_num_blocks(4, 64, 16) == 16    # 4 slots x 4 blocks
    assert default_num_blocks(4, 64, 16, num_blocks=5) == 5
    with pytest.raises(ValueError):
        default_num_blocks(4, 64, 16, num_blocks=0)


# ------------------------------------------------------------ kernel
def _scatter_pages(k, v, lens, bs, n_pages, seed=0):
    """Contiguous (B,HKV,T,hd) -> permuted pages + tables (np)."""
    b, hkv, t, hd = k.shape
    nb = t // bs
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_pages)
    tables = np.full((b, nb), n_pages + 3, np.int32)     # sentinel pad
    kp = np.zeros((n_pages, bs, hkv, hd), np.float32)
    vp = np.zeros((n_pages, bs, hkv, hd), np.float32)
    kn, vn = np.asarray(k), np.asarray(v)
    nxt = 0
    for row in range(b):
        for i in range(-(-int(lens[row]) // bs)):
            pg = int(perm[nxt])
            nxt += 1
            tables[row, i] = pg
            kp[pg] = kn[row, :, i * bs:(i + 1) * bs].transpose(1, 0, 2)
            vp[pg] = vn[row, :, i * bs:(i + 1) * bs].transpose(1, 0, 2)
    return jnp.asarray(kp), jnp.asarray(vp), tables


@pytest.mark.parametrize("shape,bs", [
    ((2, 6, 2, 32, 32), 8),            # GQA g=3
    ((1, 4, 4, 64, 16), 16),           # MHA, hd=16 (pads to 128)
    ((3, 8, 2, 128, 64), 32),          # wider pool
])
def test_paged_kernel_vs_refs(shape, bs):
    b, hq, hkv, t, hd = shape
    n_pages = 2 * (b * t // bs)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, hd))
    k = jax.random.normal(ks[1], (b, hkv, t, hd))
    v = jax.random.normal(ks[2], (b, hkv, t, hd))
    lens = np.array([t - 3 * i for i in range(b)], np.int32)
    kp, vp, tables = _scatter_pages(k, v, lens, bs, n_pages)
    tj, lj = jnp.asarray(tables), jnp.asarray(lens)
    o = paged_decode_attention(q, kp, vp, tj, lj, scale=0.2)
    r = paged_decode_attention_ref(q, kp, vp, tj, lj, scale=0.2)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               atol=2e-5, rtol=2e-5)
    # the paged path must agree with the CONTIGUOUS oracle row by row
    for row in range(b):
        rc = decode_attention_ref(q[row:row + 1], k[row:row + 1],
                                  v[row:row + 1], int(lens[row]), scale=0.2)
        np.testing.assert_allclose(np.asarray(o[row:row + 1]),
                                   np.asarray(rc), atol=2e-5, rtol=2e-5)


def test_paged_kernel_ignores_sentinel_table_entries():
    b, hq, hkv, t, hd, bs = 1, 2, 1, 32, 16, 8
    n_pages = 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, hd))
    k = jax.random.normal(ks[1], (b, hkv, t, hd))
    v = jax.random.normal(ks[2], (b, hkv, t, hd))
    lens = np.array([9], np.int32)                 # 2 of 4 pages valid
    kp, vp, tables = _scatter_pages(k, v, lens, bs, n_pages)
    o1 = paged_decode_attention(q, kp, vp, jnp.asarray(tables),
                                jnp.asarray(lens), scale=0.2)
    garbage = tables.copy()
    garbage[0, 2:] = [0, n_pages + 1000]           # valid-range AND huge ids
    o2 = paged_decode_attention(q, kp, vp, jnp.asarray(garbage),
                                jnp.asarray(lens), scale=0.2)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


# ------------------------------------------------------------ model forward
def test_make_paged_cache_rejects_non_attention():
    with pytest.raises(ValueError, match="pure-attention"):
        make_paged_cache(reduced(get_config("rwkv6-3b")), 8, 4)


def test_forward_paged_matches_contiguous(small_model):
    cfg, params = small_model
    b, max_len, bs = 2, 32, 8
    pool = b * (max_len // bs)
    prompts = [[5, 9, 2, 7, 1], [3, 8, 4, 4, 6, 2, 9, 1, 5]]

    cache = make_cache(cfg, b, max_len, src_len=1, dtype=cfg.cdtype)
    logits_c = []
    for i, p in enumerate(prompts):
        sub = jax.tree.map(
            lambda c: jnp.zeros_like(
                jax.lax.dynamic_slice_in_dim(c, i, 1, axis=1)), cache)
        lg, _, sub2 = forward(params, jnp.asarray([p]), cfg, cache=sub,
                              cache_index=jnp.zeros((), jnp.int32))
        cache = jax.tree.map(
            lambda c, s_: jax.lax.dynamic_update_slice_in_dim(
                c, s_.astype(c.dtype), i, axis=1), cache, sub2)
        logits_c.append(np.asarray(lg[0, len(p) - 1]))

    pcache = make_paged_cache(cfg, pool, bs, dtype=cfg.cdtype)
    tables = np.full((b, max_len // bs), pool + 5, np.int32)
    free = list(range(pool))
    logits_p = []
    for i, p in enumerate(prompts):     # chunked prefill, chunks of 4
        out, t0 = None, 0
        while t0 < len(p):
            chunk = p[t0:t0 + 4]
            while (tables[i] != pool + 5).sum() * bs < t0 + len(chunk):
                tables[i, (tables[i] != pool + 5).sum()] = free.pop(0)
            lg, _, pcache = forward(
                params, jnp.asarray([chunk]), cfg, cache=pcache,
                cache_index=jnp.asarray(t0, jnp.int32),
                block_tables=jnp.asarray(tables[i:i + 1]))
            out, t0 = lg[0, -1], t0 + len(chunk)
        logits_p.append(np.asarray(out))

    for lc, lp in zip(logits_c, logits_p):
        np.testing.assert_allclose(lc, lp, atol=1e-5, rtol=1e-5)

    # one batched decode step
    lengths = jnp.asarray([len(p) for p in prompts], jnp.int32)
    toks = jnp.asarray([[int(lg.argmax())] for lg in logits_c], jnp.int32)
    lg_c, _, _ = forward(params, toks, cfg, cache=cache, lengths=lengths)
    lg_p, _, _ = forward(params, toks, cfg, cache=pcache, lengths=lengths,
                         block_tables=jnp.asarray(tables))
    np.testing.assert_allclose(np.asarray(lg_c), np.asarray(lg_p),
                               atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------ engine
def test_engine_paged_matches_contiguous_tokens(small_model):
    cfg, params = small_model
    e1 = ServeEngine(cfg, params, max_batch=2, max_len=32)
    t1 = _tokens(e1.run(_mk_requests(cfg)))
    e2 = ServeEngine(cfg, params, max_batch=2, max_len=32,
                     cache="paged", block_size=8)
    t2 = _tokens(e2.run(_mk_requests(cfg)))
    assert t1 == t2
    assert e2.stats.preemptions == 0
    assert e2.stats.peak_block_pool_utilization > 0


def test_chunked_prefill_matches_unchunked(small_model):
    cfg, params = small_model
    whole = ServeEngine(cfg, params, max_batch=2, max_len=32,
                        cache="paged", block_size=8)
    t_whole = _tokens(whole.run(_mk_requests(cfg)))
    chunked = ServeEngine(cfg, params, max_batch=2, max_len=32,
                          cache="paged", block_size=8, prefill_chunk=4)
    t_chunk = _tokens(chunked.run(_mk_requests(cfg)))
    assert t_whole == t_chunk
    # the longest prompt (16 tokens) must have been split into 4 chunks
    assert chunked.stats.prefill_chunks > chunked.stats.prefills


@pytest.mark.parametrize("offload", ["none", "host"])
def test_preemption_resume_byte_identical(small_model, offload):
    """Satellite: exhaust the block pool, assert evicted requests resume
    and final tokens match an unconstrained run byte-for-byte."""
    cfg, params = small_model
    free = ServeEngine(cfg, params, max_batch=2, max_len=32,
                       cache="paged", block_size=4)
    t_free = _tokens(free.run(_mk_requests(cfg)))
    tight = ServeEngine(cfg, params, max_batch=2, max_len=32,
                        cache="paged", block_size=4, num_blocks=6,
                        offload=offload)
    done = tight.run(_mk_requests(cfg))
    assert _tokens(done) == t_free
    assert tight.stats.preemptions > 0
    assert all(r.status == "done" for r in done)
    if offload == "host":
        assert tight.stats.offload_bytes > 0
        assert tight.stats.offload_bytes == tight.stats.restore_bytes
        assert tight.stats.modeled_offload_tax_s > 0
    else:
        assert tight.stats.offload_bytes == 0


def test_paged_engine_reset_reproduces(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32,
                      cache="paged", block_size=4, num_blocks=6,
                      offload="host")
    t1 = _tokens(eng.run(_mk_requests(cfg)))
    eng.reset()
    assert eng.stats.preemptions == 0 and eng.kv.pool.used_blocks == 0
    t2 = _tokens(eng.run(_mk_requests(cfg)))
    assert t1 == t2


def test_decode_stall_during_prefill_contention_recovers(small_model):
    """A decode row that cannot grow while in-flight prefills hold the
    pool must stall and retry, not crash — only a true deadlock raises."""
    cfg, params = small_model
    reqs = dict(n=5, base_plen=6, max_new=6)
    free = ServeEngine(cfg, params, max_batch=3, max_len=32,
                       cache="paged", block_size=4)
    t_free = _tokens(free.run(_mk_requests(cfg, **reqs)))
    tight = ServeEngine(cfg, params, max_batch=3, max_len=32,
                        cache="paged", block_size=4, num_blocks=7,
                        prefill_chunk=3)
    done = tight.run(_mk_requests(cfg, **reqs))
    assert _tokens(done) == t_free
    assert all(r.status == "done" for r in done)


def test_pool_too_small_raises(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=1, max_len=32,
                      cache="paged", block_size=4, num_blocks=2)
    with pytest.raises(RuntimeError, match="pool"):
        eng.run(_mk_requests(cfg, n=1, base_plen=12, max_new=8))


# ------------------------------------------------------------ admit guard
@pytest.mark.parametrize("cache", ["contiguous", "paged"])
def test_admit_rejects_overflowing_budget(small_model, cache):
    """Satellite: plen + budget > max_len is rejected up front instead of
    risking out-of-bounds KV writes."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, cache=cache)
    bad = Request(0, prompt=list(range(1, 30)), max_new_tokens=16)  # 29+16
    ok = Request(1, prompt=list(range(1, 28)), max_new_tokens=5)    # 27+5=32
    done = eng.run([bad, ok])
    by_rid = {r.rid: r for r in done}
    assert by_rid[0].status == "rejected" and by_rid[0].generated == []
    assert by_rid[1].status == "done"
    assert len(by_rid[1].generated) == 5
    assert eng.stats.rejected == 1
    # the rejected request never touched a slot or the KV cache
    assert eng.stats.prefills == 1


# ------------------------------------------------------------ validation
def test_engine_rejects_bad_cache_config(small_model):
    cfg, params = small_model
    with pytest.raises(ValueError, match="cache"):
        ServeEngine(cfg, params, cache="virtual")
    with pytest.raises(ValueError, match="offload"):
        ServeEngine(cfg, params, cache="paged", offload="disk")
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServeEngine(cfg, params, cache="paged", prefill_chunk=0)
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, offload="host")
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, prefill_chunk=8)


# ------------------------------------------------------------ offload pricing
def test_offload_cost_lc_vs_cc():
    lc, cc = PLATFORMS["Intel+H100"], PLATFORMS["GH200"]
    nbytes = 1 << 20
    assert offload_cost_s(lc, nbytes) > offload_cost_s(cc, nbytes)
    assert offload_cost_s(lc, 0, transfers=2) == \
        pytest.approx(2 * lc.link_lat_s)
    with pytest.raises(ValueError):
        offload_cost_s(lc, -1)


def test_host_offload_tier_accounting():
    tier = HostOffloadTier("Intel+H100")
    leaves = [np.ones((2, 3, 4), np.float32)]
    nbytes, tax = tier.evict("r0", leaves, n_blocks=3)
    assert nbytes == leaves[0].nbytes and tier.holds("r0")
    assert tier.stored_blocks("r0") == 3
    assert tax == pytest.approx(
        offload_cost_s(tier.spec, nbytes, transfers=3))
    back, n_blocks, rbytes, rtax = tier.restore("r0")
    assert n_blocks == 3 and rbytes == nbytes and not tier.holds("r0")
    assert rtax > 0
    np.testing.assert_array_equal(back[0], leaves[0])
    assert tier.modeled_tax_s == pytest.approx(tax + rtax)
    tier.clear()
    assert tier.offload_bytes == 0


def test_memory_pressure_sweep_lc_vs_cc(small_model):
    """Acceptance: measured offload tax differs between an LC (PCIe) and
    CC (C2C) device model.  Closed-loop scenario -> identical traffic."""
    cfg, params = small_model
    sweep = memory_pressure_sweep(
        cfg, params, scenario="summarization", platforms=("AMD+A100",
                                                          "GH200"),
        pool_fracs=(0.4,), max_batch=2, max_len=32, block_size=4,
        n_requests=4, seed=0, prompt_cap=12, output_cap=6)
    lc, cc = sweep["points"]
    assert lc["coupling"] == "LC" and cc["coupling"] == "CC"
    assert lc["preemptions"] > 0
    # identical measured traffic (closed-loop determinism) ...
    assert lc["offload_bytes"] == cc["offload_bytes"] > 0
    assert lc["preemptions"] == cc["preemptions"]
    # ... but the LC link prices it much higher
    assert lc["modeled_offload_tax_us"] > 2 * cc["modeled_offload_tax_us"]


# ------------------------------------------------------------ refcounts / CoW
def test_block_pool_refcount_conservation_with_sharing():
    """alloc == free with sharing in between: every block physically
    freed exactly once, refcounts sum to the owned-list entries."""
    pool = BlockPool(8, 4)
    a = pool.alloc("a", 4)
    pool.adopt("b", a[:2])
    pool.adopt("c", a[:2])
    assert pool.ref_count(a[0]) == 3 and pool.ref_count(a[2]) == 1
    assert pool.shared_blocks == 2 and pool.extra_refs == 4
    total_refs = sum(pool.ref_count(i) for i in range(8))
    total_owned = sum(len(pool.owned(o)) for o in pool.owners())
    assert total_refs == total_owned == 8
    # the donor draining does NOT free shared blocks...
    assert pool.free("a") == a[2:]
    assert pool.used_blocks == 2 and pool.ref_count(a[0]) == 2
    # ...nor does the first sharer...
    assert pool.free("b") == []
    assert pool.shared_blocks == 0
    # ...only the LAST reference frees physically
    assert pool.free("c") == a[:2]
    assert pool.used_blocks == 0 and pool.free_blocks == 8


def test_block_pool_adopt_and_cow_validation():
    pool = BlockPool(4, 2)
    a = pool.alloc("a", 2)
    with pytest.raises(ValueError):
        pool.adopt("b", [3])               # free block: not adoptable
    pool.adopt("b", a)
    with pytest.raises(ValueError):
        pool.cow("b", 5)                   # no block at that index
    old, new = pool.cow("b", 0)
    assert old == a[0] and new not in a
    assert pool.owned("b") == [new, a[1]]
    assert pool.owned("a") == a            # donor list untouched
    assert pool.ref_count(old) == 1 and pool.ref_count(new) == 1
    assert pool.cow_copies_total == 1
    with pytest.raises(ValueError):
        pool.cow("b", 0)                   # private now: cow is a no-op
    pool.alloc("c", 1)                     # pool full
    with pytest.raises(MemoryError):
        pool.cow("b", 1)                   # a[1] still shared, no free block


def test_block_pool_trim_is_refcount_aware():
    """Spec-rollback trim of a sharer must not zero pages the donor still
    reads (trim returns only physically-freed ids)."""
    pool = BlockPool(8, 4)
    a = pool.alloc("a", 3)
    pool.adopt("b", a)                     # b shares all of a's blocks
    assert pool.trim("b", 4) == []         # drops 2 shared refs, frees none
    assert pool.owned("b") == a[:1]
    assert pool.ref_count(a[2]) == 1       # back to donor-private
    pool.free("a")
    assert pool.trim("b", 0) == a[:1]      # now the last ref frees


def test_block_pool_shared_metrics_families():
    from repro.telemetry.registry import MetricsRegistry
    reg = MetricsRegistry()
    pool = BlockPool(8, 4)
    pool.block_bytes = 100
    pool.bind_metrics(reg)
    a = pool.alloc("a", 2)
    pool.adopt("b", a)
    snap = {name: reg.get(name).series()[()] for name in
            ("kv_shared_blocks", "kv_cow_copies_total", "kv_bytes_saved")}
    assert snap["kv_shared_blocks"] == 2
    assert snap["kv_bytes_saved"] == 200   # 2 extra refs x block_bytes
    assert snap["kv_cow_copies_total"] == 0
    assert pool.peak_shared_blocks == 2
    pool.cow("b", 0)
    assert reg.get("kv_cow_copies_total").series()[()] == 1
    assert reg.get("kv_shared_blocks").series()[()] == 1
    pool.free("a")
    pool.free("b")
    assert reg.get("kv_shared_blocks").series()[()] == 0
    assert pool.peak_shared_blocks == 2    # high-water mark survives


def _mk_shared_requests(cfg, n=6, head=24, max_new=6):
    """Same sampled system prompt + per-request tail.  Closed loop (all
    arrivals at 0) keeps scheduling independent of measured step times;
    rid 0 decodes 3x longer, so it is still live — a valid donor — when
    slots free up for the requests beyond max_batch."""
    rng = np.random.default_rng(7)
    sys_prompt = [int(t) for t in rng.integers(1, cfg.vocab_size, head)]
    return [Request(rid, prompt=sys_prompt +
                    [int(t) for t in rng.integers(1, cfg.vocab_size,
                                                  4 + rid)],
                    max_new_tokens=(3 * max_new) if rid == 0 else max_new)
            for rid in range(n)]


def test_prefix_sharing_byte_identical_and_refcounts(small_model):
    """Acceptance: CoW prefix sharing with quantization OFF emits tokens
    byte-identical to the unshared paged run; adoption fires; refcounts
    conserve (pool drains to zero)."""
    cfg, params = small_model
    base = ServeEngine(cfg, params, max_batch=4, max_len=96, cache="paged",
                       block_size=8, prefill_chunk=8)
    t_base = _tokens(base.run(_mk_shared_requests(cfg)))
    shared = ServeEngine(cfg, params, max_batch=4, max_len=96,
                         cache="paged", block_size=8, prefill_chunk=8,
                         share_prefix=True)
    t_shared = _tokens(shared.run(_mk_shared_requests(cfg)))
    assert t_shared == t_base
    assert shared.stats.prefix_adoptions > 0
    assert shared.stats.shared_prefix_tokens > 0
    assert shared.kv.pool.peak_shared_blocks > 0
    # all references released: the pool drains to zero with no leaks
    assert shared.kv.pool.used_blocks == 0
    assert shared.kv.pool._refs == {}
    assert shared.kv.pool.free_blocks == shared.kv.num_blocks


@pytest.mark.parametrize("offload", ["none", "host"])
def test_prefix_sharing_survives_preempt_and_offload(small_model, offload):
    """Acceptance: sharing stays byte-identical across preempt/recompute
    and host-offload/restore — evicting a sharer never corrupts a block
    the donor still reads (physical frees only on last ref)."""
    cfg, params = small_model
    free_eng = ServeEngine(cfg, params, max_batch=3, max_len=64,
                           cache="paged", block_size=8, prefill_chunk=8)
    t_free = _tokens(free_eng.run(_mk_shared_requests(cfg, max_new=4)))
    tight = ServeEngine(cfg, params, max_batch=3, max_len=64,
                        cache="paged", block_size=8, prefill_chunk=8,
                        share_prefix=True, num_blocks=9, offload=offload)
    done = tight.run(_mk_shared_requests(cfg, max_new=4))
    assert _tokens(done) == t_free
    assert all(r.status == "done" for r in done)
    assert tight.stats.preemptions > 0
    assert tight.stats.prefix_adoptions > 0
    assert tight.kv.pool.used_blocks == 0 and tight.kv.pool._refs == {}


def test_prefix_sharing_with_quantization_stacks(small_model):
    """int8 + share_prefix together: the shared-vs-unshared comparison is
    still byte-identical AT THE SAME kv_dtype (quantized pages are shared
    bit-exactly, so adoption adds no extra quantization error)."""
    cfg, params = small_model
    outs = {}
    for share in (False, True):
        eng = ServeEngine(cfg, params, max_batch=4, max_len=96,
                          cache="paged", block_size=8, prefill_chunk=8,
                          kv_dtype="int8", share_prefix=share)
        outs[share] = _tokens(eng.run(_mk_shared_requests(cfg)))
        if share:
            assert eng.stats.prefix_adoptions > 0
    assert outs[True] == outs[False]


def test_cow_write_divergence_preserves_donor_pages(small_model):
    """Direct CoW exercise: force a sharer to diverge mid-sequence via
    _cow_protect and check the donor's page contents are preserved and
    the writer got a private copy."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, cache="paged",
                      block_size=4, share_prefix=True)
    pool = eng.kv.pool
    ids = pool.alloc("donor", 2)
    eng.cache = jax.tree.map(lambda p: p.at[:, ids[0]].set(1), eng.cache)
    pool.adopt("writer", ids)
    assert pool.shared_blocks == 2
    # writer is about to write tokens [0, 4): block 0 must diverge
    assert eng._cow_protect("writer", 0, 4)
    assert pool.cow_copies_total == 1
    w = pool.owned("writer")
    assert w[0] != ids[0] and w[1] == ids[1]
    # the copied page carries the donor's contents
    leaf = jax.tree.leaves(eng.cache)[0]
    np.testing.assert_array_equal(np.asarray(leaf[:, w[0]]),
                                  np.asarray(leaf[:, ids[0]]))
    # donor's view is untouched and still shared on block 1 only
    assert pool.owned("donor") == ids
    assert pool.ref_count(ids[0]) == 1 and pool.ref_count(ids[1]) == 2

"""Request-scoped tracing: lifecycle event collection, critical-path
decomposition with the conservation invariant (eager, fused, and
paged-with-preemption runs), SLO/goodput accounting and its registry
families, Perfetto round-trip of request tracks (strict JSON, per-request
tracks, paired flows), the router queue-wait histogram + fleet histogram
aggregation, and the Prometheus label-escaping regression."""
import json

import jax
import pytest

from repro.configs import get_config, reduced
from repro.core.export import REQUEST_PID, request_trace, save_request_trace
from repro.inference.engine import Request, ServeEngine
from repro.inference.fleet import ReplicaFleet
from repro.inference.router import RequestRouter
from repro.models import init_params
from repro.telemetry.critical_path import (SEGMENTS, SLO, analyze,
                                           breakdown, record_goodput,
                                           slo_report, triage)
from repro.telemetry.registry import MetricsRegistry
from repro.telemetry.tracing import RequestTrace, RequestTracer
from repro.workload import get_scenario


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = reduced(get_config("smollm-360m"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _assert_conserved(analysis):
    assert analysis.breakdowns, "no completed traces to analyze"
    for b in analysis.breakdowns:
        assert b.conserved, (
            f"rid {b.rid}: segments sum "
            f"{sum(b.segments.values())} != e2e {b.e2e_s} "
            f"(err {b.conservation_error})")
        # every segment non-negative; pieces tile [arrival, done]
        assert all(v >= 0 for v in b.segments.values())
        if b.pieces:
            assert b.pieces[0][1] == pytest.approx(b.arrival_s)
            assert b.pieces[-1][2] == pytest.approx(b.done_s)
            for (_, _, e0), (_, s1, _) in zip(b.pieces, b.pieces[1:]):
                assert s1 == pytest.approx(e0)


# ------------------------------------------------------------ tracer unit
def test_tracer_ingress_idempotent_first_wins():
    tr = RequestTracer()
    t1 = tr.ingress(0, 1.5)
    t2 = tr.ingress(0, 9.0)          # engine submit after router mint
    assert t1 is t2 and t1.arrival_s == 1.5
    assert t1.count("ingress") == 1


def test_tracer_decode_fans_out_to_participants():
    tr = RequestTracer()
    tr.decode([0, 1, 2], 1.0, 1.1, tax_s=0.01, batch=3)
    assert len(tr.traces) == 3
    for rid in (0, 1, 2):
        ev = tr.traces[rid].first("decode")
        assert ev.t0 == 1.0 and ev.t1 == pytest.approx(1.1)
        assert ev.meta["batch"] == 3


# ------------------------------------------------------- decomposition unit
def test_decompose_hand_built_trace_exact_segments():
    """A synthetic timeline with every lifecycle phase decomposes into
    exactly the intervals it was built from."""
    tr = RequestTracer()
    tr.ingress(7, 0.0)
    tr.dispatch(7, 1.0, replica=0)            # 0..1  router queue
    tr.admit(7, 3.0)                          # 1..3  admission wait
    tr.prefill(7, 3.0, 4.0, tax_s=0.25)       # 3..4  prefill (0.25 tax)
    tr.first_token(7, 4.0)
    tr.decode([7], 5.0, 6.0, tax_s=0.1)       # 4..5  interleave, 5..6 decode
    tr.preempt(7, 6.0, mode="host", offload_tax_s=0.2)
    tr.admit(7, 8.0, resume=True, restore_tax_s=0.3)   # 6..8 stall (0.5 tax)
    tr.decode([7], 8.0, 9.0, tax_s=0.0)
    tr.done(7, 9.0, n_tokens=3)
    b = breakdown(tr.traces[7])
    s = b.segments
    assert s["router_queue_wait"] == pytest.approx(1.0)
    assert s["admission_wait"] == pytest.approx(2.0)
    assert s["prefill_exec"] == pytest.approx(0.75)
    assert s["launch_tax"] == pytest.approx(0.35)
    assert s["decode_exec"] == pytest.approx(1.9)
    assert s["interleave_wait"] == pytest.approx(1.0)
    # 2s stall window: modeled offload(0.2)+restore(0.3) carved out first
    assert s["offload_restore_tax"] == pytest.approx(0.5)
    assert s["preemption_stall"] == pytest.approx(1.5)
    assert b.conserved and b.e2e_s == pytest.approx(9.0)
    assert b.preemptions == 1 and b.n_tokens == 3
    # TTFT walk stops at first token: decode/stall never pollute it
    assert b.ttft_s == pytest.approx(4.0)
    assert sum(b.ttft_segments.values()) == pytest.approx(4.0)
    assert b.ttft_segments["decode_exec"] == 0.0
    assert b.ttft_dominant == "admission_wait"
    assert b.mean_itl_s == pytest.approx((9.0 - 4.0) / 2)


def test_decompose_clamps_router_engine_clock_skew():
    """A dispatch stamped AFTER the replica's admit (router clock ran
    ahead) must not break conservation — skew folds into the waits."""
    tr = RequestTracer()
    tr.ingress(1, 0.0)
    tr.dispatch(1, 5.0, replica=0)    # router clock ahead of the engine
    tr.admit(1, 2.0)
    tr.prefill(1, 2.0, 3.0, tax_s=0.0)
    tr.first_token(1, 3.0)
    tr.done(1, 6.0, n_tokens=2)
    b = breakdown(tr.traces[1])
    assert b.conserved and b.e2e_s == pytest.approx(6.0)
    assert all(v >= 0 for v in b.segments.values())


def test_decompose_engine_only_waits_are_admission():
    tr = RequestTracer()
    tr.ingress(0, 0.0)               # no router leg at all
    tr.admit(0, 2.0)
    tr.prefill(0, 2.0, 3.0)
    tr.first_token(0, 3.0)
    tr.done(0, 3.0, n_tokens=1)
    b = breakdown(tr.traces[0])
    assert b.segments["admission_wait"] == pytest.approx(2.0)
    assert b.segments["router_queue_wait"] == 0.0
    assert b.replica is None


# -------------------------------------------------- engine-level invariant
@pytest.mark.parametrize("plan", ["eager", "fused"])
def test_conservation_invariant_planned_runs(tiny_setup, plan):
    """ISSUE acceptance: segments sum to measured E2E on eager and fused
    contiguous-cache runs."""
    cfg, params = tiny_setup
    tracer = RequestTracer()
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64, plan=plan,
                      monitor=False, tracer=tracer)
    eng.run([Request(i, prompt=list(range(5, 13)), max_new_tokens=4,
                     arrival_s=0.002 * i) for i in range(3)])
    a = analyze(tracer)
    assert len(a.breakdowns) == 3
    _assert_conserved(a)
    for b in a.breakdowns:
        assert b.n_tokens == 4
        assert b.segments["prefill_exec"] > 0
        assert b.segments["decode_exec"] > 0


def test_conservation_invariant_paged_with_preemption(tiny_setup):
    """ISSUE acceptance: the invariant holds under paged serving with
    real preemption + host offload/restore traffic."""
    cfg, params = tiny_setup
    tracer = RequestTracer()
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64, plan="jit",
                      cache="paged", block_size=4, num_blocks=6,
                      offload="host", monitor=False, tracer=tracer)
    eng.run([Request(i, prompt=list(range(1, 10)), max_new_tokens=10)
             for i in range(3)])
    a = analyze(tracer)
    assert len(a.breakdowns) == 3
    _assert_conserved(a)
    assert sum(b.preemptions for b in a.breakdowns) > 0
    assert eng.stats.preemptions == sum(b.preemptions
                                        for b in a.breakdowns)
    # modeled offload/restore transfer was carved out of the stalls
    assert sum(b.segments["offload_restore_tax"]
               for b in a.breakdowns) > 0


def test_rejected_requests_are_separated(tiny_setup):
    cfg, params = tiny_setup
    tracer = RequestTracer()
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64,
                      monitor=False, tracer=tracer)
    eng.run([Request(0, prompt=list(range(5, 13)), max_new_tokens=4),
             Request(1, prompt=list(range(5, 13)), max_new_tokens=100)])
    a = analyze(tracer)
    assert [b.rid for b in a.breakdowns] == [0]
    assert a.rejected == [1]


# ------------------------------------------------------------ fleet-level
def test_router_fleet_trace_and_queue_wait_histogram(tiny_setup):
    """One shared tracer spans router ingress -> replica completion; the
    queue-wait histogram lands per-replica in the fleet registry and
    survives aggregate_metrics() (histogram merge)."""
    cfg, params = tiny_setup
    tracer = RequestTracer()
    fleet = ReplicaFleet(cfg, params, replicas=2, max_batch=2, max_len=64,
                         monitor=False, tracer=tracer)
    router = RequestRouter(fleet, policy="round-robin", tracer=tracer)
    n = 6
    reqs = [Request(i, prompt=list(range(5, 11)), max_new_tokens=3,
                    arrival_s=0.001 * i) for i in range(n)]
    report = router.route(reqs)
    assert len(report.completed) == n
    a = analyze(tracer)
    assert len(a.breakdowns) == n
    _assert_conserved(a)
    # every request knows which replica served it
    assert {b.replica for b in a.breakdowns} == {0, 1}
    for b in a.breakdowns:
        assert b.replica == report.assignment[b.rid]
    # queue-wait histogram: one series per replica, one obs per dispatch
    fam = fleet.registry.get("router_queue_wait_seconds")
    assert sum(fam.count(replica=r) for r in (0, 1)) == n
    agg = fleet.aggregate_metrics().snapshot()
    hist = agg["router_queue_wait_seconds"]
    assert hist["type"] == "histogram"
    assert sum(s["value"]["count"] for s in hist["series"]) == n
    json.dumps(agg, allow_nan=False)


def test_histogram_merge_series_roundtrip():
    src = MetricsRegistry()
    h = src.histogram("w_seconds", buckets=(0.1, 1.0), labels=("r",))
    for v in (0.05, 0.5, 5.0):
        h.observe(v, r=0)
    snap = src.snapshot()["w_seconds"]
    dst = MetricsRegistry()
    h2 = dst.histogram("w_seconds", buckets=tuple(snap["buckets"]),
                       labels=("r",))
    s = snap["series"][0]
    h2.merge_series(s["value"]["count"], s["value"]["sum"],
                    s["value"]["buckets"], **s["labels"])
    h2.merge_series(s["value"]["count"], s["value"]["sum"],
                    s["value"]["buckets"], **s["labels"])
    assert h2.count(r=0) == 6
    assert h2.sum(r=0) == pytest.approx(2 * 5.55)
    with pytest.raises(ValueError, match="buckets"):
        h2.merge_series(1, 1.0, [1, 2], r=0)


# ------------------------------------------------------------ SLO/goodput
def test_slo_resolution_and_verdicts():
    sc = get_scenario("chatbot")
    assert sc.slo_ttft_s is not None and sc.slo_itl_s is not None
    slo = SLO.resolve(sc)
    assert slo.ttft_s == sc.slo_ttft_s
    # explicit ms flags override; 0 disables a bound
    slo = SLO.resolve(sc, ttft_ms=100.0, itl_ms=0.0)
    assert slo.ttft_s == pytest.approx(0.1) and slo.itl_s is None

    tr = RequestTracer()
    tr.ingress(0, 0.0)
    tr.admit(0, 0.0)
    tr.prefill(0, 0.0, 0.05)
    tr.first_token(0, 0.05)
    tr.decode([0], 0.05, 0.25)
    tr.done(0, 0.25, n_tokens=3)      # ttft 50ms, mean itl 100ms
    b = breakdown(tr.traces[0])
    assert SLO(ttft_s=0.1, itl_s=0.2).verdict(b) == "met"
    assert SLO(ttft_s=0.01, itl_s=0.2).verdict(b) == "ttft"
    assert SLO(ttft_s=0.1, itl_s=0.05).verdict(b) == "itl"
    assert SLO(ttft_s=0.01, itl_s=0.05).verdict(b) == "both"


def test_slo_report_goodput_and_registry_families(tiny_setup):
    cfg, params = tiny_setup
    tracer = RequestTracer()
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64,
                      monitor=False, tracer=tracer)
    eng.run([Request(i, prompt=list(range(5, 11)), max_new_tokens=3)
             for i in range(4)])
    a = analyze(tracer)
    # impossible TTFT bound -> all violate; blame names a real segment
    rep = slo_report(a, SLO(ttft_s=1e-9, itl_s=None))
    assert rep["verdicts"]["ttft"] + rep["verdicts"]["both"] == 4
    assert rep["goodput_ratio"] == 0.0
    assert sum(rep["blame"].values()) == 4
    assert set(rep["blame"]) == set(SEGMENTS)
    reg = MetricsRegistry()
    record_goodput(reg, rep)
    snap = reg.snapshot()
    assert sum(s["value"] for s in
               snap["goodput_requests_total"]["series"]) == 4
    assert sum(s["value"] for s in
               snap["goodput_blame_total"]["series"]) == 4
    assert snap["goodput_ratio"]["series"][0]["value"] == 0.0
    assert snap["slo_ttft_seconds"]["series"][0]["value"] == 1e-9
    # unconstrained SLO -> goodput 1.0
    rep2 = slo_report(a, SLO())
    assert rep2["goodput_ratio"] == 1.0 and sum(rep2["blame"].values()) == 0


def test_triage_report_shape(tiny_setup):
    cfg, params = tiny_setup
    tracer = RequestTracer()
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64,
                      monitor=False, tracer=tracer)
    eng.run([Request(i, prompt=list(range(5, 11)), max_new_tokens=3)
             for i in range(3)])
    tri = triage(analyze(tracer), SLO(ttft_s=1e-9), tail_q=50.0)
    assert tri["conservation"]["ok"]
    assert tri["n_requests"] == 3
    assert set(tri["aggregate"]["share"]) == set(SEGMENTS)
    assert sum(tri["aggregate"]["share"].values()) == pytest.approx(1.0)
    assert tri["tail"]["dominant"] in SEGMENTS
    assert tri["tail"]["n"] >= 1
    assert len(tri["waterfall"]) == 3
    row = tri["waterfall"][0]
    assert {"rid", "segments", "ttft_segments", "dominant",
            "conserved"} <= set(row)
    assert tri["slo_report"]["goodput_ratio"] == 0.0
    json.dumps(tri, allow_nan=False)


# ------------------------------------------------------- Perfetto round-trip
def _route_traced(cfg, params, **engine_kwargs):
    tracer = RequestTracer()
    fleet = ReplicaFleet(cfg, params, replicas=2, max_batch=2, max_len=64,
                         monitor=False, tracer=tracer, **engine_kwargs)
    router = RequestRouter(fleet, tracer=tracer)
    router.route([Request(i, prompt=list(range(5, 11)), max_new_tokens=3,
                          arrival_s=0.001 * i) for i in range(4)])
    return analyze(tracer)


def _check_request_trace(trace, n_requests):
    # strict JSON (Perfetto rejects NaN/Inf)
    parsed = json.loads(json.dumps(trace, allow_nan=False))
    evs = parsed["traceEvents"]
    # one track per request, and its slices tile the whole waterfall
    tracks = {e["tid"] for e in evs
              if e.get("pid") == REQUEST_PID and e["ph"] == "X"}
    assert len(tracks) == n_requests
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert names == {f"request {rid}" for rid in tracks}
    # every flow id pairs exactly one start with one finish
    flows = {}
    for e in evs:
        if e.get("cat") == "request_flow":
            flows.setdefault(e["id"], []).append(e["ph"])
    assert flows, "no flow arrows emitted"
    for fid, phs in flows.items():
        assert sorted(phs) == ["f", "s"], f"flow {fid} unpaired: {phs}"
    # exec flows land in the engine host lanes (pid 0)
    by_id = {}
    for e in evs:
        if e.get("cat") == "request_flow":
            by_id.setdefault(e["id"], {})[e["ph"]] = e
    for pair in by_id.values():
        assert pair["s"]["pid"] == REQUEST_PID
        assert pair["f"]["pid"] == 0


@pytest.mark.parametrize("engine_kwargs", [
    {},                                                  # contiguous
    {"cache": "paged", "block_size": 4, "num_blocks": 6,  # paged+preempt
     "offload": "host"},
])
def test_perfetto_roundtrip_route_traces(tiny_setup, engine_kwargs, tmp_path):
    """ISSUE satellite: strict-JSON parse, per-request track presence,
    s/f flow-pair validity, and per-request conservation across
    contiguous and paged caches."""
    cfg, params = tiny_setup
    a = _route_traced(cfg, params, **engine_kwargs)
    _assert_conserved(a)          # invariant asserted per request
    trace = request_trace(a, platform="TPU-v5e")
    _check_request_trace(trace, len(a.breakdowns))
    path = save_request_trace(a, str(tmp_path / "req_trace.json"))
    with open(path) as fh:
        _check_request_trace(json.load(fh), len(a.breakdowns))


# ------------------------------------------------- Prometheus escaping fix
def test_prometheus_label_values_escaped():
    """Regression: backslash, double-quote, and newline in label values
    must be escaped per the text-exposition spec (previously raw)."""
    reg = MetricsRegistry()
    c = reg.counter("ops_total", labels=("op",))
    c.inc(1, op='matmul"fused"')
    c.inc(2, op="a\\b")
    c.inc(3, op="line1\nline2")
    h = reg.histogram("t_seconds", labels=("op",), buckets=(1.0,))
    h.observe(0.5, op='q"x')
    text = reg.to_prometheus()
    assert 'ops_total{op="matmul\\"fused\\""} 1' in text
    assert 'ops_total{op="a\\\\b"} 2' in text
    assert 'ops_total{op="line1\\nline2"} 3' in text
    # no raw newline may survive inside any sample line
    for line in text.splitlines():
        assert "line2" not in line or "\\n" in line
    assert 't_seconds_bucket{op="q\\"x",le="1"} 1' in text
    assert 't_seconds_bucket{op="q\\"x",le="+Inf"} 1' in text


def test_prometheus_plain_values_unchanged():
    reg = MetricsRegistry()
    reg.counter("a_total").inc(2)
    reg.gauge("b", labels=("batch",)).set(1.5, batch=4)
    reg.histogram("c_seconds", buckets=(0.5, 1.0)).observe(0.7)
    text = reg.to_prometheus()
    assert "a_total 2" in text
    assert 'b{batch="4"} 1.5' in text
    assert 'c_seconds_bucket{le="1"} 1' in text
    assert 'c_seconds_bucket{le="+Inf"} 1' in text


# ------------------------------------------------------------ serialization
def test_trace_events_sorted_and_trace_queries():
    tr = RequestTrace(rid=0, arrival_s=0.0)
    tracer = RequestTracer()
    tracer.traces[0] = tr
    tracer.admit(0, 1.0)
    tracer.preempt(0, 1.0)           # same timestamp: lifecycle order
    tracer.done(0, 2.0, n_tokens=1)
    kinds = [e.kind for e in tr.sorted_events()]
    assert kinds == ["admit", "preempt", "done"]
    assert tr.count("admit") == 1
    assert tracer.completed() == [tr]
    tracer.clear()
    assert len(tracer) == 0

"""Sharding rules, multi-device lowering, EP equivalence, compression,
elastic restore — multi-device cases run in subprocesses with a forced
host-platform device count (the main test process keeps 1 device)."""
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import valid_spec
from repro.launch.mesh import make_host_mesh


def _run_sub(code: str, devices: int = 8) -> str:
    # forcing a host-platform device count only works on the CPU backend;
    # on an accelerator backend we need that many real devices
    if jax.default_backend() != "cpu" and jax.device_count() < devices:
        pytest.skip(f"needs {devices} devices, have {jax.device_count()} "
                    f"on backend {jax.default_backend()!r}")
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu", "PATH": "/usr/bin:/bin"}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         cwd="/root/repo", timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ------------------------------------------------------------ spec fallback
def test_valid_spec_divisibility_fallback():
    mesh = make_host_mesh(data=1, model=1)
    # with 1-device axes everything divides
    assert valid_spec((15, 8), P("data", "model"), mesh) == P("data", "model")


def test_param_specs_smollm_heads_replicated():
    code = """
    import jax
    from repro.configs import get_config
    from repro.distributed.sharding import param_specs
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import params_sds
    mesh = make_host_mesh(data=2, model=4)
    cfg = get_config("smollm-360m")          # 15 heads: not divisible by 4
    p = params_sds(cfg)
    specs = param_specs(p, cfg, mesh)
    wq = specs["blocks"]["slot0"]["mixer"]["wq"]
    w_in = specs["blocks"]["slot0"]["mlp"]["w_in"]
    print("WQ", wq)
    print("WIN", w_in)
    """
    out = _run_sub(code)
    assert "WQ PartitionSpec(None, None, None)" in out     # replicated
    assert "'model'" in out.split("WIN", 1)[1]             # d_ff sharded


# ------------------------------------------------------------ EP vs local
def test_moe_ep_matches_local_multidevice():
    code = """
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, reduced
    from repro.layers.moe import MeshContext, moe_init, moe_local_fwd, moe_ep_fwd
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(data=2, model=4)
    cfg = reduced(get_config("moonshot-v1-16b-a3b"))
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, n_experts=8,
                                              capacity_factor=8.0))
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
    dist = MeshContext(mesh=mesh, dp_axes=("data",), tp_axis="model")
    y_ref, aux_ref = moe_local_fwd(params, x, cfg)
    for mode in ("seq", "rep"):
        y, aux = jax.jit(lambda p, x_: moe_ep_fwd(p, x_, cfg, dist, mode=mode))(params, x)
        err = float(jnp.max(jnp.abs(y - y_ref)))
        print(mode, "err", err, "aux_err", abs(float(aux) - float(aux_ref)))
        assert err < 2e-4, (mode, err)
    print("EP_OK")
    """
    assert "EP_OK" in _run_sub(code)


# ------------------------------------------------------------ compression
def test_compressed_dp_grads_close_to_exact():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.compression import (
        init_error_state, make_compressed_dp_grad)
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(data=4, model=1)
    w = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                          jnp.float32)}
    x = jnp.asarray(np.random.default_rng(1).standard_normal((8, 8)),
                    jnp.float32)
    def loss(p, b):
        return jnp.mean((b @ p["w"]) ** 2), 0.0
    step = make_compressed_dp_grad(loss, mesh, "data")
    errs = init_error_state(w)
    g, errs, _ = step(w, errs, x)
    g_exact = jax.grad(lambda p: loss(p, x)[0])(w)
    rel = float(jnp.linalg.norm(g["w"] - g_exact["w"]) /
                jnp.linalg.norm(g_exact["w"]))
    print("rel", rel)
    assert rel < 0.05, rel
    print("COMP_OK")
    """
    assert "COMP_OK" in _run_sub(code, devices=4)


# ------------------------------------------------------------ elastic
def test_elastic_restore_across_mesh_shapes():
    code = """
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_config, reduced
    from repro.distributed.sharding import param_specs, shardings_for
    from repro.launch.mesh import make_host_mesh
    from repro.models import init_params
    cfg = reduced(get_config("internlm2-20b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    d = tempfile.mkdtemp()
    ckpt = CheckpointManager(d, async_write=False)
    ckpt.save(1, params)
    for shape in [(2, 4), (4, 2), (8, 1)]:
        mesh = make_host_mesh(data=shape[0], model=shape[1])
        sh = shardings_for(params, param_specs(params, cfg, mesh), mesh)
        restored = ckpt.restore(1, params, shardings=sh)
        leaf = jax.tree.leaves(restored)[0]
        ok = np.allclose(np.asarray(jax.tree.leaves(restored)[3]),
                         np.asarray(jax.tree.leaves(params)[3]))
        print(shape, "devices-used",
              len(leaf.sharding.device_set), "equal", ok)
        assert ok
    print("ELASTIC_OK")
    """
    assert "ELASTIC_OK" in _run_sub(code)


# ------------------------------------------------------------ lowering
def test_small_mesh_lowering_all_step_kinds():
    code = """
    import jax
    from repro.configs import get_config, reduced
    from repro.configs.base import ShapeSpec
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import build_step
    mesh = make_host_mesh(data=2, model=2, pod=2)
    for arch in ("internlm2-20b", "moonshot-v1-16b-a3b"):
        cfg = reduced(get_config(arch))
        for shape in (ShapeSpec("t", 64, 8, "train"),
                      ShapeSpec("p", 64, 4, "prefill"),
                      ShapeSpec("d", 64, 8, "decode")):
            c = build_step(cfg, shape, mesh).lower().compile()
            assert c.memory_analysis().temp_size_in_bytes >= 0
            print(arch, shape.kind, "ok")
    print("LOWER_OK")
    """
    assert "LOWER_OK" in _run_sub(code)

"""Flash-vs-dense attention equivalence, incl. hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.layers.attention import flash_mha, mha


def _mk(key, B, S, T, HQ, HKV, hd):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, HQ, hd))
    k = jax.random.normal(ks[1], (B, T, HKV, hd))
    v = jax.random.normal(ks[2], (B, T, HKV, hd))
    qp = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kp = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    return q, k, v, qp, kp


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window,cap", [(0, 0.0), (9, 0.0), (0, 5.0)])
def test_flash_matches_dense(causal, window, cap):
    q, k, v, qp, kp = _mk(jax.random.PRNGKey(0), 2, 37, 37, 6, 2, 16)
    kw = dict(scale=0.25, causal=causal, window=window, cap=cap,
              q_positions=qp, kv_positions=kp)
    a = mha(q, k, v, **kw)
    b = flash_mha(q, k, v, block_kv=8, **kw)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5


def test_flash_gradients_match():
    q, k, v, qp, kp = _mk(jax.random.PRNGKey(1), 1, 16, 16, 4, 2, 8)
    kw = dict(scale=0.3, causal=True, window=0, cap=0.0,
              q_positions=qp, kv_positions=kp)
    g1 = jax.grad(lambda q_: jnp.sum(mha(q_, k, v, **kw) ** 2))(q)
    g2 = jax.grad(lambda q_: jnp.sum(
        flash_mha(q_, k, v, block_kv=8, **kw) ** 2))(q)
    assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-4


@settings(max_examples=15, deadline=None)
@given(
    s=st.integers(2, 24),
    t=st.integers(2, 24),
    hkv=st.sampled_from([1, 2, 3]),
    g=st.sampled_from([1, 2, 4]),
    hd=st.sampled_from([4, 8]),
    causal=st.booleans(),
    blk=st.sampled_from([4, 8, 16]),
)
def test_flash_property(s, t, hkv, g, hd, causal, blk):
    """For any shape/blocking, flash == dense (online softmax exactness)."""
    if causal and t < s:
        t = s
    q, k, v, qp, kp = _mk(jax.random.PRNGKey(42), 1, s, t, hkv * g, hkv, hd)
    if causal:
        # right-align queries in the kv window, as in the cache layout
        qp = qp + (t - s)
    kw = dict(scale=hd ** -0.5, causal=causal, window=0, cap=0.0,
              q_positions=qp, kv_positions=kp)
    a = mha(q, k, v, **kw)
    b = flash_mha(q, k, v, block_kv=blk, **kw)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=2e-5)


def test_kv_valid_mask():
    q, k, v, qp, kp = _mk(jax.random.PRNGKey(2), 2, 8, 32, 4, 4, 8)
    valid = jnp.broadcast_to(jnp.arange(32)[None] < 20, (2, 32))
    kw = dict(scale=0.3, causal=False, window=0, cap=0.0,
              q_positions=qp, kv_positions=kp, kv_valid=valid)
    a = mha(q, k, v, **kw)
    b = flash_mha(q, k, v, block_kv=8, **kw)
    # and equals dense attention over the first 20 kv only
    c = mha(q, k[:, :20], v[:, :20], scale=0.3, causal=False, window=0,
            cap=0.0, q_positions=qp, kv_positions=kp[:, :20])
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5
    assert float(jnp.max(jnp.abs(a - c))) < 1e-5

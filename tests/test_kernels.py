"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.rwkv6.ops import wkv6
from repro.kernels.rwkv6.ref import wkv6_ref

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("shape", [
    (2, 4, 2, 64, 64, 32), (1, 6, 2, 37, 37, 16), (2, 8, 8, 128, 256, 64),
    (1, 4, 1, 33, 65, 112),                       # kimi-style hd=112 padding
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(shape, dtype):
    B, HQ, HKV, S, T, hd = shape
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, HQ, S, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, HKV, T, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, HKV, T, hd)).astype(dtype)
    o = flash_attention(q, k, v, scale=0.2, causal=True,
                        block_q=32, block_kv=32)
    r = attention_ref(q, k, v, scale=0.2, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window,cap", [(16, 0.0), (0, 8.0), (16, 8.0)])
def test_flash_attention_window_softcap(window, cap):
    B, HQ, HKV, S, T, hd = 1, 4, 2, 64, 64, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, HQ, S, hd))
    k = jax.random.normal(ks[1], (B, HKV, T, hd))
    v = jax.random.normal(ks[2], (B, HKV, T, hd))
    o = flash_attention(q, k, v, scale=0.2, causal=True, window=window,
                        softcap=cap, block_q=16, block_kv=16)
    r = attention_ref(q, k, v, scale=0.2, causal=True, window=window,
                      softcap=cap)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(4, 40), hkv=st.sampled_from([1, 2]),
       g=st.sampled_from([1, 3]), hd=st.sampled_from([8, 16]))
def test_flash_attention_property(s, hkv, g, hd):
    ks = jax.random.split(jax.random.PRNGKey(s), 3)
    q = jax.random.normal(ks[0], (1, hkv * g, s, hd))
    k = jax.random.normal(ks[1], (1, hkv, s, hd))
    v = jax.random.normal(ks[2], (1, hkv, s, hd))
    o = flash_attention(q, k, v, scale=hd ** -0.5, causal=True,
                        block_q=16, block_kv=16)
    r = attention_ref(q, k, v, scale=hd ** -0.5, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               atol=3e-5, rtol=3e-5)


# ------------------------------------------------------------ decode attention
@pytest.mark.parametrize("shape", [(2, 4, 2, 128, 32), (1, 8, 8, 500, 64),
                                   (3, 6, 3, 96, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_shapes(shape, dtype):
    B, HQ, HKV, T, hd = shape
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, HQ, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, HKV, T, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, HKV, T, hd)).astype(dtype)
    for kvlen in (T, T // 2, 5):
        o = decode_attention(q, k, v, kvlen, scale=0.2, block_kv=64)
        r = decode_attention_ref(q, k, v, kvlen, scale=0.2)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(r, np.float32),
                                   atol=tol, rtol=tol)


# ------------------------------------------------------------ rmsnorm
@pytest.mark.parametrize("n,d", [(64, 96), (100, 256), (7, 64)])
@pytest.mark.parametrize("with_res", [False, True])
def test_rmsnorm(n, d, with_res):
    x = jax.random.normal(KEY, (n, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d,)) + 1.0
    res = jax.random.normal(jax.random.PRNGKey(2), (n, d)) if with_res else None
    y, r2 = rmsnorm(x, w, res, block_n=32)
    yr, rr = rmsnorm_ref(x, w, res)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r2), np.asarray(rr), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 50), d=st.sampled_from([32, 64, 128]))
def test_rmsnorm_property(n, d):
    x = jax.random.normal(jax.random.PRNGKey(n), (n, d))
    w = jnp.ones((d,))
    y, _ = rmsnorm(x, w, block_n=16)
    yr, _ = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ wkv6
@pytest.mark.parametrize("shape", [(1, 2, 32, 16), (2, 3, 45, 8),
                                   (1, 1, 16, 32)])
def test_wkv6(shape):
    B, H, T, hd = shape
    ks = jax.random.split(KEY, 6)
    r = jax.random.normal(ks[0], (B, H, T, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, H, T, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, H, T, hd))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, H, T, hd)) * 0.5 - 2)
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    s0 = jax.random.normal(ks[5], (B, H, hd, hd)) * 0.1
    o, sT = wkv6(r, k, v, logw, u, s0, chunk=16)
    orf, srf = wkv6_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(srf),
                               atol=5e-4, rtol=1e-3)


def test_wkv6_extreme_decay():
    """Overflow-safety: very strong and very weak decays."""
    B, H, T, hd = 1, 1, 32, 8
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, H, T, hd))
    k = jax.random.normal(ks[1], (B, H, T, hd))
    v = jax.random.normal(ks[2], (B, H, T, hd))
    logw = jnp.where(jnp.arange(T)[None, None, :, None] % 2 == 0,
                     -50.0, -1e-4).astype(jnp.float32)
    logw = jnp.broadcast_to(logw, (B, H, T, hd))
    u = jnp.zeros((H, hd))
    s0 = jnp.zeros((B, H, hd, hd))
    o, sT = wkv6(r, k, v, logw, u, s0, chunk=8)
    orf, srf = wkv6_ref(r, k, v, logw, u, s0)
    assert np.isfinite(np.asarray(o)).all()
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               atol=1e-3, rtol=1e-3)


def test_wkv6_matches_model_chunked():
    """The Pallas kernel and the model's jnp chunked path agree."""
    from repro.layers.rwkv import wkv_chunked
    B, H, T, hd = 1, 2, 32, 8
    ks = jax.random.split(KEY, 6)
    r = jax.random.normal(ks[0], (B, T, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, hd))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, hd)) * 0.3 - 2)
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    s0 = jax.random.normal(ks[5], (B, H, hd, hd)) * 0.1
    o_model, s_model = wkv_chunked(r, k, v, logw, u, s0, chunk=16)
    # kernel uses (B,H,T,hd) layout
    def tr(x):
        return x.transpose(0, 2, 1, 3)
    o_kern, s_kern = wkv6(tr(r), tr(k), tr(v), tr(logw), u, s0, chunk=16)
    np.testing.assert_allclose(np.asarray(tr(o_kern)), np.asarray(o_model),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_kern), np.asarray(s_model),
                               atol=5e-4, rtol=1e-3)

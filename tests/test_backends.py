"""Scheduler/ExecutionBackend split: layer purity, tp=1 vs tp=2 token
equivalence (contiguous + paged + preempt->resume), per-device launch
accounting, mesh validation errors, and tensor-parallel plan pricing.

Multi-device cases run in subprocesses with a forced host-platform device
count (the main test process keeps 1 device), same as test_distributed."""
import subprocess
import sys
import textwrap
from dataclasses import dataclass
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.device_model import PLATFORMS, allreduce_cost_s
from repro.inference.engine import Request, ServeEngine
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.runtime.plan import LaunchPlan
from repro.runtime.planner import simulate_plan


def _run_sub(code: str, devices: int = 4) -> str:
    if jax.default_backend() != "cpu" and jax.device_count() < devices:
        pytest.skip(f"needs {devices} devices, have {jax.device_count()} "
                    f"on backend {jax.default_backend()!r}")
    repo = Path(__file__).resolve().parents[1]
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": str(repo / "src"), "JAX_PLATFORMS": "cpu",
           "PATH": "/usr/bin:/bin"}
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         cwd=str(repo), timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("smollm-360m"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, n=3, plen=6, new=4):
    rng = np.random.default_rng(0)
    return [Request(i, prompt=list(rng.integers(0, cfg.vocab_size, plen)),
                    max_new_tokens=new) for i in range(n)]


# ------------------------------------------------------------ layer purity
def test_scheduler_layer_is_device_free():
    """The acceptance bar of the refactor: no shard_map, mesh, or
    device-placement logic inside the scheduler module — all of that
    lives behind the ExecutionBackend protocol.  Checked on the AST so
    docstrings may still EXPLAIN the split."""
    import ast
    import inspect

    import repro.inference.engine as engine
    tree = ast.parse(inspect.getsource(engine))
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            names.add(node.id)
        elif isinstance(node, ast.Attribute):
            names.add(node.attr)
        elif isinstance(node, ast.Import):
            names.update(a.name for a in node.names)
        elif isinstance(node, ast.ImportFrom):
            names.add(node.module or "")
            names.update(a.name for a in node.names)
    forbidden = {"shard_map", "make_mesh", "make_host_mesh", "Mesh",
                 "device_put", "NamedSharding", "PartitionSpec",
                 "jax.sharding", "repro.distributed.sharding",
                 "repro.launch.mesh", "repro.inference.backends.sharded"}
    hits = names & forbidden
    assert not hits, f"scheduler layer references {sorted(hits)}"


def test_backend_protocol_shape():
    from repro.inference.backends import ExecutionBackend, LocalBackend
    cfg = reduced(get_config("smollm-360m"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    be = LocalBackend(cfg, params, max_batch=1, max_len=16)
    assert isinstance(be, ExecutionBackend)
    assert be.info.kind == "local" and be.info.tp == 1


# ------------------------------------------------------------ mesh errors
def test_make_host_mesh_actionable_device_error():
    need = jax.device_count() + 1
    with pytest.raises(ValueError) as e:
        make_host_mesh(data=need, model=1)
    msg = str(e.value)
    assert "jax.device_count()" in msg
    if jax.default_backend() == "cpu":
        assert f"xla_force_host_platform_device_count={need}" in msg


def test_make_host_mesh_rejects_nonpositive_axes():
    with pytest.raises(ValueError, match="must be >= 1"):
        make_host_mesh(data=0, model=1)
    with pytest.raises(ValueError, match="must be >= 1"):
        make_host_mesh(data=1, model=-2)


def test_engine_tp_validation(tiny):
    cfg, params = tiny
    with pytest.raises(ValueError, match="tp must be >= 1"):
        ServeEngine(cfg, params, tp=0)
    # divisibility is checked before the mesh, so this works on 1 device
    with pytest.raises(ValueError, match="must divide n_heads"):
        ServeEngine(cfg, params, tp=3)
    # plan restriction is device-independent too
    with pytest.raises(ValueError, match="plan='jit' only"):
        ServeEngine(cfg, params, tp=2, plan="eager")
    if jax.device_count() < 2:
        with pytest.raises(ValueError, match="jax.device_count"):
            ServeEngine(cfg, params, tp=2)


# ------------------------------------------------------------ accounting
def test_local_backend_per_device_accounting(tiny):
    cfg, params = tiny
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32)
    eng.run(_requests(cfg))
    st = eng.stats
    assert st.tp == 1
    assert st.collectives == 0 and st.collective_bytes == 0
    assert st.per_device_dispatches == {
        0: st.prefill_dispatches + st.decode_dispatches}
    # reset() re-baselines the cumulative backend counters
    eng.reset()
    eng.run(_requests(cfg))
    st2 = eng.stats
    assert st2.per_device_dispatches == {
        0: st2.prefill_dispatches + st2.decode_dispatches}


# ------------------------------------------------------------ plan pricing
@dataclass
class _K:
    name: str
    flops: float
    bytes: float


def _kernels(n=6):
    return [_K(f"k{i}", 1e6, 1e4) for i in range(n)]


def test_simulate_plan_tp_multiplies_launch_and_divides_work():
    spec = PLATFORMS["Intel+H100"]
    ks = _kernels()
    plan = LaunchPlan.eager(len(ks))
    ev1 = simulate_plan(ks, plan, spec, tp=1)
    ev4 = simulate_plan(ks, plan, spec, tp=4)
    # per-device dispatch streams: host launch time x tp
    assert sum(e.t_launch for e in ev4) == pytest.approx(
        4 * sum(e.t_launch for e in ev1))
    # per-device work: kernel durations shrink (never grow) with tp
    assert sum(e.duration for e in ev4) < sum(e.duration for e in ev1)


def test_simulate_plan_collective_bytes_pricing():
    spec = PLATFORMS["GH200"]
    ks = _kernels()
    plan = LaunchPlan.eager(len(ks))
    base = simulate_plan(ks, plan, spec, tp=2)
    # scalar: one aggregate all-reduce after the final segment
    tot = simulate_plan(ks, plan, spec, tp=2, collective_bytes=1 << 20)
    extra = tot[-1].duration - base[-1].duration
    assert extra == pytest.approx(allreduce_cost_s(spec, 1 << 20, 2))
    # per-segment list localizes the latency floors
    per_seg = [0.0] * len(ks)
    per_seg[1] = per_seg[4] = 1 << 10
    loc = simulate_plan(ks, plan, spec, tp=2, collective_bytes=per_seg)
    want = 2 * allreduce_cost_s(spec, 1 << 10, 2)
    assert (sum(e.duration for e in loc) - sum(e.duration for e in base)
            == pytest.approx(want))
    with pytest.raises(ValueError, match="entries"):
        simulate_plan(ks, plan, spec, tp=2, collective_bytes=[1.0])
    with pytest.raises(ValueError, match="tp must be >= 1"):
        simulate_plan(ks, plan, spec, tp=0)


def test_allreduce_cost_model():
    lc, cc = PLATFORMS["Intel+H100"], PLATFORMS["GH200"]
    nbytes = 8 << 20
    assert allreduce_cost_s(lc, nbytes, 1) == 0.0
    # CC fabric (NVLink-C2C) beats LC (PCIe) at equal payload and degree
    assert allreduce_cost_s(cc, nbytes, 4) < allreduce_cost_s(lc, nbytes, 4)
    # cost grows with degree (more ring steps, more wire bytes/device)
    assert allreduce_cost_s(lc, nbytes, 8) > allreduce_cost_s(lc, nbytes, 2)
    with pytest.raises(ValueError):
        allreduce_cost_s(lc, -1.0, 2)


def test_tp_sweep_modeled_shift(tiny):
    cfg, params = tiny
    from repro.telemetry.characterize import tp_sweep
    sweep = tp_sweep(cfg, params, batches=(1, 2), tps=(1, 2),
                     platforms=("Intel+H100",), max_len=16)
    pts = {(p["tp"], p["batch"]): p for p in sweep["points"]}
    assert set(pts) == {(1, 1), (1, 2), (2, 1), (2, 2)}
    # host dispatch streams double with tp on the SAME kernel stream
    assert pts[(2, 1)]["n_kernels"] == pts[(1, 1)]["n_kernels"]
    assert pts[(2, 1)]["launch_tax_us"] == pytest.approx(
        2 * pts[(1, 1)]["launch_tax_us"], rel=1e-6)
    # collectives appear only at tp>1 and are priced over the link
    assert pts[(1, 1)]["collective_bytes"] == 0
    assert pts[(2, 1)]["collective_bytes"] > 0
    assert pts[(2, 1)]["modeled_collective_tax_us"] > 0
    assert "Intel+H100" in sweep["inflection_batch"]


# ------------------------------------------------------------ equivalence
def test_tp2_token_equivalence_all_cache_modes():
    """tp=2 ShardedBackend must emit byte-identical greedy tokens to the
    tp=1 LocalBackend on reduced smollm for cache='contiguous' AND
    cache='paged', including a preempt->resume case (tight pool, both
    recompute and host-offload restore), plus sane sharded stats."""
    code = """
    import jax, numpy as np
    from repro.configs import get_config, reduced
    from repro.inference.engine import Request, ServeEngine
    from repro.models import init_params

    cfg = reduced(get_config("smollm-360m"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)

    def reqs(n=4, plen=8, new=6):
        rng = np.random.default_rng(0)
        return [Request(i, prompt=list(rng.integers(0, cfg.vocab_size, plen)),
                        max_new_tokens=new) for i in range(n)]

    def toks(eng):
        done = eng.run(reqs())
        return [r.generated for r in sorted(done, key=lambda r: r.rid)]

    # contiguous
    c1 = toks(ServeEngine(cfg, params, max_batch=2, max_len=32))
    e2 = ServeEngine(cfg, params, max_batch=2, max_len=32, tp=2)
    c2 = toks(e2)
    assert c1 == c2, ("contiguous", c1, c2)
    st = e2.stats
    assert st.tp == 2
    assert set(st.per_device_dispatches) == {0, 1}
    assert st.decode_dispatches == 2 * st.decode_steps
    assert st.collectives > 0 and st.collective_bytes > 0
    assert st.modeled_collective_tax_s > 0
    print("CONTIG_OK")

    # paged, free pool
    kw = dict(max_batch=2, max_len=32, cache="paged", block_size=4)
    p1 = toks(ServeEngine(cfg, params, **kw))
    p2 = toks(ServeEngine(cfg, params, tp=2, **kw))
    assert p1 == p2, ("paged", p1, p2)
    print("PAGED_OK")

    # tight pool: preempt -> recompute resume
    kw = dict(max_batch=3, max_len=32, cache="paged", block_size=4,
              num_blocks=9, prefill_chunk=4)
    r1e = ServeEngine(cfg, params, **kw); r1 = toks(r1e)
    r2e = ServeEngine(cfg, params, tp=2, **kw); r2 = toks(r2e)
    assert r1 == r2, ("recompute", r1, r2)
    assert r1e.stats.preemptions > 0 and \\
        r1e.stats.preemptions == r2e.stats.preemptions
    print("PREEMPT_RECOMPUTE_OK")

    # tight pool: preempt -> host-offload restore (byte-exact KV restore
    # through the sharded pages)
    kw["offload"] = "host"
    o1e = ServeEngine(cfg, params, **kw); o1 = toks(o1e)
    o2e = ServeEngine(cfg, params, tp=2, **kw); o2 = toks(o2e)
    assert o1 == o2, ("offload", o1, o2)
    # head-sharded pages: each device stages 1/tp of the KV over its own
    # host link, so per-device staged bytes halve at tp=2
    assert o2e.stats.offload_bytes * 2 == o1e.stats.offload_bytes > 0
    assert o2e.stats.restore_bytes * 2 == o1e.stats.restore_bytes > 0
    print("PREEMPT_OFFLOAD_OK")

    # warmup -> reset -> measure keeps compiled shard_map fns and tokens
    o2e.reset()
    assert toks(o2e) == o1
    assert o2e.stats.per_device_dispatches[0] == \\
        o2e.stats.per_device_dispatches[1] > 0
    print("RESET_OK")
    """
    out = _run_sub(code)
    for marker in ("CONTIG_OK", "PAGED_OK", "PREEMPT_RECOMPUTE_OK",
                   "PREEMPT_OFFLOAD_OK", "RESET_OK"):
        assert marker in out


def test_sharded_serve_cli_reports_tp_counters():
    code = """
    import json, subprocess, sys
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "smollm-360m", "--reduced", "--requests", "3", "--max-batch", "2",
         "--max-new", "3", "--max-len", "64", "--tp", "2", "--no-warmup"],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stderr[-2000:]
    rep = json.loads(out.stdout.strip().splitlines()[-1])
    assert rep["tp"] == 2
    assert set(rep["per_device_dispatches"]) == {"0", "1"}
    assert rep["collective_bytes"] > 0
    assert rep["modeled_collective_tax_us"] > 0
    print("CLI_OK")
    """
    assert "CLI_OK" in _run_sub(code)

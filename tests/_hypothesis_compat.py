"""Optional-dependency shim for hypothesis (dev-only dependency).

Property tests use hypothesis when it is installed; without it they are
skipped at runtime while every deterministic test in the same module still
collects and runs.  Test modules import ``given``/``settings``/``st`` from
here instead of from hypothesis directly.

Install the real thing with: ``pip install hypothesis``.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Accepts any strategy-building call chain; values never used."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*_a, **_k):
        # replace the property test with a zero-arg skipper so pytest
        # never tries to resolve the strategy params as fixtures
        def deco(fn):
            def _skipped():
                pytest.skip("hypothesis not installed (optional dev dep)")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

"""Launch-plan runtime: plan validity, equivalence across strategies,
the cost-aware auto partitioner, the compiled-segment cache, and the
serving engine's plan-aware dispatch accounting."""
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.fusion import _speedup
from repro.core.proximity import fusion_segments, mine_chains
from repro.core.tracing import trace_fn
from repro.inference.engine import Request, ServeEngine
from repro.models import forward, init_params
from repro.runtime import (LaunchPlan, PlanExecutor, Planner, cache_stats,
                           clear_cache)


def _toy_fn(x, w1, w2):
    h = jax.nn.gelu(x @ w1)
    h = h * 2 + 1
    return jax.nn.softmax(h @ w2, axis=-1)


def _toy_args():
    key = jax.random.PRNGKey(0)
    return (jax.random.normal(key, (4, 8)),
            jax.random.normal(key, (8, 16)),
            jax.random.normal(key, (16, 8)))


# ------------------------------------------------------------ plan shapes
def test_plan_builders_cover_exactly():
    tr = trace_fn(_toy_fn, *_toy_args())
    n = len(tr.kernels)
    for plan in (LaunchPlan.eager(n), LaunchPlan.whole_graph(n),
                 LaunchPlan.chain(tr.kernel_names, 4)):
        plan.validate(n)
        assert plan.n_kernels == n
    assert LaunchPlan.eager(n).n_launches == n
    assert LaunchPlan.whole_graph(n).n_launches == 1


def test_plan_rejects_bad_cover():
    with pytest.raises(ValueError):
        LaunchPlan.from_segments([[0, 2], [1]])
    with pytest.raises(ValueError):
        LaunchPlan.from_segments([[0], [1]]).validate(3)


# ------------------------------------------------------------ equivalence
def test_plans_equivalent_on_toy_fn():
    args = _toy_args()
    tr = trace_fn(_toy_fn, *args)
    n = len(tr.kernels)
    eager, _ = PlanExecutor(tr, LaunchPlan.eager(n)).run(*args)
    planner = Planner(tr, "GH200")
    for plan in (LaunchPlan.whole_graph(n),
                 LaunchPlan.chain(tr.kernel_names, 4),
                 planner.cost_partition(),
                 planner.auto().plan):
        out, _ = PlanExecutor(tr, plan).run(*args)
        np.testing.assert_allclose(np.asarray(out[-1]),
                                   np.asarray(eager[-1]), atol=1e-6)


def test_plans_equivalent_on_reduced_smollm():
    cfg = reduced(get_config("smollm-360m"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                                cfg.vocab_size)

    def fwd(p, t):
        return forward(p, t, cfg, unroll=True)[0]

    tr = trace_fn(fwd, params, tokens)
    n = len(tr.kernels)
    eager, _ = PlanExecutor(tr, LaunchPlan.eager(n)).run(params, tokens)
    auto = Planner(tr, "GH200").auto().plan
    assert auto.n_launches < n
    out, _ = PlanExecutor(tr, auto).run(params, tokens)
    np.testing.assert_allclose(np.asarray(out[-1], np.float32),
                               np.asarray(eager[-1], np.float32), atol=1e-4)


# ------------------------------------------------------------ planner
def test_auto_plan_beats_fixed_chains_on_paper_workload():
    """Acceptance: modeled TKLQT of the auto plan <= best chain(L),
    L in {2,4,8,16}, on a paper workload (gpt2, Table III)."""
    cfg = reduced(get_config("gpt2"), n_layers=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                                cfg.vocab_size)

    def fwd(p, t):
        return forward(p, t, cfg, unroll=True)[0]

    tr = trace_fn(fwd, params, tokens)
    for platform in ("GH200", "Intel+H100"):
        planner = Planner(tr, platform)
        choice = planner.auto(lengths=(2, 4, 8, 16))
        chain_best = min(planner.evaluate(planner.chain(L)).tklqt
                         for L in (2, 4, 8, 16))
        assert choice.report.tklqt <= chain_best + 1e-15
        assert choice.report.tklqt < planner.evaluate(planner.eager()).tklqt


def test_cost_partition_isolates_device_bound_kernels():
    tr = trace_fn(_toy_fn, *_toy_args())
    planner = Planner(tr, "GH200")
    plan = planner.cost_partition(max_segment=4)
    plan.validate(len(tr.kernels))
    assert plan.max_segment <= 4


# ------------------------------------------------------------ segment cache
def test_segment_cache_hits_across_executors():
    args = _toy_args()
    tr = trace_fn(_toy_fn, *args)
    n = len(tr.kernels)
    clear_cache()
    ex1 = PlanExecutor(tr, LaunchPlan.whole_graph(n))
    ex1.run(*args)
    assert cache_stats() == {"hits": 0, "misses": 1}
    ex2 = PlanExecutor(tr, LaunchPlan.whole_graph(n))
    ex2.run(*args)
    assert cache_stats() == {"hits": 1, "misses": 1}
    # a different plan over the same trace is a distinct entry
    PlanExecutor(tr, LaunchPlan.eager(n)).run(*args)
    assert cache_stats()["misses"] == 2
    # a fresh trace of the same fn never aliases (unique trace token)
    tr2 = trace_fn(_toy_fn, *args)
    PlanExecutor(tr2, LaunchPlan.whole_graph(n)).run(*args)
    assert cache_stats()["misses"] == 3


# ------------------------------------------------------------ degenerate
def test_mine_chains_shorter_than_length():
    res = mine_chains(["a", "b", "c"], 8)
    assert res.k_fused == res.k_eager == 3
    assert res.speedup == 1.0 and res.candidates == []
    assert mine_chains([], 4).speedup == 1.0
    segs = fusion_segments(["a", "b", "c"], 8)
    assert segs == [[0], [1], [2]]


def test_measured_speedup_guards():
    assert _speedup(1.0, 0.5) == 2.0
    assert _speedup(1.0, 0.0) == float("inf")
    assert math.isnan(_speedup(0.0, 0.0))


# ------------------------------------------------------------ serve engine
def test_engine_chain_plan_fewer_dispatches_same_tokens():
    """Acceptance: plan='chain' decodes with strictly fewer dispatches per
    step than plan='eager' while generating identical tokens."""
    cfg = reduced(get_config("smollm-360m"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)

    def run(plan):
        eng = ServeEngine(cfg, params, max_batch=2, max_len=64, plan=plan)
        done = eng.run([Request(0, prompt=list(range(7, 17)),
                                max_new_tokens=4)])
        return [r.generated for r in done], eng.stats

    toks_jit, s_jit = run("jit")
    toks_eager, s_eager = run("eager")
    toks_chain, s_chain = run("chain")
    assert toks_jit == toks_eager == toks_chain
    assert s_chain.dispatches_per_decode_step \
        < s_eager.dispatches_per_decode_step
    assert s_jit.dispatches_per_decode_step == 1.0
    assert s_chain.decode_steps == s_eager.decode_steps
    assert s_chain.modeled_tklqt_s < s_eager.modeled_tklqt_s
    assert s_chain.plan == "chain" and s_chain.prefill_dispatches > 0

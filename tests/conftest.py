import os

# keep tests on the single real device (the dry-run sets 512 itself,
# in a separate process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

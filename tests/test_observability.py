"""Observability layer: metrics registry semantics, registry-backed
EngineStats, SpanRecorder ring buffer, operator->kernel attribution
completeness (plan=eager AND plan=fused), live boundedness monitor vs
the offline sweep rule, strict Chrome-trace export with paired flow
events, and the shared strict-JSON sanitizer."""
import json
import math
from fractions import Fraction

import jax
import pytest

from repro.configs import get_config, reduced
from repro.core.device_model import KernelEvent
from repro.core.export import merged_chrome_trace, to_chrome_trace
from repro.core.fusion import json_sanitize
from repro.inference.engine import Request, ServeEngine
from repro.models import init_params
from repro.telemetry.attribution import (AttributionReport, OperatorRow,
                                         attribute_events, merge_report,
                                         parse_operator)
from repro.telemetry.monitor import BoundednessMonitor
from repro.telemetry.registry import (Counter, MetricsRegistry,
                                      exponential_buckets)
from repro.telemetry.spans import SpanRecorder


# ------------------------------------------------------------ registry
def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests served")
    c.inc()
    c.inc(2.5)
    assert c.value() == 3.5
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)

    g = reg.gauge("util", "pool utilization")
    g.set(0.25)
    g.add(0.5)
    assert g.value() == 0.75

    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 5.0, 50.0):
        h.observe(v)
    assert h.count() == 4
    assert h.sum() == pytest.approx(55.55)
    assert h.quantile(0.25) == 0.1
    assert h.quantile(1.0) == math.inf          # overflow bucket
    with pytest.raises(ValueError, match="q must be"):
        h.quantile(1.5)


def test_registry_labels_strict_and_get_or_create():
    reg = MetricsRegistry()
    c = reg.counter("bytes_total", labels=("direction",))
    c.inc(10, direction="evict")
    c.inc(4, direction="restore")
    assert c.value(direction="evict") == 10
    # full label set is mandatory — both missing and surplus labels fail
    with pytest.raises(ValueError, match="declared labels"):
        c.inc(1)
    with pytest.raises(ValueError, match="declared labels"):
        c.inc(1, direction="evict", extra="x")
    # get-or-create returns the SAME family; kind mismatch is a TypeError
    assert reg.counter("bytes_total", labels=("direction",)) is c
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("bytes_total")


def test_exponential_buckets_and_validation():
    b = exponential_buckets(1e-6, 2.0, 4)
    assert b == (1e-6, 2e-6, 4e-6, 8e-6)
    with pytest.raises(ValueError):
        exponential_buckets(0.0, 2.0, 4)
    with pytest.raises(ValueError):
        exponential_buckets(1.0, 1.0, 4)
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="strictly"):
        reg.histogram("bad", buckets=(1.0, 1.0, 2.0))


def test_snapshot_and_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("a_total", "help a").inc(2)
    reg.gauge("b", labels=("batch",)).set(1.5, batch=4)
    reg.histogram("c_seconds", buckets=(0.5, 1.0)).observe(0.7)
    snap = reg.snapshot()
    assert set(snap) == {"a_total", "b", "c_seconds"}
    assert snap["a_total"]["type"] == "counter"
    assert snap["a_total"]["series"][0]["value"] == 2.0
    assert snap["b"]["series"][0]["labels"] == {"batch": "4"}
    assert snap["c_seconds"]["series"][0]["value"]["count"] == 1
    assert snap["c_seconds"]["buckets"] == [0.5, 1.0]
    json.dumps(snap, allow_nan=False)           # plain strict JSON

    text = reg.to_prometheus()
    assert "# TYPE a_total counter" in text
    assert "a_total 2" in text
    assert 'b{batch="4"} 1.5' in text
    assert 'c_seconds_bucket{le="1"} 1' in text
    assert 'c_seconds_bucket{le="+Inf"} 1' in text
    assert "c_seconds_count 1" in text


# ------------------------------------------------------------ spans ring
def test_span_recorder_default_uncapped():
    rec = SpanRecorder()
    for i in range(100):
        rec.add(f"s{i}", "host", float(i), float(i) + 0.5)
    assert len(rec.spans) == 100 and rec.dropped == 0


def test_span_recorder_ring_buffer_keeps_newest():
    rec = SpanRecorder(max_spans=3)
    for i in range(5):
        rec.add(f"s{i}", "host", float(i), float(i) + 0.5)
    assert len(rec.spans) == 3
    assert [s.name for s in rec.spans] == ["s2", "s3", "s4"]
    assert rec.dropped == 2
    with pytest.raises(ValueError, match="max_spans"):
        SpanRecorder(max_spans=0)


def test_span_recorder_dropped_counter_binds_and_backfills():
    rec = SpanRecorder(max_spans=2)
    for i in range(4):
        rec.add(f"s{i}", "host", 0.0, 1.0)
    reg = MetricsRegistry()
    rec.bind_metrics(reg)       # backfills the 2 pre-bind evictions
    c = reg.get("telemetry_spans_dropped_total")
    assert isinstance(c, Counter) and c.value() == 2
    rec.add("s4", "host", 0.0, 1.0)
    assert rec.dropped == 3 and c.value() == 3
    rec.clear()                 # clears spans, keeps the monotonic counter
    assert rec.spans == [] and c.value() == 3


# ------------------------------------------------------------ attribution
def test_parse_operator_taxonomy():
    assert parse_operator("layer3/slot0/attn").op == "attention"
    assert parse_operator("layer3/slot0/attn").layer == 3
    assert parse_operator("layer0/slot1/mlp").op == "mlp"
    assert parse_operator("layer0/norm1").op == "norm"
    assert parse_operator("embed").op == "embed"
    assert parse_operator("draft/layer0/attn").op == "draft"
    assert parse_operator("layer1/slot0/attn", "psum").op == "collective"
    assert parse_operator("mystery_scope").op == "other"
    tag = parse_operator("layer2/slot0/attn")
    assert tag.key(by_layer=True) == "layer2/attention"
    assert tag.key() == "attention"


class _K:
    def __init__(self, name, operator):
        self.name = name
        self.operator = operator


class _Plan:
    def __init__(self, segments):
        self.segments = segments


def _ev(name, t_launch=1e-6, t_queue=2e-6, duration=3e-6):
    return KernelEvent(name=name, launch_begin=0.0, launch_end=t_launch,
                       kernel_start=t_launch + t_queue,
                       kernel_end=t_launch + t_queue + duration)


def test_attribute_events_fused_segment_splits_fractionally():
    kernels = [_K("dot", "layer0/slot0/attn"), _K("add", "layer0/norm1"),
               _K("mul", "layer0/slot0/mlp")]
    plan = _Plan([(0, 1), (2,)])       # fused 2-kernel segment + singleton
    events = [_ev("seg0"), _ev("seg1")]
    rep = attribute_events(kernels, plan, events)
    assert rep.total_events == 2
    assert rep.complete                       # exact Fraction arithmetic
    by_op = {r.operator: r for r in rep.rows}
    assert by_op["attention"].launches == Fraction(1, 2)
    assert by_op["norm"].launches == Fraction(1, 2)
    assert by_op["mlp"].launches == 1
    # fused segment's times split 50/50 across its two members' operators
    assert by_op["attention"].launch_s == pytest.approx(0.5e-6)
    assert by_op["mlp"].tklqt_s == pytest.approx(3e-6)
    # rows are ranked by TKLQT and export percentages that sum to 100
    dicts = rep.as_dicts()
    assert dicts == sorted(dicts, key=lambda d: -d["tklqt_us"])
    assert sum(d["tklqt_pct"] for d in dicts) == pytest.approx(100.0)


def test_attribute_events_draft_and_mismatch_guards():
    kernels = [_K("dot", "layer0/slot0/attn")]
    plan = _Plan([(0,)])
    rep = attribute_events(kernels, plan,
                           [_ev("draft_launch[0]"), _ev("seg0")])
    assert {r.operator for r in rep.rows} == {"draft", "attention"}
    assert rep.complete and rep.total_events == 2
    with pytest.raises(ValueError, match="more segment events"):
        attribute_events(kernels, plan, [_ev("a"), _ev("b")])
    with pytest.raises(ValueError, match="covered 0 of 1"):
        attribute_events(kernels, plan, [])


def test_merge_report_accumulates_calls():
    rep = AttributionReport(
        rows=[OperatorRow("attention", launches=Fraction(3), kernels=3,
                          launch_s=1e-6, queue_s=2e-6, exec_s=3e-6)],
        total_events=3)
    acc: dict = {}
    merge_report(acc, rep, calls=2)
    merge_report(acc, rep, calls=1)
    assert acc["attention"].launches == 9
    assert acc["attention"].launch_s == pytest.approx(3e-6)


# ------------------------------------------------------------ engine wiring
@pytest.fixture(scope="module")
def tiny_setup():
    cfg = reduced(get_config("smollm-360m"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve(cfg, params, plan, n=3, **kw):
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64, plan=plan, **kw)
    eng.run([Request(i, prompt=list(range(5, 13)), max_new_tokens=4)
             for i in range(n)])
    return eng


@pytest.mark.parametrize("plan", ["eager", "fused"])
def test_attribution_accounts_all_decode_dispatches(tiny_setup, plan):
    """ISSUE acceptance: 100% of decode dispatches attributed, exactly,
    under a one-segment-per-kernel plan AND a fused-rule plan."""
    cfg, params = tiny_setup
    eng = _serve(cfg, params, plan)
    rep = eng._planned_decode.attribution
    assert rep is not None
    assert rep.complete
    assert rep.accounted_launches == rep.total_events
    # the per-call timeline matches the engine's measured dispatch rate
    st = eng.stats
    assert rep.total_events == pytest.approx(st.dispatches_per_decode_step)
    ops = {r.operator for r in rep.rows}
    assert {"attention", "mlp", "norm"} <= ops


def test_engine_stats_is_registry_view(tiny_setup):
    cfg, params = tiny_setup
    eng = _serve(cfg, params, "eager")
    st, reg = eng.stats, eng.registry
    snap = reg.snapshot()
    # scalar counters read back through the registry, as ints
    assert isinstance(st.tokens_out, int) and st.tokens_out == 12
    assert snap["engine_tokens_out"]["series"][0]["value"] == 12
    assert snap["engine_decode_steps"]["series"][0]["value"] == \
        st.decode_steps
    # latency histograms populated from the same run
    h = reg.get("engine_step_time_seconds")
    assert h.count() == st.decode_steps
    assert reg.get("engine_ttft_seconds").count() == st.prefills
    # backend + monitor families registered alongside
    assert reg.get("backend_dispatches_total") is not None
    assert reg.get("monitor_inflection_batch") is not None
    text = reg.to_prometheus()
    assert "engine_tokens_out 12" in text


def test_engine_reset_gives_fresh_registry(tiny_setup):
    cfg, params = tiny_setup
    eng = _serve(cfg, params, "eager")
    old = eng.registry
    assert eng.stats.tokens_out > 0
    eng.reset()
    assert eng.registry is not old          # warmup metrics don't leak
    assert eng.stats.tokens_out == 0
    assert eng.registry.get("engine_step_time_seconds").count() == 0
    assert eng.monitor.result().batches == []
    # run again: the rebound instruments record into the new registry
    eng.run([Request(0, prompt=list(range(5, 13)), max_new_tokens=4)])
    assert eng.stats.tokens_out == 4
    assert eng.registry.get("engine_step_time_seconds").count() > 0


def test_kvcache_metrics_flow_through_engine_registry(tiny_setup):
    cfg, params = tiny_setup
    eng = _serve(cfg, params, "eager", cache="paged", block_size=8)
    snap = eng.registry.snapshot()
    alloc = snap["kvcache_blocks_allocated_total"]["series"][0]["value"]
    freed = snap["kvcache_blocks_freed_total"]["series"][0]["value"]
    assert alloc > 0 and freed > 0
    # every page handed out came back once every request finished
    assert alloc == freed
    assert snap["kvcache_blocks_used"]["series"][0]["value"] == 0


def test_monitor_matches_offline_sweep_rule(tiny_setup):
    """ISSUE acceptance: the live monitor's transition batch equals
    classify_measured_sweep over the same (batch, step, tax) data."""
    cfg, params = tiny_setup
    from repro.telemetry.characterize import classify_measured_sweep
    mon = BoundednessMonitor()
    batches, steps, taxes = [], [], []
    for b in (1, 2, 4):
        # uniform closed workload: every request identical, max_batch=b,
        # so every decode step runs at the full batch and the monitor's
        # bucket means equal the run means
        eng = ServeEngine(cfg, params, max_batch=b, max_len=64,
                          plan="eager", monitor=mon)
        eng.run([Request(i, prompt=list(range(5, 13)), max_new_tokens=4)
                 for i in range(b)])
        st = eng.stats
        batches.append(b)
        steps.append(sum(st.step_times_s) / len(st.step_times_s))
        taxes.append(st.launch_tax_per_decode_step_s)
    live = mon.result()
    offline = classify_measured_sweep(batches, steps, taxes)
    assert live.batches == batches
    assert live.inflection_batch == offline.inflection_batch
    for b in batches:
        assert live.classify(b) == offline.classify(b)
    assert mon.verdict() in ("CPU-bound", "GPU-bound")
    # operator attribution rode along from every planned decode call
    top = mon.top_operators(k=3)
    assert top and top[0][2] >= top[-1][2]
    assert {op for op, _, _ in mon.top_operators(k=10)} >= \
        {"attention", "mlp", "norm"}
    summary = mon.summary()
    json.dumps(json_sanitize(summary), allow_nan=False)
    assert summary["classification"] and summary["top_operators"]


def test_monitor_off_and_empty_verdict(tiny_setup):
    cfg, params = tiny_setup
    eng = ServeEngine(cfg, params, max_batch=1, max_len=64, monitor=False)
    assert eng.monitor is None
    eng.run([Request(0, prompt=[3, 4, 5, 6], max_new_tokens=2)])
    assert eng.stats.tokens_out == 2        # telemetry-off still serves
    assert BoundednessMonitor().verdict() == "unknown"
    with pytest.raises(ValueError, match="window"):
        BoundednessMonitor(window=0)


# ------------------------------------------------------------ chrome trace
def _check_flow_pairing(trace):
    """Every dispatch_flow id must pair exactly one host start (``s``)
    with exactly one device finish (``f``)."""
    starts, finishes = {}, {}
    for ev in trace["traceEvents"]:
        assert ev["ph"] in ("X", "s", "f")
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        assert isinstance(ev["ts"], (int, float))
        if ev.get("cat") == "dispatch_flow":
            side = starts if ev["ph"] == "s" else finishes
            assert ev["id"] not in side, f"duplicate flow id {ev['id']}"
            side[ev["id"]] = ev
    assert set(starts) == set(finishes)
    return starts, finishes


def test_chrome_trace_strict_json_and_flow_pairs(tiny_setup):
    cfg, params = tiny_setup
    rec = SpanRecorder()
    eng = _serve(cfg, params, "eager", telemetry=rec)
    events = eng._planned_decode.modeled_events
    trace = to_chrome_trace(events, "TPU-v5e")
    json.dumps(trace, allow_nan=False)               # strict JSON
    starts, finishes = _check_flow_pairing(trace)
    assert len(starts) == len(events)
    for fid, s in starts.items():
        f = finishes[fid]
        assert s["tid"] == 0 and f["tid"] == 1       # host -> device
        assert f["bp"] == "e"
        assert f["ts"] >= s["ts"]                    # kernel after launch
    # kernel slices carry the operator provenance for attribution drill-in
    ops = [ev["args"]["operator"] for ev in trace["traceEvents"]
           if ev.get("cat") == "kernel" and "operator" in ev.get("args", {})]
    assert ops and any("attn" in o for o in ops)


def test_merged_trace_flow_pairs_per_anchor(tiny_setup):
    cfg, params = tiny_setup
    rec = SpanRecorder()
    eng = _serve(cfg, params, "eager", telemetry=rec)
    events = eng._planned_decode.modeled_events
    anchors = [s.t0 for s in rec.by_cat("decode")][:2]
    assert len(anchors) == 2
    trace = merged_chrome_trace(rec.spans, "TPU-v5e",
                                device_events=events,
                                device_anchors=anchors)
    json.dumps(trace, allow_nan=False)
    starts, finishes = _check_flow_pairing(trace)
    assert len(starts) == len(events) * len(anchors)
    for fid, s in starts.items():
        assert s["tid"] == 1 and finishes[fid]["tid"] == 2
    names = trace["otherData"]["thread_names"]
    assert set(names) == {"0", "1", "2"}


# ------------------------------------------------------------ strict JSON
def test_json_sanitize_nested_inf_nan():
    payload = {"a": float("inf"), "b": [float("nan"), 1.5],
               "c": {"d": (float("-inf"), "ok")}, "e": 3}
    out = json_sanitize(payload)
    json.dumps(out, allow_nan=False)                 # would raise unsanitized
    assert out["a"] == "inf" and out["b"][0] == "nan"
    assert out["c"]["d"] == ["-inf", "ok"] and out["e"] == 3
    with pytest.raises(ValueError):
        json.dumps(payload, allow_nan=False)


def test_bench_run_sanitizer_is_shared_helper():
    from benchmarks.run import _json_sanitize
    assert _json_sanitize({"x": float("inf")}) == {"x": "inf"}

"""Fused decode path: Pallas fused kernels vs refs (interpret mode), the
fusion-rule registry matching/substituting on real traces, plan-table
round-trips, and the serving engine's fused-plan dispatch accounting."""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.fusion import FusionOutcome, json_safe
from repro.core.tracing import trace_fn
from repro.inference.engine import Request, ServeEngine
from repro.kernels.fused import residual_rmsnorm, rmsnorm_matmul
from repro.kernels.fused.residual_rmsnorm.ref import residual_rmsnorm_ref
from repro.kernels.fused.rmsnorm_matmul.ref import rmsnorm_matmul_ref
from repro.layers.common import rmsnorm as rmsnorm_layer
from repro.models import forward, init_params, make_cache
from repro.runtime import (LaunchPlan, PlanExecutor, Planner, find_matches,
                           fused_plan)
from repro.runtime.autotune import (AutotuneEntry, CandidateResult, PlanTable,
                                    autotune, select)


# ------------------------------------------------------------ kernel numerics
@pytest.mark.parametrize("shape", [(1, 1, 64), (2, 3, 32), (5, 128)])
def test_residual_rmsnorm_matches_ref(shape):
    d = shape[-1]
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], shape)
    r = jax.random.normal(ks[1], shape)
    w = jax.random.normal(ks[2], (d,))
    y, s = residual_rmsnorm(x, w, r)
    y_ref, s_ref = residual_rmsnorm_ref(x.reshape(-1, d), w,
                                        r.reshape(-1, d))
    np.testing.assert_allclose(np.asarray(y).reshape(-1, d),
                               np.asarray(y_ref), atol=1e-6)
    np.testing.assert_allclose(np.asarray(s).reshape(-1, d),
                               np.asarray(s_ref), atol=1e-6)


def test_plain_rmsnorm_matches_layer_oracle():
    """Without a residual the fused kernel must equal layers.common.rmsnorm
    — the exact op the decode trace windows come from."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 1, 48))
    w = jax.random.normal(jax.random.PRNGKey(1), (48,))
    y, s = residual_rmsnorm(x, w)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(rmsnorm_layer(x, w)), atol=1e-6)
    np.testing.assert_allclose(np.asarray(s), np.asarray(x), atol=0)


@pytest.mark.parametrize("n,d,f", [(1, 64, 128), (7, 32, 48), (16, 64, 64)])
def test_rmsnorm_matmul_matches_ref(n, d, f):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.normal(ks[0], (n, d))
    w = jax.random.normal(ks[1], (d,))
    p = jax.random.normal(ks[2], (d, f))
    y, normed = rmsnorm_matmul(x, w, p)
    y_ref, normed_ref = rmsnorm_matmul_ref(x, w, p)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(normed), np.asarray(normed_ref),
                               atol=1e-6)


# ------------------------------------------------------------ rule registry
def _decode_setup(n_layers=2):
    cfg = reduced(get_config("smollm-360m"), n_layers=n_layers)
    params = init_params(jax.random.PRNGKey(0), cfg)
    cache = make_cache(cfg, 1, 64, src_len=1, dtype=cfg.cdtype)
    toks = jnp.zeros((1, 1), jnp.int32)
    lengths = jnp.ones((1,), jnp.int32)

    def decode_body(params, cache, tokens, lengths):
        logits, _, cache2 = forward(params, tokens, cfg, cache=cache,
                                    lengths=lengths, unroll=True)
        return logits[:, 0], cache2

    trace = trace_fn(decode_body, params, cache, toks, lengths)
    return cfg, params, trace, (params, cache, toks, lengths)


def test_rules_match_real_decode_trace():
    _, _, trace, _ = _decode_setup()
    matches = find_matches(trace)
    names = [m.rule_name for m in matches]
    # the decode trace has both block-boundary norms and norm->projection
    assert "residual_rmsnorm" in names
    assert "rmsnorm_matmul" in names
    for m in matches:
        # verified numeric equivalence on every substituted window
        assert m.max_abs_err <= 1e-4
        # windows are disjoint, in order
        assert m.stop - m.start == len(m.indices)
    starts = [m.start for m in matches]
    assert starts == sorted(starts)
    for a, b in zip(matches, matches[1:]):
        assert a.stop <= b.start


def test_fused_plan_is_exact_cover_with_rule_tags():
    _, _, trace, _ = _decode_setup()
    plan = fused_plan(trace)            # eager base
    plan.validate(len(trace.kernels))
    assert plan.strategy == "fused"
    assert plan.n_fused_rules > 0
    assert plan.n_launches < len(trace.kernels)
    rule_segs = dict(plan.rules)
    for si, name in rule_segs.items():
        assert len(plan.segments[si]) > 1
        assert name in plan.rule_names()
    # cache identity distinguishes rule-tagged plans
    assert plan.key() != LaunchPlan.eager(len(trace.kernels)).key()


def test_fused_plan_outputs_equal_eager():
    _, _, trace, args = _decode_setup()
    n = len(trace.kernels)
    eager, _ = PlanExecutor(trace, LaunchPlan.eager(n)).run(*args)
    for base in (None, Planner(trace, "GH200").auto().plan):
        plan = fused_plan(trace, base=base)
        out, _ = PlanExecutor(trace, plan).run(*args)
        for a, b in zip(eager, out):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=1e-5)


def test_planner_fused_rules_beats_eager_launches():
    _, _, trace, _ = _decode_setup()
    planner = Planner(trace, "GH200")
    plan = planner.fused_rules()
    assert plan.n_fused_rules > 0
    assert plan.n_launches < planner.eager().n_launches
    # modeled report prices the plan without error
    assert planner.evaluate(plan).tklqt > 0.0


# ------------------------------------------------------------ serving engine
def test_engine_fused_plan_fewer_dispatches_same_tokens():
    """Acceptance: at batch=1 the fused-rules plan decodes with fewer
    dispatches per step than eager, hits fusion rules every step, and
    generates identical tokens."""
    cfg = reduced(get_config("smollm-360m"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)

    def run(plan):
        eng = ServeEngine(cfg, params, max_batch=1, max_len=64, plan=plan)
        done = eng.run([Request(0, prompt=list(range(7, 17)),
                                max_new_tokens=4)])
        return [r.generated for r in done], eng.stats

    toks_eager, s_eager = run("eager")
    toks_fused, s_fused = run("fused")
    assert toks_eager == toks_fused
    assert s_fused.dispatches_per_decode_step \
        < s_eager.dispatches_per_decode_step
    assert s_fused.fused_dispatches_per_decode_step > 0
    assert s_fused.rule_hits and all(v > 0
                                     for v in s_fused.rule_hits.values())
    assert s_eager.fused_dispatches == 0 and not s_eager.rule_hits


# ------------------------------------------------------------ autotuner
def _table():
    def cand(plan, step_us, disp):
        return CandidateResult(
            plan=plan, mean_decode_step_s=step_us * 1e-6,
            decode_launch_tax_s=0.0, dispatches_per_decode_step=disp,
            fused_dispatches_per_decode_step=0.0, tokens_per_s=1.0,
            decode_steps=10)

    t = PlanTable(arch="smollm-360m", scenario="chatbot",
                  platform="TPU-v5e")
    t.entries[1] = AutotuneEntry(
        batch=1, region="CPU-bound", selected="fused",
        candidates=[cand("eager", 100.0, 331), cand("fused", 40.0, 13)])
    t.entries[8] = AutotuneEntry(
        batch=8, region="GPU-bound", selected="jit",
        candidates=[cand("jit", 20.0, 1)])
    return t


def test_plan_table_round_trip(tmp_path):
    t = _table()
    path = t.save(str(tmp_path / "plan_table.json"))
    loaded = PlanTable.load(path)
    assert loaded.to_dict() == t.to_dict()
    assert loaded.lookup(1) == "fused"
    assert loaded.lookup(8) == "jit"
    # between entries -> nearest at/below; below all -> smallest
    assert loaded.lookup(4) == "fused"
    assert loaded.lookup(64) == "jit"
    assert PlanTable.from_any(path).lookup(1) == "fused"
    assert PlanTable.from_any(loaded.to_dict()).lookup(8) == "jit"
    with pytest.raises(ValueError):
        PlanTable.from_dict({"version": 99})
    assert PlanTable("a", "s", "p").lookup(4) == "auto"


def test_select_prefers_fewer_dispatches_on_tie():
    def cand(plan, step_us, disp):
        return CandidateResult(
            plan=plan, mean_decode_step_s=step_us * 1e-6,
            decode_launch_tax_s=0.0, dispatches_per_decode_step=disp,
            fused_dispatches_per_decode_step=0.0, tokens_per_s=1.0,
            decode_steps=10)

    assert select([cand("eager", 100, 331), cand("fused", 50, 13)]) == "fused"
    # within the tie band the lower dispatch count wins
    assert select([cand("chain", 50.2, 191), cand("fused", 50.0, 13),
                   cand("eager", 100, 331)], tie_rel_tol=0.05) == "fused"
    assert select([cand("fused", 50.0, 13), cand("chain", 49.9, 191)],
                  tie_rel_tol=0.05) == "fused"


def test_autotune_emits_fused_or_chain_in_cpu_bound_region(tmp_path):
    """Mini end-to-end: autotune one CPU-bound batch point, persist the
    table, and serve with plan='autotuned' resolving from it."""
    cfg = reduced(get_config("smollm-360m"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    result = autotune(cfg, params, scenario="chatbot", batches=(1,),
                      n_requests=3, prompt_cap=12, output_cap=4,
                      max_len=64)
    entry = result.table.entries[1]
    assert entry.region == "CPU-bound"     # single point: flat curve
    assert entry.selected in ("fused", "chain")
    assert {c.plan for c in entry.candidates} == {"eager", "chain", "fused"}
    path = result.table.save(str(tmp_path / "plan_table.json"))

    eng = ServeEngine(cfg, params, max_batch=1, max_len=64,
                      plan="autotuned", plan_table=path)
    assert eng.plan == entry.selected
    assert eng.plan_label == f"autotuned:{entry.selected}"
    done = eng.run([Request(0, prompt=[3, 5, 7], max_new_tokens=2)])
    assert len(done) == 1 and len(done[0].generated) == 2
    with pytest.raises(ValueError):
        ServeEngine(cfg, params, max_batch=1, plan="autotuned")


# ------------------------------------------------------------ json export
def test_fusion_outcome_json_safe():
    """Regression: inf/nan speedups must serialize as STRICT json — a
    0-cost fused run used to emit bare Infinity/NaN tokens."""
    out = FusionOutcome(length=8, k_eager=10, k_fused=2,
                        ideal_speedup=5.0, eager_host_s=1.0,
                        fused_host_s=0.0,
                        measured_speedup=float("inf"),
                        max_abs_err=float("nan"))
    payload = json.dumps(out.row(), allow_nan=False)   # must not raise
    parsed = json.loads(payload)
    assert parsed["measured_speedup"] == "inf"
    assert parsed["max_abs_err"] == "nan"
    assert parsed["ideal_speedup"] == 5.0
    assert json_safe(2.5) == 2.5 and json_safe(float("-inf")) == "-inf"
    assert math.isnan(float("nan"))  # sanity: nan stays nan pre-export

    from benchmarks.run import _json_sanitize
    nested = {"rows": [{"us_per_call": float("inf"), "ok": 1.0}]}
    safe = json.dumps(_json_sanitize(nested), allow_nan=False)
    assert json.loads(safe)["rows"][0]["us_per_call"] == "inf"

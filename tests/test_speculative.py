"""Speculative decoding: accept-rule units, launch-tax-aware depth policy,
greedy byte-equivalence across seeds and cache modes (including an
adversarial always-rejecting draft), paged block-table rollback,
preempt->resume interaction, counter invariants, and validation errors.

The greedy contract under test: every token the speculative engine emits
is an argmax the TARGET computed from the true prefix, so the output
stream is byte-identical to plain greedy decoding regardless of draft
quality — the draft can only change HOW MANY launches it took."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.inference.engine import Request, ServeEngine
from repro.inference.speculative import (accept_lengths, default_draft_config,
                                         draft_params_from_target,
                                         greedy_accept, is_truncation_of,
                                         pick_spec_k, validate_draft)
from repro.kvcache.allocator import BlockPool
from repro.models import init_params


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("smollm-360m"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def reject_draft(tiny):
    """Adversarial draft: truncated-target params with an UNTIED, shifted
    unembed — it proposes ~x+1 wherever the (tied-embedding) target copies
    x, so verify rejects at position 0 nearly every round.  Maximal
    pressure on the correction + rollback paths."""
    cfg, params = tiny
    dcfg = default_draft_config(cfg).replace(tie_embeddings=False)
    dparams = dict(draft_params_from_target(params, dcfg))
    dparams["lm_head"] = jnp.roll(params["embed"], 1, axis=0).T
    return dcfg, dparams


def _requests(cfg, n=3, new=10, seed=7):
    rng = np.random.default_rng(seed)
    return [Request(i, prompt=list(rng.integers(1, cfg.vocab_size, 5 + i)),
                    max_new_tokens=new) for i in range(n)]


def _toks(eng, cfg, **kw):
    done = eng.run(_requests(cfg, **kw))
    return {r.rid: list(r.generated) for r in done}


# ------------------------------------------------------------ accept rule
def test_greedy_accept_full_accept():
    n, emitted = greedy_accept([5, 9, 2], [5, 9, 2, 7])
    assert n == 3
    # the whole window plus the target's bonus token after it
    assert emitted == [5, 9, 2, 7]


def test_greedy_accept_full_reject():
    n, emitted = greedy_accept([5, 9, 2], [4, 9, 2, 7])
    assert n == 0
    # still emits >= 1 token: the target's own correction
    assert emitted == [4]


def test_greedy_accept_mid_window_reject():
    n, emitted = greedy_accept([5, 9, 2], [5, 9, 8, 7])
    assert n == 2
    # accepted prefix, then the target's correction REPLACES the draft's
    # rejected token — never the draft's
    assert emitted == [5, 9, 8]


def test_greedy_accept_shape_mismatch():
    with pytest.raises(ValueError, match="k\\+1"):
        greedy_accept([5, 9], [5, 9])


def test_accept_lengths_vectorized():
    draft = np.array([[5, 9, 2], [5, 9, 2], [1, 2, 3]])
    tgt = np.array([[5, 9, 2, 7], [5, 8, 2, 7], [0, 2, 3, 4]])
    assert accept_lengths(draft, tgt).tolist() == [3, 1, 0]


# ------------------------------------------------------------ depth policy
def test_pick_spec_k_deep_when_cpu_bound():
    # inflection None = dispatch-bound over the whole measured range
    assert pick_spec_k(1, max_k=8, inflection_batch=None) == 8
    assert pick_spec_k(4, max_k=8, inflection_batch=16) == 8


def test_pick_spec_k_shallow_near_inflection():
    assert pick_spec_k(12, max_k=8, inflection_batch=16) == 4


def test_pick_spec_k_off_when_gpu_bound():
    assert pick_spec_k(16, max_k=8, inflection_batch=16) == 0
    assert pick_spec_k(64, max_k=8, inflection_batch=16) == 0


def test_pick_spec_k_degenerate():
    assert pick_spec_k(0, max_k=8) == 0
    assert pick_spec_k(4, max_k=0) == 0


# ------------------------------------------------------------ validation
def test_validate_draft_errors(tiny):
    cfg, _ = tiny
    dcfg = default_draft_config(cfg)
    with pytest.raises(ValueError, match="spec_k"):
        validate_draft(cfg, dcfg, 0)
    with pytest.raises(ValueError, match="vocab"):
        validate_draft(cfg, dcfg.replace(vocab_size=cfg.vocab_size + 1), 4)
    with pytest.raises(ValueError, match="not smaller"):
        validate_draft(cfg, cfg, 4)


def test_engine_speculative_requires_jit_and_greedy(tiny):
    cfg, params = tiny
    with pytest.raises(ValueError, match="plan='jit'"):
        ServeEngine(cfg, params, max_batch=2, max_len=32,
                    speculative=True, plan="eager")
    with pytest.raises(ValueError, match="greedy"):
        ServeEngine(cfg, params, max_batch=2, max_len=32,
                    speculative=True, greedy=False)
    with pytest.raises(ValueError, match="speculative"):
        ServeEngine(cfg, params, max_batch=2, max_len=32,
                    draft_config=default_draft_config(cfg))


def test_engine_rejects_non_truncation_draft_without_params(tiny):
    cfg, params = tiny
    bad = default_draft_config(cfg).replace(d_model=cfg.d_model * 2,
                                            head_dim=cfg.hd * 2)
    with pytest.raises(ValueError, match="draft_params"):
        ServeEngine(cfg, params, max_batch=2, max_len=32,
                    speculative=True, draft_config=bad)


def test_is_truncation_of(tiny):
    cfg, _ = tiny
    assert is_truncation_of(default_draft_config(cfg), cfg)
    assert not is_truncation_of(cfg.replace(d_model=cfg.d_model * 2), cfg)


# ------------------------------------------------ greedy byte-equivalence
@pytest.mark.parametrize("seed", [0, 1])
def test_spec_matches_greedy_contiguous(tiny, seed):
    cfg, params = tiny
    ref = _toks(ServeEngine(cfg, params, max_batch=4, max_len=64),
                cfg, seed=seed)
    eng = ServeEngine(cfg, params, max_batch=4, max_len=64,
                      speculative=True, spec_k=4)
    assert _toks(eng, cfg, seed=seed) == ref
    assert eng.stats.spec_rounds > 0


@pytest.mark.parametrize("seed", [0, 1])
def test_spec_matches_greedy_paged(tiny, seed):
    cfg, params = tiny
    ref = _toks(ServeEngine(cfg, params, max_batch=4, max_len=64),
                cfg, seed=seed)
    eng = ServeEngine(cfg, params, max_batch=4, max_len=64, cache="paged",
                      block_size=4, num_blocks=64, speculative=True,
                      spec_k=4)
    assert _toks(eng, cfg, seed=seed) == ref
    assert eng.stats.spec_rounds > 0


@pytest.mark.parametrize("cache_kw", [
    {},
    dict(cache="paged", block_size=4, num_blocks=64),
])
def test_rejecting_draft_still_byte_identical(tiny, reject_draft, cache_kw):
    """Full-reject pressure: the draft disagrees almost everywhere, so
    every round exercises the correction path (and, paged, the
    block-table rollback of the over-grown verify window)."""
    cfg, params = tiny
    dcfg, dparams = reject_draft
    ref = _toks(ServeEngine(cfg, params, max_batch=4, max_len=64), cfg)
    eng = ServeEngine(cfg, params, max_batch=4, max_len=64,
                      speculative=True, spec_k=4, draft_config=dcfg,
                      draft_params=dparams, **cache_kw)
    assert _toks(eng, cfg) == ref
    # the adversarial draft must actually have been rejected
    assert eng.stats.accept_rate < 0.5
    assert eng.stats.corrections > 0


def test_spec_preempt_resume_byte_identical(tiny, reject_draft):
    """Tight pool + host offload: speculation's over-grown windows force
    rollback AND interact with evict/restore; tokens must not change."""
    cfg, params = tiny
    dcfg, dparams = reject_draft
    kw = dict(max_batch=4, max_len=64, cache="paged", block_size=4,
              num_blocks=24, offload="host")
    ref_eng = ServeEngine(cfg, params, **kw)
    ref = _toks(ref_eng, cfg, n=4, new=14)
    eng = ServeEngine(cfg, params, speculative=True, spec_k=4,
                      draft_config=dcfg, draft_params=dparams, **kw)
    assert _toks(eng, cfg, n=4, new=14) == ref


# -------------------------------------------------------- paged rollback
def test_block_pool_trim():
    pool = BlockPool(num_blocks=8, block_size=4)
    pool.alloc("r", 4)                       # covers 16 tokens
    freed = pool.trim("r", 6)                # only 2 blocks needed
    assert freed == [2, 3]
    assert pool.owned("r") == [0, 1]
    assert pool.free_blocks == 6
    assert pool.trim("r", 6) == []           # idempotent
    assert pool.trim("missing", 1) == []


def test_spec_round_trims_rejected_blocks(tiny, reject_draft):
    """With an always-rejecting draft, each verify window grows the block
    table past what the single emitted token needs; the rollback must
    return those blocks, so the spec engine's PEAK pool utilization stays
    within one verify window of the greedy run's."""
    cfg, params = tiny
    dcfg, dparams = reject_draft
    kw = dict(max_batch=2, max_len=64, cache="paged", block_size=4,
              num_blocks=32)
    ref = ServeEngine(cfg, params, **kw)
    _toks(ref, cfg, n=2)
    eng = ServeEngine(cfg, params, speculative=True, spec_k=4,
                      draft_config=dcfg, draft_params=dparams, **kw)
    _toks(eng, cfg, n=2)
    assert eng.stats.accept_rate < 0.5
    # k=4 verify can touch at most ceil((k+1)/block_size)+1 = 3 extra
    # blocks per row beyond the emitted length; without trim the gap
    # would instead grow with every rejected round
    b = kw["max_batch"]
    slack = (3 * b) / kw["num_blocks"]
    assert (eng.stats.peak_block_pool_utilization
            <= ref.stats.peak_block_pool_utilization + slack)


# ------------------------------------------------------------ counters
def test_counter_invariants(tiny):
    cfg, params = tiny
    n = 3
    eng = ServeEngine(cfg, params, max_batch=4, max_len=64,
                      speculative=True, spec_k=3)
    _toks(eng, cfg, n=n)
    st = eng.stats
    assert 0 < st.accepted <= st.proposed
    assert st.proposed <= 3 * st.spec_rounds * 4
    assert st.spec_emitted == st.accepted + st.corrections
    # each request's first token comes from prefill, the rest from rounds
    assert st.tokens_out == st.spec_emitted + n
    assert st.draft_dispatches >= st.spec_rounds          # >= 1 per round
    assert st.modeled_draft_launch_tax_s > 0
    assert 0 < st.steps_per_emitted_token < 1
    assert 0 < st.accept_rate <= 1


def test_reset_clears_spec_state(tiny):
    cfg, params = tiny
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64,
                      speculative=True, spec_k=3)
    first = _toks(eng, cfg, n=2)
    eng.reset()
    assert eng.stats.spec_rounds == 0
    assert not eng.draft_lengths.any()
    assert _toks(eng, cfg, n=2) == first


def test_depth_policy_disables_speculation_past_inflection(tiny):
    """spec_inflection at/below the running batch turns rounds off — the
    engine falls back to plain decode steps (and still matches greedy)."""
    cfg, params = tiny
    ref = _toks(ServeEngine(cfg, params, max_batch=2, max_len=64), cfg, n=2)
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64,
                      speculative=True, spec_k=4, spec_inflection=1)
    assert _toks(eng, cfg, n=2) == ref
    assert eng.stats.spec_rounds == 0
    assert eng.stats.proposed == 0

"""End-to-end system behaviour: training convergence, serving engine
correctness under continuous batching, SKIP-on-model integration."""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.inference.engine import Request, ServeEngine
from repro.models import forward, init_params, make_cache
from repro.training.loop import TrainConfig, Trainer


def test_training_reduces_loss(tmp_path):
    cfg = reduced(get_config("smollm-360m"), n_layers=2)
    data = DataConfig(batch=4, seq_len=64, vocab_size=cfg.vocab_size)
    from repro.training.optim import OptConfig
    out = Trainer(cfg, data, TrainConfig(steps=30, ckpt_every=100,
                                         ckpt_dir=str(tmp_path)),
                  OptConfig(lr=1e-3, warmup_steps=5, total_steps=30)).run()
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_engine_continuous_batching_matches_incremental():
    cfg = reduced(get_config("smollm-360m"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    req = Request(0, prompt=list(range(7, 17)), max_new_tokens=5)
    eng = ServeEngine(cfg, params, max_batch=3, max_len=64)
    out_cb = eng.run([req])[0].generated

    cache = make_cache(cfg, 1, 64, src_len=1)
    toks = jnp.asarray([req.prompt], jnp.int32)
    logits, _, cache = forward(params, toks, cfg, cache=cache,
                               cache_index=jnp.zeros((), jnp.int32))
    seq = [int(jnp.argmax(logits[0, len(req.prompt) - 1]))]
    idx = len(req.prompt)
    for _ in range(4):
        logits, _, cache = forward(params, jnp.asarray([[seq[-1]]], jnp.int32),
                                   cfg, cache=cache,
                                   cache_index=jnp.asarray(idx, jnp.int32))
        seq.append(int(jnp.argmax(logits[0, 0])))
        idx += 1
    assert out_cb == seq


def test_engine_slot_reuse_no_state_leak():
    """A slot reused by a second request must produce the same output as a
    fresh engine (recurrent-state zeroing on admit)."""
    cfg = reduced(get_config("rwkv6-3b"), n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    r_warm = Request(0, prompt=[5, 6, 7, 8], max_new_tokens=3)
    target = Request(1, prompt=[20, 21, 22, 23], max_new_tokens=4)
    eng = ServeEngine(cfg, params, max_batch=1, max_len=64)
    eng.run([r_warm])                       # occupies + frees slot 0
    got = eng.run([Request(2, prompt=list(target.prompt),
                           max_new_tokens=4)])[0].generated
    fresh = ServeEngine(cfg, params, max_batch=1, max_len=64)
    want = fresh.run([target])[0].generated
    assert got == want


def test_skip_on_model_finds_layer_chains():
    from repro.core import SKIP
    cfg = reduced(get_config("gpt2"), n_layers=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                                cfg.vocab_size)

    def fwd(p, t):
        return forward(p, t, cfg, unroll=True)[0]

    skip = SKIP.trace(fwd, params, tokens)
    rec = skip.recommend(length=8)
    assert len(rec.deterministic) > 0          # per-layer repeats exist
    assert rec.speedup > 1.3                   # Eq. 8 on a real model
    out = skip.fuse(length=8, repeats=1)
    assert out.k_fused < out.k_eager
    assert out.max_abs_err < 1e-4
